"""Sync/async equivalence and fault tolerance of the continuous-batching
front-end: the same seeded workload through ``GeometryServer.flush`` and
through ``AsyncGeometryServer`` must produce bitwise-identical per-ticket
results and identical launch/byte counters for EVERY plan kind (diagonal,
matrix, projective, fixed-point), the awaitable-ticket protocol must
deliver the same values, and the PR 6 zero-lost-requests invariant must
hold under fault injection THROUGH the async path.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import serving
from repro.core.transform_chain import TransformChain
from repro.serving import workload
from repro.serving.async_engine import AsyncGeometryServer, SLOConfig
from repro.serving.clock import VirtualClock


def _reset():
    serving.reset_stats()
    serving.clear_plan_cache()


def _fresh_async(**kw):
    _reset()
    kw.setdefault("clock", VirtualClock())
    return AsyncGeometryServer(**kw)


#: the counters that must be IDENTICAL between one synchronous flush and
#: an async drain of the same submissions -- the front-end decides when
#: buckets launch, never what a launch computes or moves
_ECONOMY = ("launches", "buckets", "requests", "payload_points",
            "padded_points", "plan_compiles", "traces")


def _snap():
    return {k: serving.stats[k] for k in _ECONOMY}


def _assert_same_result(a, b):
    """Bitwise equality, including the projective cull mask."""
    mask_a = getattr(a, "mask", None)
    mask_b = getattr(b, "mask", None)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (mask_a is None) == (mask_b is None)
    if mask_a is not None:
        np.testing.assert_array_equal(np.asarray(mask_a),
                                      np.asarray(mask_b))


# ---------------------------------------------------------------------------
# sync/async bitwise equivalence, per plan kind and mixed
# ---------------------------------------------------------------------------

#: one workload generator per plan kind the engine compiles
_KINDS = {
    "diag": lambda rng: (workload.chain_for(rng, 2, "TST"), None),
    "matrix": lambda rng: (workload.chain_for(rng, 3, "TRS"), None),
    "projective": lambda rng: (workload.chain_for(rng, 3, "TSRP"), None),
    "q8.7": lambda rng: (workload.chain_for(rng, 2, "TTSS"), "q8.7"),
}


def _kind_workload(kind: str, n: int, seed: int):
    rng = np.random.default_rng([0xA51C, seed])
    reqs = []
    for _ in range(n):
        chain, qname = _KINDS[kind](rng)
        pts = rng.uniform(-2, 2, (int(rng.integers(1, 40)),
                                  chain.dim)).astype(np.float32)
        reqs.append((chain, pts, qname))
    return reqs


@pytest.mark.parametrize("kind", sorted(_KINDS))
def test_sync_async_bitwise_equivalence(kind):
    reqs = _kind_workload(kind, 24, seed=3)

    _reset()
    sync = serving.GeometryServer(backend="ref")
    for chain, pts, qname in reqs:
        sync.submit(chain, pts, qformat=qname)
    sync_results = sync.flush()
    sync_counters = _snap()

    eng = _fresh_async(backend="ref")
    tickets = [eng.submit_async(chain, pts, qformat=qname)
               for chain, pts, qname in reqs]
    eng.drain()
    async_counters = _snap()

    assert async_counters == sync_counters
    for t, expected in zip(tickets, sync_results):
        assert t.done()
        _assert_same_result(t.result(), expected)


def test_sync_async_equivalence_mixed_lanes():
    """All plan kinds and both dtype lanes in ONE flush: the async drain
    must reproduce the synchronous bucket composition exactly."""
    reqs = workload.mixed_lane_workload(7, 48)
    assert any(q for _, _, q in reqs) and \
        any(c.is_projective for c, _, _ in reqs)

    _reset()
    sync = serving.GeometryServer(backend="ref")
    for chain, pts, qname in reqs:
        sync.submit(chain, pts, qformat=qname)
    sync_results = sync.flush()
    sync_counters = _snap()
    assert sync_counters["launches"] < len(reqs)   # batching did happen

    eng = _fresh_async(backend="ref")
    tickets = [eng.submit_async(chain, pts, qformat=qname)
               for chain, pts, qname in reqs]
    eng.drain()
    assert _snap() == sync_counters
    for t, expected in zip(tickets, sync_results):
        _assert_same_result(t.result(), expected)
    st = eng.stats
    assert st["resolved"] == len(reqs) and st["failed"] == 0
    assert st["queue_depth"] == 0


def test_async_results_deterministic_across_engines():
    """Two engines, same submissions, same (virtual) schedule -> bitwise
    identical resolutions: the determinism the soak gate stands on."""
    reqs = workload.mixed_lane_workload(13, 24)

    def serve():
        eng = _fresh_async(backend="ref")
        ts = [eng.submit_async(c, p, qformat=q) for c, p, q in reqs]
        eng.drain()
        return [np.asarray(t.result()) for t in ts]

    for a, b in zip(serve(), serve()):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# awaitable tickets
# ---------------------------------------------------------------------------

def test_ticket_await_protocol():
    rng = np.random.default_rng(0)
    chain = workload.chain_for(rng, 3, "TRS")
    pts = rng.normal(size=(6, 3)).astype(np.float32)
    eng = _fresh_async(backend="ref",
                       slo=SLOConfig(max_wait_s=0.001, target_rows=64))

    async def request_stream():
        t = eng.submit_async(chain, pts)
        assert not t.done()
        out = await t
        return np.asarray(out)

    got, = eng.run(request_stream())
    exp = np.asarray(chain.apply(jnp.asarray(pts), backend="ref"))
    np.testing.assert_allclose(got, exp, rtol=2e-6, atol=2e-6)


def test_run_interleaves_multiple_streams():
    """Coroutines submitting at different virtual instants all resolve,
    and each awaited value matches that stream's own request."""
    rng = np.random.default_rng(1)
    chain = workload.chain_for(rng, 2, "TSRT")
    eng = _fresh_async(backend="ref",
                       slo=SLOConfig(max_wait_s=0.002, target_rows=4))
    payloads = [rng.normal(size=(n, 2)).astype(np.float32)
                for n in (3, 5, 7)]

    async def stream(pts):
        first = await eng.submit_async(chain, pts)
        second = await eng.submit_async(chain, pts * 2)
        return np.asarray(first), np.asarray(second)

    results = eng.run(*[stream(p) for p in payloads])
    for pts, (first, second) in zip(payloads, results):
        exp1 = chain.apply(jnp.asarray(pts), backend="ref")
        exp2 = chain.apply(jnp.asarray(pts * 2), backend="ref")
        np.testing.assert_allclose(first, np.asarray(exp1),
                                   rtol=2e-6, atol=2e-6)
        np.testing.assert_allclose(second, np.asarray(exp2),
                                   rtol=2e-6, atol=2e-6)


def test_ticket_result_before_resolution_raises():
    rng = np.random.default_rng(2)
    chain = workload.chain_for(rng, 2, "TST")
    eng = _fresh_async(backend="ref")
    t = eng.submit_async(chain, np.ones((3, 2), np.float32))
    with pytest.raises(RuntimeError, match="pending"):
        t.result()
    assert t.latency is None
    eng.drain()
    assert t.latency == 0.0          # same virtual instant


def test_gather_returns_results_in_ticket_order():
    rng = np.random.default_rng(3)
    chain = workload.chain_for(rng, 2, "TST")
    eng = _fresh_async(backend="ref",
                       slo=SLOConfig(max_wait_s=0.004, target_rows=64))
    pts = [np.full((2, 2), i, np.float32) for i in range(5)]
    tickets = [eng.submit_async(chain, p) for p in pts]
    results = eng.gather(tickets)
    assert all(t.done() for t in tickets)
    for r, p in zip(results, pts):
        exp = chain.apply(jnp.asarray(p), backend="ref")
        np.testing.assert_array_equal(np.asarray(r), np.asarray(exp))


# ---------------------------------------------------------------------------
# identity chains ride the always-due passthrough
# ---------------------------------------------------------------------------

def test_identity_chain_resolves_on_first_poll():
    eng = _fresh_async(backend="ref",
                       slo=SLOConfig(max_wait_s=10.0, target_rows=64))
    pts = np.arange(8, dtype=np.float32).reshape(4, 2)
    t = eng.submit_async(TransformChain.identity(2), pts)
    assert eng.next_due_in() == 0.0     # no launch to amortise
    assert eng.poll() == 1
    np.testing.assert_array_equal(np.asarray(t.result()), pts)
    assert serving.stats["launches"] == 0


# ---------------------------------------------------------------------------
# typed rejections at the async intake
# ---------------------------------------------------------------------------

def test_validation_rejection_releases_admission_slot():
    rng = np.random.default_rng(4)
    chain = workload.chain_for(rng, 2, "TST")
    eng = _fresh_async(backend="ref")
    with pytest.raises(serving.RequestError) as exc:
        eng.submit_async(chain, np.ones((3, 3), np.float32))  # wrong dim
    assert exc.value.code == "shape"
    # the request never queued: slot, admitted count, and module stats
    assert eng.queue_depth == 0
    st = eng.stats
    assert st["admitted"] == 0
    assert serving.stats["admitted_requests"] == 0
    assert serving.stats["rejected_requests"] == 1
    # the engine still serves afterwards
    t = eng.submit_async(chain, np.ones((3, 2), np.float32))
    eng.drain()
    assert t.done()


# ---------------------------------------------------------------------------
# PR 6 fault tolerance composes with continuous batching
# ---------------------------------------------------------------------------

def test_chaos_zero_lost_through_async_path():
    """Every admitted request resolves to points or a typed error under
    fault injection -- the zero-lost invariant, now on the async path."""
    reqs = workload.mixed_lane_workload(21, 48)
    inj = serving.FaultInjector(seed=21, flaky_rate=0.1, backend_rate=0.08,
                                corrupt_rate=0.08, poison_rate=0.05)
    eng = _fresh_async(backend="interpret", injector=inj,
                       fault_config=serving.FaultConfig(backoff_base_s=0.0))
    tickets = [eng.submit_async(c, p, qformat=q) for c, p, q in reqs]
    eng.drain()

    assert all(t.done() for t in tickets)
    failed = [t for t in tickets if serving.is_error(t.result())]
    resolved = [t for t in tickets if not serving.is_error(t.result())]
    # the injector's rates guarantee the ladder actually ran
    assert serving.stats["launch_failures"] > 0
    for t in failed:
        assert isinstance(t.result(), serving.LaunchError)
        assert t.result().ticket == t.id
    st = eng.stats
    assert st["resolved"] == len(resolved)
    assert st["failed"] == len(failed)
    assert st["resolved"] + st["failed"] == st["admitted"] == len(reqs)
    assert st["queue_depth"] == 0

    # recovered results are the true values: spot-check a few against
    # the oracle the chaos harness uses
    for t, (chain, pts, qname) in list(zip(tickets, reqs))[:8]:
        if serving.is_error(t.result()) or qname is not None:
            continue
        if chain.is_projective:
            continue
        exp = chain.apply(jnp.asarray(pts), backend="interpret")
        np.testing.assert_allclose(np.asarray(t.result()), np.asarray(exp),
                                   rtol=2e-5, atol=2e-5)
