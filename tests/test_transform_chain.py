"""Fused transform-chain compiler tests: random composite chains against a
sequential per-primitive oracle (deterministic property-style sweeps), the
plan-cache no-retrace guarantee, and the one-HBM-pass byte economy.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import transform_chain as tc
from repro.core import transform_engine as te
from repro.kernels import opcount

RNG = np.random.default_rng(7)


def _rot_row(dim, axis, theta):
    """Independent right-multiply rotation matrix for the oracle."""
    c, s = np.cos(theta), np.sin(theta)
    if dim == 2:
        return np.array([[c, s], [-s, c]], np.float32)
    m = np.eye(3, dtype=np.float32)
    i, j = [(1, 2), (2, 0), (0, 1)][axis]
    m[i, i] = m[j, j] = c
    m[i, j], m[j, i] = s, -s
    return m


def _sequential_oracle(chain: tc.TransformChain, pts: np.ndarray) -> np.ndarray:
    """Apply the chain one primitive at a time in float64 numpy."""
    q = np.asarray(pts, np.float64)
    d = chain.dim
    for (kind, axis), val in zip(chain.kinds, chain.params):
        if kind == "T":
            q = q + np.broadcast_to(np.asarray(val, np.float64), (d,))
        elif kind == "S":
            q = q * np.broadcast_to(np.asarray(val, np.float64), (d,))
        elif kind == "A":
            s = np.broadcast_to(np.asarray(val[0], np.float64), (d,))
            t = np.broadcast_to(np.asarray(val[1], np.float64), (d,))
            q = q * s + t
        elif kind == "R":
            q = q @ _rot_row(d, axis, val)
        else:
            m = np.asarray(val, np.float64)
            if m.shape == (d + 1, d + 1):
                q = q @ m[:d, :d] + m[d, :d]
            else:
                q = q @ m
    return q.astype(np.float32)


def _random_chain(rng, dim, length) -> tc.TransformChain:
    chain = tc.TransformChain.identity(dim)
    for _ in range(length):
        kind = rng.choice(["T", "S", "R", "A", "M"])
        if kind == "T":
            chain = chain.translate(*rng.uniform(-3, 3, dim).tolist())
        elif kind == "S":
            if rng.random() < 0.3:
                chain = chain.scale(float(rng.uniform(0.2, 2.0)))
            else:
                chain = chain.scale(*rng.uniform(0.2, 2.0, dim).tolist())
        elif kind == "R":
            theta = float(rng.uniform(-np.pi, np.pi))
            chain = chain.rotate(theta) if dim == 2 else \
                chain.rotate(theta, axis=int(rng.integers(3)))
        elif kind == "A":
            chain = chain.affine(rng.uniform(0.2, 2.0, dim).tolist(),
                                 rng.uniform(-2, 2, dim).tolist())
        else:
            m = np.eye(dim + 1, dtype=np.float32)
            m[:dim, :dim] += rng.uniform(-0.4, 0.4, (dim, dim))
            m[dim, :dim] = rng.uniform(-2, 2, dim)
            chain = chain.matrix(m)
    return chain


# ---------------------------------------------------------------------------
# fused == sequential, random chains, all CPU backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("dim", [2, 3])
@pytest.mark.parametrize("length", [1, 2, 3, 5, 8])
def test_fused_chain_matches_sequential(backend, dim, length):
    rng = np.random.default_rng(100 * dim + length)
    for trial in range(3):
        chain = _random_chain(rng, dim, length)
        n = int(rng.integers(1, 300))       # ragged sizes incl. tiny
        pts = rng.standard_normal((n, dim)).astype(np.float32)
        got = chain.apply(jnp.asarray(pts), backend=backend)
        exp = _sequential_oracle(chain, pts)
        np.testing.assert_allclose(np.asarray(got), exp,
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_leading_batch_dims_and_apply_many(backend):
    rng = np.random.default_rng(3)
    chain = _random_chain(rng, 2, 4)
    pts = rng.standard_normal((5, 17, 2)).astype(np.float32)
    got = chain.apply_many(jnp.asarray(pts), backend=backend)
    assert got.shape == pts.shape
    exp = _sequential_oracle(chain, pts.reshape(-1, 2)).reshape(pts.shape)
    np.testing.assert_allclose(np.asarray(got), exp, rtol=2e-4, atol=2e-4)
    with pytest.raises(ValueError):
        chain.apply_many(jnp.asarray(pts[0]))   # ndim < 3


def test_empty_chain_is_identity():
    pts = jnp.asarray(RNG.standard_normal((9, 2)), jnp.float32)
    out = tc.TransformChain.identity(2).apply(pts)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(pts))


def test_bf16_chain_interpret_matches_ref():
    rng = np.random.default_rng(11)
    chain = _random_chain(rng, 2, 4)
    pts = jnp.asarray(rng.standard_normal((65, 2)), jnp.bfloat16)
    got_i = chain.apply(pts, backend="interpret")
    got_r = chain.apply(pts, backend="ref")
    np.testing.assert_allclose(np.float32(got_i), np.float32(got_r),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# algebraic folding
# ---------------------------------------------------------------------------

def test_adjacent_translates_sum_and_scales_multiply():
    chain = (tc.TransformChain.identity(2)
             .translate(1.0, 2.0).translate(3.0, -1.0)
             .scale(2.0).scale(0.5, 4.0))
    assert chain.is_diagonal
    a, t = chain.folded()
    np.testing.assert_allclose(np.asarray(a), np.diag([1.0, 8.0]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(t), [4.0 * 1.0, 1.0 * 8.0],
                               atol=1e-6)


def test_scale_translate_fuses_to_one_affine_pass():
    """A diagonal chain folds to one (s, t) pair == one fused affine."""
    chain = (tc.TransformChain.identity(2)
             .scale(2.0, 0.5).translate(1.0, -1.0).scale(3.0))
    pts = jnp.asarray(RNG.standard_normal((40, 2)), jnp.float32)
    exp = te.affine(te.translate(te.scale(pts, jnp.asarray([2.0, 0.5])),
                                 jnp.asarray([1.0, -1.0])),
                    jnp.asarray([3.0, 3.0]), jnp.zeros((2,)))
    np.testing.assert_allclose(np.asarray(chain.apply(pts)), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_diagonal_structure_never_builds_matrix_plan():
    diag = tc.TransformChain.identity(3).translate(1, 2, 3).scale(0.5)
    mixed = diag.rotate(0.1, axis="z")
    assert diag.is_diagonal and not mixed.is_diagonal
    assert diag._plan("ref").kind == "diag"
    assert mixed._plan("ref").kind == "matrix"


def test_homogeneous_matrix_roundtrip():
    chain = (tc.TransformChain.identity(2)
             .scale(2.0, 0.5).rotate(0.3).translate(1.0, -2.0))
    h = np.asarray(chain.as_homogeneous())
    pts = RNG.standard_normal((21, 2)).astype(np.float32)
    homo = np.concatenate([pts, np.ones((21, 1), np.float32)], axis=1)
    exp = (homo @ h)[:, :2]
    np.testing.assert_allclose(np.asarray(chain.apply(jnp.asarray(pts))),
                               exp, rtol=1e-4, atol=1e-4)
    rebuilt = tc.TransformChain.identity(2).matrix(jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(rebuilt.apply(jnp.asarray(pts))),
                               exp, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# plan cache: no re-fold, no retrace on repeated apply
# ---------------------------------------------------------------------------

def test_plan_cache_hit_no_retrace():
    tc.clear_plan_cache()
    tc.reset_stats()
    pts = jnp.asarray(RNG.standard_normal((50, 2)), jnp.float32)

    chain = (tc.TransformChain.identity(2)
             .scale(1.5, 0.5).rotate(0.2).translate(1.0, 1.0))
    chain.apply(pts, backend="ref")
    assert tc.stats["compiles"] == 1 and tc.stats["traces"] == 1

    # same structure, same shape, *different parameter values*: cache hit,
    # no new plan, no retrace -- the serving hot path.
    chain2 = (tc.TransformChain.identity(2)
              .scale(0.7, 2.0).rotate(-1.1).translate(-3.0, 0.5))
    out2 = chain2.apply(pts, backend="ref")
    assert tc.stats["compiles"] == 1, "same structure must not recompile"
    assert tc.stats["hits"] == 1
    assert tc.stats["traces"] == 1, "same structure+shape must not retrace"
    np.testing.assert_allclose(np.asarray(out2),
                               _sequential_oracle(chain2, np.asarray(pts)),
                               rtol=1e-4, atol=1e-4)

    # new shape with a cached plan: jax retraces once, still no recompile
    chain.apply(jnp.asarray(RNG.standard_normal((7, 2)), jnp.float32),
                backend="ref")
    assert tc.stats["compiles"] == 1 and tc.stats["traces"] == 2

    # different structure: a genuinely new plan
    chain.rotate(0.1).apply(pts, backend="ref")
    assert tc.stats["compiles"] == 2


def test_apply_differentiable_through_traced_params():
    """grad/jit over chain *parameters* (pose optimisation) must work: the
    host fold only serves concrete parameters; traced ones fold in jnp
    inside the caller's trace."""
    import jax

    pts = jnp.asarray(RNG.standard_normal((12, 2)), jnp.float32)

    def loss(theta):
        chain = (tc.TransformChain.identity(2)
                 .rotate(theta).translate(1.0, 2.0))
        return chain.apply(pts).sum()

    g = jax.grad(loss)(0.3)
    # d/dtheta sum(p @ R(theta) + t) has a closed form via R'(theta)
    c, s = np.cos(0.3), np.sin(0.3)
    dr = np.array([[-s, c], [-c, -s]], np.float32)
    expect = (np.asarray(pts) @ dr).sum()
    np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-4, atol=1e-4)
    # jit over parameters traces the jnp fold path, same values
    out = jax.jit(lambda th: (tc.TransformChain.identity(2)
                              .rotate(th).translate(1.0, 2.0)).apply(pts))(0.3)
    eager = (tc.TransformChain.identity(2)
             .rotate(0.3).translate(1.0, 2.0)).apply(pts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(eager),
                               rtol=1e-5, atol=1e-5)


def test_builder_is_lazy_until_apply():
    """then_* / builder calls must do no kernel dispatch (satellite: the old
    Transform2D ran an eager ref matmul per builder call)."""
    with opcount.counting() as records:
        chain = (tc.TransformChain.identity(2)
                 .translate(1.0, 2.0).scale(2.0, 0.5).rotate(0.4)
                 .translate(-1.0, 0.0))
        tf = (te.Transform2D.identity()
              .then_scale(2.0, 0.5).then_rotate(0.3).then_translate(1.0, 2.0))
    assert records == [], f"builders dispatched kernels: {records}"
    assert len(chain) == 4 and len(tf.chain) == 3


# ---------------------------------------------------------------------------
# byte economy: fused moves strictly fewer bytes than sequential
# ---------------------------------------------------------------------------

def test_fused_chain_moves_strictly_fewer_bytes():
    n = 4096
    pts = jnp.asarray(RNG.standard_normal((n, 2)), jnp.float32)
    sv = jnp.asarray([1.3, 0.8], jnp.float32)
    t1 = jnp.asarray([3.0, 2.0], jnp.float32)
    t2 = jnp.asarray([-1.0, 5.0], jnp.float32)

    with opcount.counting() as seq:
        te.translate(te.rotate(te.scale(te.translate(pts, t2), sv), 0.3), t1)
    assert len(seq) == 4                       # one HBM pass per primitive
    seq_bytes = opcount.total_bytes(seq)

    chain = (tc.TransformChain.identity(2)
             .translate(-1.0, 5.0).scale(1.3, 0.8).rotate(0.3)
             .translate(3.0, 2.0))
    with opcount.counting() as fused:
        chain.apply(pts, backend="ref")
    assert len(fused) == 1                     # the whole chain: one pass
    fused_bytes = opcount.total_bytes(fused)

    # fused = 2*N*d*4 + O(1); sequential ~ 2*k*N*d*4 -- strictly fewer,
    # and by at least (k-1) full read+write passes.
    assert fused_bytes < seq_bytes
    assert seq_bytes - fused_bytes >= 3 * pts.nbytes


# ---------------------------------------------------------------------------
# Transform2D / Transform3D wrappers keep the public API working
# ---------------------------------------------------------------------------

def test_transform2d_api_unchanged_through_new_ir():
    pts = jnp.asarray(RNG.standard_normal((30, 2)), jnp.float32)
    tf = (te.Transform2D.identity()
          .then_scale(2.0, 0.5).then_rotate(0.3).then_translate(1.0, -2.0))
    via_ir = tf.apply(pts)
    via_seq = te.translate(
        te.rotate(te.scale(pts, jnp.asarray([2.0, 0.5])), 0.3),
        jnp.asarray([1.0, -2.0]))
    np.testing.assert_allclose(np.asarray(via_ir), np.asarray(via_seq),
                               rtol=1e-3, atol=1e-3)
    m = np.asarray(tf.matrix)                  # still a (3, 3) homogeneous
    assert m.shape == (3, 3) and np.allclose(m[:, 2], [0, 0, 1], atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(te.Transform2D.from_matrix(jnp.asarray(m)).apply(pts)),
        np.asarray(via_ir), rtol=1e-4, atol=1e-4)


def test_transform3d_composite_matches_oracle():
    pts = RNG.standard_normal((25, 3)).astype(np.float32)
    tf = (te.Transform3D.identity()
          .then_rotate(0.4, "x").then_scale(2.0, 1.0, 0.5)
          .then_rotate(-0.2, "z").then_translate(1.0, 2.0, 3.0))
    exp = _sequential_oracle(tf.chain, pts)
    np.testing.assert_allclose(np.asarray(tf.apply(jnp.asarray(pts))), exp,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(tf.apply(jnp.asarray(pts), backend="interpret")), exp,
        rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# kernel-level: the fused chain bodies vs their oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("n", [1, 7, 129, 1000])
def test_chain_kernels_interpret_match_ref(d, n):
    from repro import kernels
    rng = np.random.default_rng(d * 1000 + n)
    pts = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    s = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(kernels.chain_apply(pts, a, t, backend="interpret")),
        np.asarray(kernels.chain_apply(pts, a, t, backend="ref")),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(kernels.chain_diag(pts, s, t, backend="interpret")),
        np.asarray(kernels.chain_diag(pts, s, t, backend="ref")),
        rtol=1e-6, atol=1e-6)
