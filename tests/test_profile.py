"""Profiler + bench-trajectory tests: attribution exactness against the
engine's own counters, cost-model prediction parity, span-stream
round-trips, and the directional trend gate.

The load-bearing invariants:

  * the attribution tree's launch count equals ``serving.stats
    ["launches"]`` exactly (the tracer emits the launch instant inside
    ``_count_launch``, the ONE place the counter moves);
  * every launch's observed/predicted HBM byte ratio is exactly 1.0 --
    ``kernels.opcount`` and ``autotune.costmodel`` share the byte
    formula, so drift is an accounting bug, not noise;
  * ``tools/bench_trend.py`` exits 0 on the real committed trajectory
    and 1 on a synthetic worsened-counter fixture.
"""
import json
import os

import pytest

from repro import obs, serving
from repro.autotune import costmodel
from repro.kernels import opcount
from repro.obs import bench_history
from repro.obs.profile import Profile, profile_smoke_workload
from repro.serving import engine

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture()
def smoke():
    """One traced smoke workload from a clean counter origin."""
    engine.reset_stats()
    tracer, server = profile_smoke_workload()
    return tracer, server, Profile.from_tracer(tracer)


# ---------------------------------------------------------------------------
# cost-model prediction API
# ---------------------------------------------------------------------------

class TestPredictLaunch:
    def test_bytes_match_opcount_exactly(self):
        # the profiler's ratio==1.0 guarantee, checked at the source:
        # the prediction IS the opcount byte formula
        for kind in ("diag", "matrix", "projective"):
            for bsz, lpad, d in ((1, 8, 2), (4, 16, 2), (3, 32, 3)):
                p = costmodel.predict_launch(kind, bsz, lpad, d)
                assert p.hbm_bytes == opcount.packed_chain_bytes(
                    bsz, lpad, d, itemsize=4, kind=kind)

    def test_q_lane_bytes_and_kernel(self):
        p = costmodel.predict_launch("diag", 4, 16, 2, qformat="q8.7",
                                     itemsize=2)
        assert p.kernel == "chain_diag_batch_q"
        assert p.hbm_bytes == opcount.packed_chain_bytes(
            4, 16, 2, itemsize=2, kind="diag")
        assert p.hbm_bytes == 544    # pinned: int16 halves the float lane

    def test_pinned_prediction(self):
        p = costmodel.predict_launch("matrix", 3, 32, 3)
        assert (p.kernel, p.hbm_bytes, p.flops, p.m1_cycles) == \
            ("chain_apply_batch", 2448, 2880, 506)

    def test_m1_cycles_monotone_in_shape(self):
        for kind in ("diag", "matrix", "projective"):
            c8 = costmodel.m1_chain_cycles(kind, 8, 2)
            c64 = costmodel.m1_chain_cycles(kind, 64, 2)
            assert 0 < c8 < c64
        # pinned representative values for the three plan kinds
        assert costmodel.m1_chain_cycles("diag", 64, 2) == 166
        assert costmodel.m1_chain_cycles("matrix", 64, 2) == 198
        assert costmodel.m1_chain_cycles("projective", 64, 2) == 342
        with pytest.raises(ValueError):
            costmodel.m1_chain_cycles("nope", 8, 2)


# ---------------------------------------------------------------------------
# attribution exactness
# ---------------------------------------------------------------------------

class TestProfileAttribution:
    def test_launch_counts_match_engine_counters(self, smoke):
        tracer, _server, prof = smoke
        assert prof.launches == serving.stats["launches"] > 0
        assert prof.launches == tracer.count("launch")
        # every aggregation axis accounts for every launch
        assert sum(g.launches for g in prof.buckets.values()) == \
            prof.launches
        assert sum(g.launches for g in prof.kinds.values()) == \
            prof.launches

    def test_per_bucket_attribution_is_exact(self, smoke):
        tracer, _server, prof = smoke
        # the bucket table reproduces the per-track launch-instant
        # distribution of the raw stream, bucket by bucket
        by_track = {}
        for s in tracer.spans:
            if s.instant and s.name == "launch":
                by_track[s.track] = by_track.get(s.track, 0) + 1
        assert {k: g.launches for k, g in prof.buckets.items()} == by_track
        assert len(prof.buckets) > 1    # mixed lanes: several buckets

    def test_tree_self_time_sums_to_total(self, smoke):
        _tracer, _server, prof = smoke
        # self times partition each root span's extent: summing self_s
        # over the whole tree recovers the total root extents
        total_roots = sum(n.total_s for n in prof.root.children.values())
        total_self = sum(n.self_s for _d, n in prof.root.walk()
                         if n is not prof.root)
        assert total_self == pytest.approx(total_roots, rel=1e-9)

    def test_byte_ratio_exact(self, smoke):
        _tracer, _server, prof = smoke
        assert prof.byte_ratio_exact
        assert len(prof.byte_ratios) == prof.launches
        c = prof.counters()
        assert c["byte_ratio_exact"] == 1
        assert c["hbm_bytes"] == c["pred_hbm_bytes"] > 0
        assert c["pred_flops"] > 0 and c["pred_m1_cycles"] > 0

    def test_deterministic_across_runs(self, smoke):
        _tracer, _server, prof = smoke
        engine.reset_stats()
        tracer2, _ = profile_smoke_workload()
        assert Profile.from_tracer(tracer2).counters() == prof.counters()

    def test_markdown_report_shape(self, smoke):
        _tracer, _server, prof = smoke
        md = prof.render_markdown()
        assert "## Attribution tree" in md
        assert "## Launches by kernel" in md
        assert "## Model error" in md
        assert "exact (every ratio == 1.0): True" in md


# ---------------------------------------------------------------------------
# span-stream persistence
# ---------------------------------------------------------------------------

class TestSpanStreamRoundTrip:
    def test_dump_load_preserves_counters(self, smoke, tmp_path):
        tracer, _server, prof = smoke
        path = str(tmp_path / "spans.jsonl")
        n = obs.dump_span_stream(tracer, path)
        spans = obs.load_span_stream(path)
        assert len(spans) == n == len(tracer.spans)
        assert Profile.from_spans(spans).counters() == prof.counters()

    def test_dump_is_byte_deterministic(self, smoke, tmp_path):
        tracer, _server, _prof = smoke
        p1, p2 = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
        obs.dump_span_stream(tracer, str(p1))
        obs.dump_span_stream(tracer, str(p2))
        assert p1.read_bytes() == p2.read_bytes()


# ---------------------------------------------------------------------------
# bench trajectory analytics
# ---------------------------------------------------------------------------

def _record(tmp_path, stamp, rows):
    doc = {"timestamp": stamp, "smoke": True,
           "rows": [dict(r, name=name) for name, r in rows.items()]}
    path = tmp_path / f"BENCH_{stamp}.json"
    path.write_text(json.dumps(doc))
    return str(path)


class TestBenchHistory:
    def test_real_committed_trajectory_is_clean(self):
        history = bench_history.load_history(
            os.path.join(REPO_ROOT, "benchmarks"))
        assert len(history) >= 2
        assert bench_history.find_regressions(history) == []

    def test_synthetic_regression_detected(self, tmp_path):
        _record(tmp_path, "20260101_000000",
                {"chain_smoke": {"launches": 10, "lost": 0,
                                 "us_per_call": 5.0}})
        _record(tmp_path, "20260102_000000",
                {"chain_smoke": {"launches": 12, "lost": 0,
                                 "us_per_call": 4.0}})
        history = bench_history.load_history(str(tmp_path))
        regs = bench_history.find_regressions(history)
        assert len(regs) == 1
        r = regs[0]
        assert (r.row, r.field, r.prev, r.value) == \
            ("chain_smoke", "launches", 10, 12)
        assert "worsened" in str(r)

    def test_improvement_and_new_rows_are_not_regressions(self, tmp_path):
        _record(tmp_path, "20260101_000000",
                {"a": {"launches": 10}})
        _record(tmp_path, "20260102_000000",
                {"a": {"launches": 8}, "b": {"launches": 99}})
        history = bench_history.load_history(str(tmp_path))
        assert bench_history.find_regressions(history) == []

    def test_wallclock_fields_never_gate(self, tmp_path):
        _record(tmp_path, "20260101_000000",
                {"a": {"us_per_call": 1.0, "wall_s": 1.0}})
        _record(tmp_path, "20260102_000000",
                {"a": {"us_per_call": 9.0, "wall_s": 9.0}})
        history = bench_history.load_history(str(tmp_path))
        assert bench_history.find_regressions(history) == []

    def test_series_and_drift_report(self, tmp_path):
        _record(tmp_path, "20260101_000000", {"a": {"launches": 10}})
        _record(tmp_path, "20260102_000000", {"a": {"launches": 8}})
        history = bench_history.load_history(str(tmp_path))
        assert bench_history.series(history, "a", "launches") == [
            ("BENCH_20260101_000000.json", 10),
            ("BENCH_20260102_000000.json", 8)]
        report = bench_history.drift_report(history)
        assert "| a | launches | 10 | 8 | IMPROVED |" in report


class TestBenchTrendCLI:
    def _main(self):
        import importlib.util
        spec = importlib.util.spec_from_file_location(
            "bench_trend", os.path.join(REPO_ROOT, "tools",
                                        "bench_trend.py"))
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod.main

    def test_exit_codes(self, tmp_path, capsys):
        main = self._main()
        # fewer than two records: nothing to compare
        assert main(["--bench-dir", str(tmp_path)]) == 2
        _record(tmp_path, "20260101_000000", {"a": {"launches": 10}})
        _record(tmp_path, "20260102_000000", {"a": {"launches": 12}})
        assert main(["--bench-dir", str(tmp_path)]) == 1
        assert "REGRESSION" in capsys.readouterr().err
        # real committed trajectory stays clean
        assert main(["--bench-dir",
                     os.path.join(REPO_ROOT, "benchmarks")]) == 0

    def test_report_written(self, tmp_path):
        main = self._main()
        _record(tmp_path, "20260101_000000", {"a": {"launches": 10}})
        _record(tmp_path, "20260102_000000", {"a": {"launches": 10}})
        out = tmp_path / "drift.md"
        assert main(["--bench-dir", str(tmp_path),
                     "--report", str(out)]) == 0
        assert "# Bench trajectory" in out.read_text()
