"""Faithful-reproduction tests: M1 emulator + Intel cycle models vs paper."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import transform_chain as tc
from repro.core.morphosys import intel, programs, rc_array


class TestContextWords:
    def test_published_add_word(self):
        # Table 1: Out = A + B  ->  0x0000F400
        assert rc_array.encode_context(rc_array.OP_ADD_AB) == 0xF400
        assert rc_array.decode_context(0x0000F400) == (rc_array.OP_ADD_AB, 0)

    def test_published_cmul_word(self):
        # Table 2: Out = 5 x A  ->  0x00009005
        assert rc_array.encode_context(rc_array.OP_CMUL, 5) == 0x9005
        assert rc_array.decode_context(0x00009005) == (rc_array.OP_CMUL, 5)

    def test_negative_immediate_roundtrip(self):
        word = rc_array.encode_context(rc_array.OP_CMAC, -4)
        assert rc_array.decode_context(word) == (rc_array.OP_CMAC, -4)


class TestCycleCounts:
    """Table 5 published cycle counts for routines with published listings."""

    @pytest.mark.parametrize("n,expected", [(8, 21), (64, 96)])
    def test_translation_cycles(self, n, expected):
        r = programs.run_translation(np.arange(n), np.arange(n))
        assert r.cycles == expected

    @pytest.mark.parametrize("n,expected", [(8, 14), (64, 55)])
    def test_scaling_cycles(self, n, expected):
        r = programs.run_scaling(np.arange(n), 5)
        assert r.cycles == expected

    def test_table1_structure(self):
        # Table 1 occupies instruction addresses 0..96 -> 97 instructions
        assert len(programs.translation_program(64)) == 97

    def test_table2_structure(self):
        # Table 2 occupies 0..55 -> 56 instructions
        assert len(programs.scaling_program(64)) == 56

    def test_matmul_reconstruction_cycles(self):
        """Paper reports 256 cycles but prints no listing; our overlapped
        reconstruction is 90 cycles (documented delta)."""
        a = np.ones((8, 8), np.int16)
        b = np.ones((8, 8), np.int16)
        assert programs.run_matmul(a, b).cycles == 90

    def test_composite_ii_reconstruction_cycles(self):
        pts = np.ones((2, 8), np.int16)
        assert programs.run_rotation_points((1, 1), pts).cycles == 25


class TestFunctionalCorrectness:
    def test_translation_values(self):
        rng = np.random.default_rng(0)
        for n in (8, 64):
            u = rng.integers(-30000, 30000, n)
            v = rng.integers(-30000, 30000, n)
            r = programs.run_translation(u, v)
            np.testing.assert_array_equal(
                r.values, programs.oracle_translation(u, v))

    def test_translation_wraps_int16(self):
        u = np.array([32767] * 8, np.int16)
        v = np.array([1] * 8, np.int16)
        r = programs.run_translation(u, v)
        assert (np.asarray(r.values) == -32768).all()

    def test_scaling_values(self):
        rng = np.random.default_rng(1)
        for n in (8, 64):
            u = rng.integers(-5000, 5000, n)
            r = programs.run_scaling(u, 5)
            np.testing.assert_array_equal(
                r.values, programs.oracle_scaling(u, 5))

    def test_matmul_values(self):
        rng = np.random.default_rng(2)
        for _ in range(5):
            a = rng.integers(-100, 100, (8, 8))
            b = rng.integers(-1000, 1000, (8, 8))
            r = programs.run_matmul(a, b)
            np.testing.assert_array_equal(r.values, programs.oracle_matmul(a, b))

    def test_rotation_points(self):
        rng = np.random.default_rng(3)
        pts = rng.integers(-100, 100, (2, 8))
        r = programs.run_rotation_points((3, 4), pts)
        rot = np.array([[3, -4], [4, 3]])
        np.testing.assert_array_equal(r.values, programs.oracle_matmul(rot, pts))

    @pytest.mark.parametrize("theta", [0.35, -1.1, 2.4])
    def test_rotation_points_match_chain_compiler_q7(self, theta):
        """Paper-fidelity cross-check: the M1 fixed-point rotation (Q7
        cos/sin, |coef| < 128 for the 8-bit context immediate) agrees
        with the chain compiler's rotation fold within quantization
        tolerance -- the emulator and the Pallas path compute the same
        transformation.

        Conventions line up exactly: the emulator's [[c,-s],[s,c]] @
        column-points equals the compiler's row-points @ [[c,s],[-s,c]].
        Integer products are exact in int16 (|x|,|y| < 91, |coef| < 128
        -> |sum| < 2*91*127 < 32767), so the ONLY error source is
        rounding cos/sin to Q7, bounded by 0.5*(|x|+|y|)/127 per
        coordinate."""
        scale = 127
        c = int(np.round(np.cos(theta) * scale))
        s = int(np.round(np.sin(theta) * scale))
        rng = np.random.default_rng(int(abs(theta) * 100))
        pts = rng.integers(-90, 91, (2, 8)).astype(np.int16)

        emu = programs.run_rotation_points((c, s), pts).values / scale

        chain = tc.TransformChain.identity(2).rotate(theta)
        ref = np.asarray(chain.apply(
            jnp.asarray(pts.T.astype(np.float32)), backend="ref")).T

        tol = 0.5 * np.abs(pts).sum(axis=0).max() / scale + 1e-3
        np.testing.assert_allclose(emu, ref, atol=tol)
        # and the interpret-mode Pallas kernel ties all three together
        pal = np.asarray(chain.apply(
            jnp.asarray(pts.T.astype(np.float32)), backend="interpret")).T
        np.testing.assert_allclose(emu, pal, atol=tol)


class TestIntelModels:
    """Tables 3-4 per-instruction clocks; n=64 translation totals are the
    paper's documented arithmetic slips."""

    @pytest.mark.parametrize("cpu,n,published,matches", [
        ("80486", 8, 90, True), ("80386", 8, 220, True),
        ("80486", 64, 769, False), ("80386", 64, 1723, False),
    ])
    def test_translation_model(self, cpu, n, published, matches):
        model = intel.translation_cycles(cpu, n)
        if matches:
            assert model == published
        else:  # slip: within 9% of published, per-instruction math exact
            assert abs(model - published) / published < 0.09

    @pytest.mark.parametrize("cpu,n,published", [
        ("80486", 8, 74), ("80386", 8, 172),
        ("80486", 64, 578), ("80386", 64, 1348),
    ])
    def test_scaling_model_exact(self, cpu, n, published):
        assert intel.scaling_cycles(cpu, n) == published

    def test_published_speedups(self):
        """Table 5 speedups = cycle ratios of its own published numbers."""
        for row in intel.PAPER_TABLE5:
            if row.speedup is None:
                continue
            m1 = intel.paper_row(row.algorithm, "m1", row.n_elements).cycles
            assert row.cycles / m1 == pytest.approx(row.speedup, rel=0.02)
