"""Per-arch smoke tests (reduced configs) + decode/train consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build
from repro.models.config import ModelConfig


def _batch(cfg: ModelConfig, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    elif cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.list_archs())
class TestArchSmoke:
    """One forward/train step per assigned arch on its reduced config."""

    def test_train_step_shapes_and_finite(self, arch):
        cfg = configs.get(arch).reduced()
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg)
        logits, aux = model.forward(params, batch)
        assert logits.shape == (2, 32, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        loss, metrics = model.loss(params, batch)
        assert bool(jnp.isfinite(loss))
        grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))

    def test_serve_path(self, arch):
        cfg = configs.get(arch).reduced()
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg)
        if cfg.is_encdec:
            batch = dict(batch, tokens=batch["tokens"][:, :1])
        cache = model.init_cache(2, 48, enc_len=32 if cfg.is_encdec else 0)
        logits, cache = model.prefill(params, batch, cache)
        assert logits.shape == (2, cfg.vocab_size)
        pos = 1 if cfg.is_encdec else 32
        logits2, cache = model.decode(
            params, jnp.zeros((2,), jnp.int32), pos, cache)
        assert logits2.shape == (2, cfg.vocab_size)
        assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["yi-6b", "h2o-danube-1.8b", "hymba-1.5b",
                                  "mamba2-130m", "granite-moe-3b-a800m",
                                  "whisper-medium", "internvl2-76b"])
def test_decode_matches_forward(arch):
    """Prefill+decode logits == full-sequence forward logits (per arch
    family; catches cache/mask/rope/state bugs)."""
    cfg = configs.get(arch).reduced()
    cfg = dataclasses.replace(cfg, remat="none",
                              capacity_factor=8.0)   # no MoE drops
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 24
    batch = _batch(cfg, b, s, seed=1)
    logits_full, _ = model.forward(params, batch)
    sp = s - 4
    pre = dict(batch, tokens=batch["tokens"][:, :sp])
    if cfg.is_encdec:
        pre["tokens"] = batch["tokens"][:, :1]
    cache = model.init_cache(b, s, enc_len=s if cfg.is_encdec else 0)
    lg, cache = model.prefill(params, pre, cache)
    if cfg.is_encdec:
        errs = []
        for i in range(1, 6):
            lg, cache = model.decode(params, batch["tokens"][:, i], i, cache)
            errs.append(float(jnp.abs(lg - logits_full[:, i]).max()))
    else:
        errs = [float(jnp.abs(lg - logits_full[:, sp - 1]).max())]
        for i in range(sp, s):
            lg, cache = model.decode(params, batch["tokens"][:, i], i, cache)
            errs.append(float(jnp.abs(lg - logits_full[:, i]).max()))
    assert max(errs) < 2e-4, errs


def test_param_counts_match_published_sizes():
    expected = {
        "internvl2-76b": (70e9, 76e9),     # LM backbone of the 76B VLM
        "granite-moe-3b-a800m": (3.0e9, 3.6e9),
        "dbrx-132b": (125e9, 136e9),
        "phi3-mini-3.8b": (3.5e9, 4.0e9),
        "deepseek-67b": (64e9, 70e9),
        "yi-6b": (5.7e9, 6.4e9),
        "h2o-danube-1.8b": (1.6e9, 2.0e9),
        "hymba-1.5b": (1.3e9, 1.7e9),
        "whisper-medium": (0.7e9, 1.1e9),  # SwiGLU FFN vs paper's GELU
        "mamba2-130m": (0.11e9, 0.15e9),
    }
    for arch, (lo, hi) in expected.items():
        n = configs.get(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_below_total():
    cfg = configs.get("granite-moe-3b-a800m")
    assert cfg.active_param_count() < 0.4 * cfg.param_count()


def test_ssd_chunked_equals_recurrent():
    """Mamba-2 SSD chunked scan == naive per-token recurrence."""
    from repro.models import ssm
    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=64,
                      ssm_state=8, ssm_headdim=8, ssm_chunk=4,
                      dtype="float32")
    p = ssm.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 13, 32)) * 0.5
    y_chunked, cache = ssm.forward(p, x, cfg, return_state=True)
    c = ssm.init_cache(cfg, 2)
    ys = []
    for t in range(13):
        yt, c = ssm.decode_step(p, x[:, t:t + 1], cfg, c)
        ys.append(yt)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_chunked, y_rec, atol=1e-4)
    np.testing.assert_allclose(cache["state"], c["state"], atol=1e-4)


def test_moe_matches_per_token_oracle():
    from repro.models import moe
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      n_experts=4, experts_per_token=2, capacity_factor=8.0,
                      dtype="float32")
    p = moe.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = moe.moe_ffn(p, x, cfg)
    xt = x.reshape(-1, 32)
    logits = xt @ p["router"]
    gv, ei = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
    gv = gv / gv.sum(-1, keepdims=True)
    exp = []
    for ti in range(32):
        acc = 0
        for j in range(2):
            e = int(ei[ti, j])
            h = jax.nn.silu(xt[ti] @ p["w_gate"][e]) * (xt[ti] @ p["w_up"][e])
            acc = acc + float(gv[ti, j]) * (h @ p["w_down"][e])
        exp.append(acc)
    np.testing.assert_allclose(y.reshape(-1, 32), jnp.stack(exp), atol=1e-5)
    assert float(aux) > 0


def test_swa_ring_buffer_evicts_old_positions():
    """Ring cache holds only the window; attention ignores evicted slots."""
    cfg = configs.get("h2o-danube-1.8b").reduced()   # window 32
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 48   # prompt longer than window
    batch = _batch(cfg, b, s, seed=3)
    logits_full, _ = model.forward(params, batch)
    cache = model.init_cache(b, 64)
    assert cache["kpos"].shape[-1] == cfg.window
    lg, cache = model.prefill(params, batch, cache)
    np.testing.assert_allclose(lg, logits_full[:, -1], atol=2e-4)


def test_int8_kv_cache_close_to_bf16():
    """Beyond-paper serving option: int8 KV quantization halves the cache;
    decode logits stay close to the bf16-cache path."""
    import dataclasses as dc
    cfg = configs.get("yi-6b").reduced()
    cfg = dc.replace(cfg, remat="none")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 24
    batch = _batch(cfg, b, s, seed=7)
    logits_full, _ = model.forward(params, batch)

    cfg8 = dc.replace(cfg, kv_cache_dtype="int8")
    model8 = build(cfg8)
    cache = model8.init_cache(b, s + 4)
    assert cache["k"].dtype == jnp.int8 if not isinstance(cache["k"], dict) \
        else True
    lg, cache = model8.prefill(params, batch, cache)
    np.testing.assert_allclose(np.float32(lg), np.float32(logits_full[:, -1]),
                               atol=0.15)
    lg2, cache = model8.decode(params, batch["tokens"][:, -1], s, cache)
    assert bool(jnp.isfinite(lg2).all())
