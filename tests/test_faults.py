"""Fault-model tests: the typed request-error taxonomy, submit/apply
boundary validation, the engine's retry / backend-degradation /
bisection recovery ladder under seeded fault injection, the q-lane
wrap-prediction policies, and the chaos soak's zero-lost invariant.
"""
import numpy as np
import pytest

from repro import errors, quantize, serving
from repro.core import transform_chain as tc
from repro.kernels import dispatch
from repro.serving import engine, faults, workload

RNG = np.random.default_rng(60)


def _fresh(**kw):
    serving.reset_stats()
    serving.clear_plan_cache()
    return serving.GeometryServer(**kw)


def _chain2():
    return tc.TransformChain.identity(2).translate(0.5, -0.25).scale(1.5, 0.5)


def _pts(n=8, dim=2):
    return RNG.uniform(-1, 1, (n, dim)).astype(np.float32)


# ---------------------------------------------------------------------------
# taxonomy
# ---------------------------------------------------------------------------

class TestTaxonomy:
    def test_codes_and_subclassing(self):
        # every member is a ValueError (legacy except-sites keep catching)
        for cls, code in [(errors.ShapeError, "shape"),
                          (errors.DtypeError, "dtype"),
                          (errors.EmptyPointsError, "empty"),
                          (errors.NonFiniteError, "nonfinite"),
                          (errors.QRangeError, "q-range"),
                          (errors.LaunchError, "launch")]:
            assert issubclass(cls, errors.RequestError)
            assert issubclass(cls, ValueError)
            assert cls.code == code
        # dtype misuse historically raised TypeError; both must keep working
        assert issubclass(errors.DtypeError, TypeError)

    def test_ticket_prefix_and_with_ticket(self):
        e = errors.ShapeError("bad", ticket=42)
        assert e.ticket == 42 and "[request 42]" in str(e)
        anon = errors.NonFiniteError("nan")
        assert anon.ticket is None and "[request" not in str(anon)
        named = anon.with_ticket(7)
        assert type(named) is errors.NonFiniteError and named.ticket == 7

    def test_fault_config_validates(self):
        with pytest.raises(ValueError):
            engine.FaultConfig(on_q_overflow="explode")
        with pytest.raises(ValueError):
            engine.FaultConfig(max_launch_attempts=0)


# ---------------------------------------------------------------------------
# boundary validation: TransformChain.apply
# ---------------------------------------------------------------------------

class TestApplyBoundary:
    def test_apply_rejects_empty_and_shape_and_float64(self):
        chain = _chain2()
        with pytest.raises(errors.EmptyPointsError):
            chain.apply(np.zeros((0, 2), np.float32))
        with pytest.raises(errors.ShapeError):
            chain.apply(np.zeros((4, 3), np.float32))
        with pytest.raises(errors.DtypeError):
            chain.apply(np.zeros((4, 2), np.float64))

    def test_apply_shape_error_is_still_a_valueerror(self):
        with pytest.raises(ValueError):
            _chain2().apply(np.zeros((4, 3), np.float32))


# ---------------------------------------------------------------------------
# boundary validation: GeometryServer.submit
# ---------------------------------------------------------------------------

class TestSubmitBoundary:
    def test_typed_rejections_carry_the_ticket(self):
        srv = _fresh(backend="ref")
        srv.submit(_chain2(), _pts())            # ticket 0
        cases = [
            (np.zeros((0, 2), np.float32), errors.EmptyPointsError),
            (np.zeros((3, 3), np.float32), errors.ShapeError),
            (np.zeros((3, 2), np.float64), errors.DtypeError),
            (np.float32(1.0), errors.ShapeError),          # bare scalar
            (np.full((3, 2), np.inf, np.float32), errors.NonFiniteError),
        ]
        for i, (bad, exc) in enumerate(cases):
            with pytest.raises(exc) as ei:
                srv.submit(_chain2(), bad)
            # rejected submissions burn their ticket id -- never reused
            assert ei.value.ticket == 1 + i
        assert serving.stats["rejected_requests"] == len(cases)
        # the queue survived every rejection
        (out,) = srv.flush()
        assert out.shape == (8, 2)

    def test_float_lane_is_strict_float32(self):
        srv = _fresh(backend="ref")
        with pytest.raises(errors.DtypeError):
            srv.submit(_chain2(), np.zeros((4, 2), np.float16))
        with pytest.raises(errors.DtypeError):
            srv.submit(_chain2(), np.zeros((4, 2), np.int32))

    def test_nonfinite_fold_rejected_at_submit(self):
        srv = _fresh(backend="ref")
        chain = tc.TransformChain.identity(2).scale(np.inf, 1.0)
        with pytest.raises(errors.NonFiniteError) as ei:
            srv.submit(chain, _pts())
        assert "fold" in str(ei.value)

    def test_malform_modes_map_to_codes(self):
        srv = _fresh(backend="ref")
        for mode, code in faults.MALFORM_MODES:
            with pytest.raises(errors.RequestError) as ei:
                srv.submit(_chain2(), faults.malform(_pts(), mode))
            assert ei.value.code == code, mode


# ---------------------------------------------------------------------------
# q-lane wrap prediction (satellite: error_bound wired into submit)
# ---------------------------------------------------------------------------

class TestQOverflowPolicy:
    def test_wrap_boundary_is_pinned(self):
        """quantize.fits flips between a x100 and a x1000 scale for q8.7
        (range [-256, 256)) -- the exact predicate submit consults."""
        fmt = quantize.as_qformat("q8.7")
        ok = tc.TransformChain.identity(2).scale(100.0).fold()
        bad = tc.TransformChain.identity(2).scale(1000.0).fold()
        assert quantize.fits(ok, "diag", fmt, 1.0)
        assert not quantize.fits(bad, "diag", fmt, 1.0)
        with pytest.raises(errors.QRangeError):
            quantize.ensure_fits(bad, "diag", fmt, 1.0, ticket=5)

    def test_reject_policy_raises_qrange(self):
        srv = _fresh(backend="ref",
                     fault_config=engine.FaultConfig(on_q_overflow="reject"))
        chain = tc.TransformChain.identity(2).scale(1000.0)
        with pytest.raises(errors.QRangeError) as ei:
            srv.submit(chain, _pts(), qformat="q8.7")
        assert ei.value.ticket == 0
        assert serving.stats["rejected_requests"] == 1

    def test_fallback_policy_serves_through_float32(self):
        srv = _fresh(backend="ref")          # default policy: fallback
        chain = tc.TransformChain.identity(2).scale(1000.0)
        pts = _pts()
        srv.submit(chain, pts, qformat="q8.7")
        (out,) = srv.flush()
        assert out.dtype == np.float32
        np.testing.assert_allclose(out, np.asarray(chain.apply(pts)),
                                   rtol=1e-5, atol=1e-5)
        assert serving.stats["q_fallbacks"] == 1
        assert srv.last_report[0].q_fallback_requests == 1

    def test_fallback_requantises_for_int16_callers(self):
        """int16 in -> int16 out even when the lane degrades to float."""
        srv = _fresh(backend="ref")
        chain = tc.TransformChain.identity(2).scale(1000.0)
        fmt = quantize.as_qformat("q8.7")
        words = fmt.quantize(_pts())
        srv.submit(chain, words, qformat="q8.7")
        (out,) = srv.flush()
        assert out.dtype == np.int16

    def test_fitting_q_requests_stay_bitwise(self):
        """The wrap check must not perturb the in-range q lane: packed
        results stay bitwise equal to apply(dtype=...)."""
        srv = _fresh(backend="ref")
        chain = _chain2()
        pts = _pts(16)
        srv.submit(chain, pts, qformat="q8.7")
        (out,) = srv.flush()
        ref = chain.apply(pts, dtype="q8.7", backend="ref")
        np.testing.assert_array_equal(out, np.asarray(ref))
        assert serving.stats["q_fallbacks"] == 0

    def test_wrap_policy_preserves_legacy_semantics(self):
        srv = _fresh(backend="ref",
                     fault_config=engine.FaultConfig(on_q_overflow="wrap"))
        chain = tc.TransformChain.identity(2).scale(1000.0)
        pts = _pts()
        srv.submit(chain, pts, qformat="q8.7")
        (out,) = srv.flush()
        ref = chain.apply(pts, dtype="q8.7", backend="ref")  # wraps too
        np.testing.assert_array_equal(out, np.asarray(ref))
        assert serving.stats["q_fallbacks"] == 0


# ---------------------------------------------------------------------------
# recovery ladder under seeded injection
# ---------------------------------------------------------------------------

def _cfg(**kw):
    kw.setdefault("backoff_base_s", 0.0)     # tests need no real sleeps
    return engine.FaultConfig(**kw)


class TestRecovery:
    def test_flaky_launch_recovers_by_retry(self):
        inj = faults.FaultInjector(flaky_tickets=frozenset({0, 1}),
                                   flaky_attempts=2)
        srv = _fresh(backend="ref", fault_config=_cfg(), injector=inj)
        chain, pts = _chain2(), _pts()
        srv.submit(chain, pts)
        srv.submit(chain, _pts())
        out = srv.flush()
        np.testing.assert_allclose(out[0], np.asarray(chain.apply(pts)),
                                   rtol=1e-6, atol=1e-6)
        # attempt 0 (phase 1) + attempt 1 fail, attempt 2 succeeds
        assert serving.stats["launch_failures"] == 2
        assert serving.stats["retries"] == 2
        assert serving.stats["recovered_requests"] == 2
        assert serving.stats["failed_requests"] == 0
        assert srv.last_report[0].retries == 2

    def test_backend_fault_degrades_down_the_ladder(self):
        assert dispatch.fallback_ladder("interpret") == ("interpret", "ref")
        inj = faults.FaultInjector(backend_tickets=frozenset({0}))
        srv = _fresh(backend="interpret",
                     fault_config=_cfg(max_launch_attempts=2), injector=inj)
        chain, pts = _chain2(), _pts()
        srv.submit(chain, pts)
        (out,) = srv.flush()
        np.testing.assert_allclose(
            out, np.asarray(chain.apply(pts, backend="ref")),
            rtol=1e-6, atol=1e-6)
        assert serving.stats["backend_fallbacks"] == 1
        rep = srv.last_report[0]
        assert rep.backend == "interpret" and rep.final_backend == "ref"

    def test_corruption_detected_and_retried_pristine(self):
        inj = faults.FaultInjector(corrupt_tickets=frozenset({0}))
        srv = _fresh(backend="ref", fault_config=_cfg(), injector=inj)
        chain, pts = _chain2(), _pts()
        srv.submit(chain, pts)
        (out,) = srv.flush()
        # recovered output is finite and correct: the retry re-packed
        # from the pristine host copy, not the corrupted staging buffer
        assert np.isfinite(out).all()
        np.testing.assert_allclose(out, np.asarray(chain.apply(pts)),
                                   rtol=1e-6, atol=1e-6)
        assert inj.injected_corruptions == 1
        assert serving.stats["launch_failures"] == 1
        assert serving.stats["retries"] == 1
        assert serving.stats["recovered_requests"] == 1

    def test_poison_is_bisected_to_a_named_failure(self):
        """B=8 bucket with one poison request: 3 bisections isolate it,
        the 7 siblings all recover, the poison resolves to a LaunchError
        carrying its own ticket."""
        inj = faults.FaultInjector(poison_tickets=frozenset({3}))
        srv = _fresh(backend="ref",
                     fault_config=_cfg(max_launch_attempts=2), injector=inj)
        chain = _chain2()
        ptss = [_pts(8) for _ in range(8)]    # one bucket: same structure/L
        for p in ptss:
            srv.submit(chain, p)
        out = srv.flush()
        assert len(out) == 8
        for i in range(8):
            if i == 3:
                assert isinstance(out[i], errors.LaunchError)
                assert serving.is_error(out[i]) and out[i].ticket == 3
            else:
                np.testing.assert_allclose(
                    out[i], np.asarray(chain.apply(ptss[i])),
                    rtol=1e-6, atol=1e-6)
        assert serving.stats["bisections"] == 3   # 8 -> 4 -> 2 -> 1
        assert serving.stats["failed_requests"] == 1
        assert serving.stats["recovered_requests"] == 7
        rep = srv.last_report[0]
        assert rep.bisections == 3 and rep.failed_requests == 1

    def test_failed_bucket_never_touches_its_neighbours(self):
        """Bucket isolation: a poisoned bucket recovers/fails alone; the
        other bucket completes with exactly its one clean launch."""
        inj = faults.FaultInjector(poison_tickets=frozenset({0}))
        srv = _fresh(backend="ref",
                     fault_config=_cfg(max_launch_attempts=2), injector=inj)
        poisoned_chain, clean_chain = _chain2(), \
            tc.TransformChain.identity(3).translate(1.0, 2.0, 3.0)
        srv.submit(poisoned_chain, _pts())            # ticket 0: poison
        clean_pts = _pts(8, 3)
        srv.submit(clean_chain, clean_pts)            # different bucket
        out = srv.flush()
        assert isinstance(out[0], errors.LaunchError)
        np.testing.assert_allclose(
            out[1], np.asarray(clean_chain.apply(clean_pts)),
            rtol=1e-6, atol=1e-6)
        clean_rep = [r for r in srv.last_report
                     if r.structure.startswith("3D")][0]
        assert clean_rep.launches == 1 and clean_rep.failed_requests == 0

    def test_failed_shard_does_not_orphan_sibling_shards(self):
        """Satellite: oversized-bucket sharding under failure.  12 equal
        requests shard into 4 launches; a poison in one shard must not
        lose any other shard's results."""
        inj = faults.FaultInjector(poison_tickets=frozenset({4}))
        srv = _fresh(backend="ref",
                     fault_config=_cfg(max_launch_attempts=2), injector=inj,
                     max_points_per_launch=3 * 128)
        chain = _chain2()
        ptss = [_pts(100) for _ in range(12)]
        for p in ptss:
            srv.submit(chain, p)
        out = srv.flush()
        rep = srv.last_report[0]
        assert serving.stats["shards"] == 3   # 4 launches = 1 + 3 shards
        for i in range(12):
            if i == 4:
                assert isinstance(out[i], errors.LaunchError)
            else:
                np.testing.assert_allclose(
                    out[i], np.asarray(chain.apply(ptss[i])),
                    rtol=1e-6, atol=1e-6)
        # only the poisoned shard (3 requests) went through recovery
        assert serving.stats["recovered_requests"] == 2
        assert serving.stats["failed_requests"] == 1
        assert rep.failed_requests == 1

    def test_injected_fault_counts_as_launch_failure_not_launch(self):
        """An injector-blocked attempt never dispatched: stats['launches']
        counts only real dispatches, so clean-run launch counts are
        unchanged by the hooks existing."""
        inj = faults.FaultInjector(flaky_tickets=frozenset({0}),
                                   flaky_attempts=1)
        srv = _fresh(backend="ref", fault_config=_cfg(), injector=inj)
        srv.submit(_chain2(), _pts())
        srv.flush()
        # attempt 0 blocked (no dispatch), attempt 1 dispatched
        assert serving.stats["launches"] == 1
        assert serving.stats["launch_failures"] == 1
        assert sum(r.launches for r in srv.last_report) == \
            serving.stats["launches"]


# ---------------------------------------------------------------------------
# the chaos soak harness
# ---------------------------------------------------------------------------

class TestChaosSoak:
    def test_soak_zero_lost_and_deterministic(self):
        serving.reset_stats()
        serving.clear_plan_cache()
        a = faults.run_chaos_soak(seed=1, n_requests=32)
        b = faults.run_chaos_soak(seed=1, n_requests=32)
        assert a.lost == 0 and a.mismatches == 0
        assert a.counters() == b.counters()
        # the soak actually exercised the machinery it claims to gate
        assert a.rejected_at_submit == a.malformed > 0
        assert a.launch_failures > 0 and a.q_fallbacks == 1
        assert a.resolved + a.failed_requests == a.requests

    def test_soak_seeds_differ(self):
        serving.reset_stats()
        serving.clear_plan_cache()
        a = faults.run_chaos_soak(seed=1, n_requests=32)
        b = faults.run_chaos_soak(seed=2, n_requests=32)
        assert a.lost == b.lost == 0
        assert a.counters() != b.counters()

    def test_roles_are_pure_function_of_seed_and_ticket(self):
        i1 = faults.FaultInjector(seed=9, flaky_rate=0.2, backend_rate=0.2,
                                  corrupt_rate=0.2, poison_rate=0.2)
        i2 = faults.FaultInjector(seed=9, flaky_rate=0.2, backend_rate=0.2,
                                  corrupt_rate=0.2, poison_rate=0.2)
        roles = [i1.role(t) for t in range(200)]
        assert roles == [i2.role(t) for t in range(200)]
        assert len({r for r in roles if r}) == 4   # all roles drawn

    def test_mixed_lane_workload_shape(self):
        triples = workload.mixed_lane_workload(3, 40, q_fraction=0.5)
        assert len(triples) == 40
        q = [t for t in triples if t[2] is not None]
        assert 0 < len(q) < 40
        assert all(not c.is_projective for c, _, f in q)
