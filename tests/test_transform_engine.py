"""Transform-engine + analysis-layer unit tests (paper sections 4-6)."""
import jax.numpy as jnp
import numpy as np

from repro.core import analysis, transform_engine as te
from repro.core.morphosys import intel


def test_rotation_inverse():
    pts = jnp.asarray(np.random.default_rng(0).standard_normal((40, 2)),
                      jnp.float32)
    back = te.rotate(te.rotate(pts, 0.9), -0.9)
    np.testing.assert_allclose(back, pts, atol=1e-5)


def test_scale_then_inverse_scale():
    pts = jnp.asarray(np.random.default_rng(1).standard_normal((40, 2)),
                      jnp.float32)
    s = jnp.asarray([2.0, 4.0])
    np.testing.assert_allclose(te.scale(te.scale(pts, s), 1.0 / s), pts,
                               atol=1e-5)


def test_homogeneous_identity():
    pts = jnp.asarray(np.random.default_rng(2).standard_normal((10, 2)),
                      jnp.float32)
    np.testing.assert_allclose(te.Transform2D.identity().apply(pts), pts,
                               atol=1e-6)


def test_derive_matches_paper_columns():
    """analysis.derive reproduces Table 5's derived columns."""
    row = analysis.derive("translation", "m1", 64, 96)
    assert row.elements_per_cycle == round(64 / 96, 4)   # paper: 0.667
    assert row.total_time_us == 96 / intel.CLOCK_MHZ["m1"]  # paper: 0.96us
    r486 = analysis.derive("translation", "80486", 64, 769, ref_cycles=96)
    assert abs(r486.speedup_vs - 8.01) < 0.01            # paper speedup


def test_format_table_runs():
    rows = [analysis.derive("scaling", "m1", 64, 55)]
    out = analysis.format_table(rows)
    assert "scaling" in out and "55" in out
