"""Batched transform-serving engine tests: packed-batch equality against
per-request ``apply``, the size-bucketing waste cap, the one-compile-per-
structure (no-retrace) guarantee under load, oversized-bucket sharding,
and the packed-batch launch/byte accounting.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import serving
from repro.core import transform_chain as tc
from repro.kernels import opcount
from repro.serving import bucketing, workload


def _fresh_server(**kw):
    serving.reset_stats()
    serving.clear_plan_cache()
    return serving.GeometryServer(**kw)


def _serve_and_compare(backend, reqs, **server_kw):
    """Serve ``reqs`` packed and compare each result to per-request apply.

    The fold is bit-identical by construction (one shared host code path),
    so the only permitted daylight is the fused application's last-ULP
    freedom (XLA:CPU contracts float multiply-adds per program shape):
    diagonal plans must match exactly; matrix plans to float32-epsilon
    scale -- far inside the 2e-4 the compiler's own oracle tests allow;
    projective plans to a slightly wider relative tolerance (the
    perspective divide amplifies the last-ULP freedom), with the cull
    mask carried on ``Projected.mask`` matching ``chain.project``.
    """
    srv = _fresh_server(backend=backend, **server_kw)
    outs = srv.serve(reqs)
    assert len(outs) == len(reqs)
    for chain, pts in reqs:
        assert pts.dtype == np.float32
    for (chain, pts), out in zip(reqs, outs):
        assert out.shape == pts.shape
        if chain.is_projective:
            exp, mexp = chain.project(jnp.asarray(pts), backend=backend)
            assert isinstance(out, serving.Projected)
            np.testing.assert_array_equal(np.asarray(out.mask),
                                          np.asarray(mexp))
            np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                       rtol=1e-5, atol=1e-5)
            continue
        exp = chain.apply(jnp.asarray(pts), backend=backend)
        if chain.is_diagonal:
            np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
        else:
            np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                       rtol=2e-6, atol=2e-6)
    return srv


# ---------------------------------------------------------------------------
# packed == per-request across random mixed workloads
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_packed_matches_per_request_mixed_workload(backend):
    rng = np.random.default_rng(11)
    reqs = workload.random_workload(rng, 48, max_points=300)
    # the default template pool now includes projective viewing chains
    assert any(c.is_projective for c, _ in reqs)
    srv = _serve_and_compare(backend, reqs)
    # structures x sizes bucket; every bucket saved launches vs per-request
    assert serving.stats["requests"] == 48
    assert serving.stats["launches"] < 48
    assert serving.stats["launches"] == sum(r.launches
                                            for r in srv.last_report)


def test_packed_results_deterministic_across_flushes():
    """Same workload, same bucket shapes -> bitwise identical results."""
    rng = np.random.default_rng(5)
    reqs = workload.random_workload(rng, 24, max_points=200)
    out1 = _fresh_server(backend="ref").serve(reqs)
    out2 = serving.GeometryServer(backend="ref").serve(reqs)
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_padding_never_contaminates_payload():
    """A request's bits must not depend on WHICH requests share its bucket
    (same bucket shape, different neighbours)."""
    rng = np.random.default_rng(9)
    dim, kinds = 2, "TSRT"
    probe = workload.chain_for(rng, dim, kinds)
    pts = rng.standard_normal((50, dim)).astype(np.float32)
    outs = []
    for neighbour_seed in (1, 2):
        nrng = np.random.default_rng(neighbour_seed)
        reqs = [(probe, pts)] + [
            (workload.chain_for(nrng, dim, kinds),
             nrng.standard_normal((int(nrng.integers(1, 64)), dim))
             .astype(np.float32))
            for _ in range(5)]
        outs.append(np.asarray(_fresh_server(backend="ref").serve(reqs)[0]))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_identity_passes_through_and_empty_rejects():
    """Identity chains skip the launch path entirely; empty point sets
    are rejected AT SUBMIT with a typed, ticket-carrying error (an empty
    result is indistinguishable from a lost one) -- PR 6 tightened what
    used to be a silent pass-through."""
    srv = _fresh_server(backend="ref")
    pts = np.ones((4, 2), np.float32)
    srv.submit(tc.TransformChain.identity(2), pts)
    with pytest.raises(serving.errors.EmptyPointsError) as ei:
        srv.submit(workload.chain_for(np.random.default_rng(0), 2, "TS"),
                   np.zeros((0, 2), np.float32))
    assert ei.value.ticket == 1 and ei.value.code == "empty"
    (out_id,) = srv.flush()
    np.testing.assert_array_equal(np.asarray(out_id), pts)
    assert serving.stats["launches"] == 0
    assert serving.stats["rejected_requests"] == 1


def test_leading_batch_shapes_roundtrip():
    """(B, N, d)-shaped requests come back with their original shape."""
    rng = np.random.default_rng(3)
    chain = workload.chain_for(rng, 3, "TRS")
    pts = rng.standard_normal((4, 13, 3)).astype(np.float32)
    out = _fresh_server(backend="ref").serve([(chain, pts)])[0]
    assert out.shape == pts.shape
    exp = chain.apply(jnp.asarray(pts), backend="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-6, atol=2e-6)


def test_submitted_points_are_copied():
    """Mutating the caller's buffer between submit and flush must not
    change the queued request (and identity results must not alias it)."""
    rng = np.random.default_rng(1)
    chain = workload.chain_for(rng, 2, "TS")
    pts = rng.standard_normal((20, 2)).astype(np.float32)
    snapshot = pts.copy()
    srv = _fresh_server(backend="ref")
    srv.submit(chain, pts)
    srv.submit(tc.TransformChain.identity(2), pts)
    pts[:] = 0.0
    out, out_id = srv.flush()
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(chain.apply(jnp.asarray(snapshot), backend="ref")))
    np.testing.assert_array_equal(np.asarray(out_id), snapshot)


def test_dim_mismatch_rejected():
    srv = _fresh_server(backend="ref")
    with pytest.raises(ValueError):
        srv.submit(tc.TransformChain.identity(2).translate(1.0),
                   np.zeros((5, 3), np.float32))


# ---------------------------------------------------------------------------
# size-bucketing policy: the waste cap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("waste_cap", [0.5, 0.25, 0.125])
def test_padded_length_respects_waste_cap(waste_cap):
    min_len = 8
    prev = 0
    for n in range(1, 3000):
        lpad = bucketing.padded_length(n, min_len=min_len,
                                       waste_cap=waste_cap)
        assert lpad >= n and lpad >= min_len
        if n >= min_len:
            assert bucketing.waste_fraction(n, lpad) < waste_cap, \
                f"n={n} lpad={lpad}"
        assert lpad >= prev          # monotone grid
        prev = lpad


def test_pow2_grid_at_default_cap():
    """waste_cap=0.5 degenerates to power-of-two padding."""
    for n in (1, 8, 9, 17, 100, 1000):
        lpad = bucketing.padded_length(n)
        assert lpad & (lpad - 1) == 0


def test_engine_waste_stays_under_cap():
    rng = np.random.default_rng(17)
    reqs = workload.random_workload(rng, 40, max_points=400, min_points=8)
    for cap in (0.5, 0.25):
        srv = _fresh_server(backend="ref", waste_cap=cap)
        srv.serve(reqs)
        for rep in srv.last_report:
            assert rep.waste < cap, rep


# ---------------------------------------------------------------------------
# plan economy: one compile per structure under load, few launches
# ---------------------------------------------------------------------------

def test_one_plan_compile_per_structure_under_load():
    rng = np.random.default_rng(23)
    templates = ((2, "TSRT"), (3, "SAT"), (2, "TST"))
    reqs = workload.random_workload(rng, 60, templates=templates,
                                    max_points=250)
    srv = _fresh_server(backend="ref")
    srv.serve(reqs)
    assert serving.stats["plan_compiles"] == len(templates)
    assert serving.stats["plan_hits"] == len(srv.last_report) - len(templates)
    # a second wave: same request sizes (same bucket shapes) but fresh
    # parameter values -- the serving hot path.  No new compiles, no new
    # traces.
    traces = serving.stats["traces"]
    prng = np.random.default_rng(99)
    wave2 = [(workload.chain_for(prng, ch.dim,
                                 "".join(k for k, _ in ch.kinds)), pts)
             for ch, pts in reqs]
    srv.serve(wave2)
    assert serving.stats["plan_compiles"] == len(templates)
    assert serving.stats["traces"] == traces, \
        "seen bucket shapes must not retrace"


def test_bucketing_groups_by_structure_and_size():
    rng = np.random.default_rng(31)
    # 16 requests, one structure, sizes split across two pow2 classes
    chain_rng = np.random.default_rng(7)
    reqs = []
    for i in range(16):
        n = 30 if i % 2 else 120          # -> lpad 32 and 128
        reqs.append((workload.chain_for(chain_rng, 2, "TSRT"),
                     rng.standard_normal((n, 2)).astype(np.float32)))
    srv = _fresh_server(backend="ref")
    srv.serve(reqs)
    assert serving.stats["buckets"] == 2
    assert serving.stats["launches"] == 2
    assert {r.lpad for r in srv.last_report} == {32, 128}
    assert all(r.requests == 8 for r in srv.last_report)


# ---------------------------------------------------------------------------
# sharding oversized buckets
# ---------------------------------------------------------------------------

def test_oversized_bucket_shards_and_matches():
    rng = np.random.default_rng(41)
    chain_rng = np.random.default_rng(2)
    reqs = [(workload.chain_for(chain_rng, 2, "TSRT"),
             rng.standard_normal((100, 2)).astype(np.float32))
            for _ in range(12)]                   # one bucket, lpad=128
    srv = _serve_and_compare("ref", reqs, max_points_per_launch=3 * 128)
    assert serving.stats["buckets"] == 1
    assert serving.stats["launches"] == 4        # 12 reqs / 3 rows per shard
    assert serving.stats["shards"] == 3
    assert srv.last_report[0].launches == 4


# ---------------------------------------------------------------------------
# packed-batch byte accounting
# ---------------------------------------------------------------------------

def test_serving_records_packed_bytes_per_launch():
    rng = np.random.default_rng(43)
    chain_rng = np.random.default_rng(4)
    reqs = [(workload.chain_for(chain_rng, 2, "TSRT"),
             rng.standard_normal((60, 2)).astype(np.float32))
            for _ in range(8)]                    # one matrix bucket, lpad=64
    srv = _fresh_server(backend="ref")
    with opcount.counting() as records:
        srv.serve(reqs)
    serve_records = [r for r in records if r[0].startswith("serve_bucket_")]
    assert len(serve_records) == serving.stats["launches"] == 1
    (_, nbytes), = serve_records
    assert nbytes == opcount.packed_chain_bytes(8, 64, 2, kind="matrix")
    # the batched launch moves padded bytes, but still strictly fewer than
    # 8 requests x k=4 primitives of sequential per-primitive dispatch
    sequential = 8 * 4 * 2 * (60 * 2 * 4)
    assert nbytes < sequential


# ---------------------------------------------------------------------------
# stats reset semantics: the launch invariant across flush cycles
# ---------------------------------------------------------------------------

def test_stats_launch_invariant_across_flush_cycles():
    """``stats["launches"] == sum(r.launches for r in srv.reports)`` must
    hold across MULTIPLE flushes (reports accumulate; last_report is only
    the latest flush's slice) and survive a per-server reset."""
    rng = np.random.default_rng(51)
    srv = _fresh_server(backend="ref")
    for cycle in range(3):
        for chain, pts in workload.random_workload(rng, 12, max_points=80):
            srv.submit(chain, pts)
        srv.flush()
        assert serving.stats["launches"] == \
            sum(r.launches for r in srv.reports)
    assert len(srv.reports) > len(srv.last_report)  # accumulated, not sliced
    # per-server reset zeroes BOTH sides of the invariant in one step
    srv.reset_stats()
    assert serving.stats["launches"] == 0 and srv.reports == []
    for chain, pts in workload.random_workload(rng, 8, max_points=80):
        srv.submit(chain, pts)
    srv.flush()
    assert serving.stats["launches"] == sum(r.launches for r in srv.reports)


def test_stats_launch_invariant_holds_through_recovery():
    """Recovery launches (retries, ladder rungs, bisection probes) count
    into the SAME per-bucket reports the module counter sums over, so the
    invariant survives fault injection too."""
    reqs = workload.mixed_lane_workload(33, 32)
    inj = serving.FaultInjector(seed=33, flaky_rate=0.12, backend_rate=0.08,
                                corrupt_rate=0.08, poison_rate=0.05)
    serving.reset_stats()
    serving.clear_plan_cache()
    srv = serving.GeometryServer(backend="interpret", injector=inj,
                                 fault_config=serving.FaultConfig(
                                     backoff_base_s=0.0))
    for cycle in range(2):
        for chain, pts, qname in reqs:
            srv.submit(chain, pts, qformat=qname)
        srv.flush()
        assert serving.stats["launches"] == \
            sum(r.launches for r in srv.reports)
    assert serving.stats["launch_failures"] > 0     # the ladder really ran
