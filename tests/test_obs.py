"""Observability-layer tests: span-tree tracer semantics, the typed
metrics registry and its back-compat ``StatsView``, Chrome-trace /
Prometheus export determinism, the flight recorder, per-server counter
isolation, and the span-tree completeness invariants under seeded fault
injection (every submitted ticket's tree accounts for its outcome --
success, rejection, recovery, or bisection -- and the ``launch``
instant count equals ``stats["launches"]`` exactly).
"""
import json
import math

import numpy as np
import pytest

from repro import obs, serving
from repro.core import transform_chain as tc
from repro.serving import engine, faults
from repro.serving.async_engine import AsyncGeometryServer, SLOConfig
from repro.serving.clock import VirtualClock

RNG = np.random.default_rng(80)


def _fresh(**kw):
    serving.reset_stats()
    serving.clear_plan_cache()
    return serving.GeometryServer(**kw)


def _cfg(**kw):
    kw.setdefault("backoff_base_s", 0.0)
    return engine.FaultConfig(**kw)


def _chain2():
    return tc.TransformChain.identity(2).translate(0.5, -0.25).scale(1.5)


def _pts(n=8, dim=2):
    return RNG.uniform(-1, 1, (n, dim)).astype(np.float32)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

class TestTracer:
    def test_begin_end_nest_and_stack(self):
        clk = VirtualClock()
        trc = obs.Tracer(clock=clk)
        a = trc.begin("outer")
        clk.advance(1.0)
        b = trc.begin("inner", ticket=7)
        clk.advance(0.5)
        trc.end(b)
        trc.end(a)
        outer, inner = trc.spans[0], trc.spans[1]
        assert outer.name == "outer" and outer.t0 == 0.0 and outer.t1 == 1.5
        assert inner.parent == outer.sid and inner.duration == 0.5
        assert inner.ticket == 7

    def test_end_merges_attrs_and_late_ticket(self):
        trc = obs.Tracer(clock=VirtualClock())
        sid = trc.begin("s", a=1)
        trc.end(sid, ticket=3, b=2)
        (s,) = trc.spans
        assert s.ticket == 3 and s.attrs == {"a": 1, "b": 2}

    def test_instant_and_complete(self):
        trc = obs.Tracer(clock=VirtualClock(start=2.0))
        trc.instant("mark", ticket=1, k="v")
        trc.complete("retro", 0.25, 0.75, ticket=1)
        mark, retro = trc.spans
        assert mark.instant and mark.t0 == 2.0
        assert not retro.instant and (retro.t0, retro.t1) == (0.25, 0.75)
        assert trc.n_events == 2 and trc.n_spans == 1

    def test_span_contextmanager_closes_on_error(self):
        trc = obs.Tracer(clock=VirtualClock())
        with pytest.raises(RuntimeError):
            with trc.span("work", ticket=5):
                raise RuntimeError("boom")
        (s,) = trc.spans
        assert s.t1 is not None and s.ticket == 5

    def test_span_tree_reconstructs_per_ticket(self):
        trc = obs.Tracer(clock=VirtualClock())
        a = trc.begin("shared")              # untagged: drops out of trees
        b = trc.begin("request.validate", ticket=1)
        trc.end(b)
        c = trc.begin("bucket", tickets=(1, 2))
        trc.instant("launch", tickets=(1, 2))
        trc.end(c)
        trc.end(a)
        roots = trc.span_tree(1)
        names = [n.name for n in roots]
        assert names == ["request.validate", "bucket"]
        # the launch instant re-nests under the bucket span, not the
        # uncollected "shared" ancestor
        assert [ch.name for ch in roots[1].children] == ["launch"]
        assert trc.span_tree(3) == []

    def test_install_and_restore(self):
        trc = obs.Tracer(clock=VirtualClock())
        assert not obs.active().enabled
        with obs.installed(trc):
            assert obs.active() is trc
            inner = obs.Tracer(clock=VirtualClock())
            with obs.installed(inner):
                assert obs.active() is inner
            assert obs.active() is trc
        assert not obs.active().enabled

    def test_null_tracer_is_inert(self):
        n = obs.NullTracer()
        assert not n.enabled and n.spans == ()
        sid = n.begin("x")
        n.end(sid)
        n.instant("y")
        with n.span("z"):
            pass
        assert n.spans == ()


# ---------------------------------------------------------------------------
# metrics registry + back-compat views
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_gauge_histogram(self):
        reg = obs.MetricsRegistry("t")
        c = reg.counter("hits")
        c.inc()
        c.inc(4)
        g = reg.gauge("depth")
        g.track_max(3)
        g.track_max(1)
        h = reg.histogram("lat")
        for v in (1.0, 2.0, 3.0, 4.0):
            h.observe(v)
        assert reg.value("hits") == 5 and reg.value("depth") == 3
        assert h.count == 4 and h.sum == 10.0 and h.max == 4.0
        assert h.percentile(50) == 2.0

    def test_labels_fan_out(self):
        reg = obs.MetricsRegistry()
        fam = reg.counter("req", labels=("tenant",))
        fam.labels(tenant="a").inc(2)
        fam.labels(tenant="b").inc()
        assert reg.value("req", tenant="a") == 2
        assert reg.value("req", tenant="b") == 1
        with pytest.raises(ValueError):
            fam.labels(nope="x")

    def test_redeclare_must_be_consistent(self):
        reg = obs.MetricsRegistry()
        reg.counter("n")
        assert reg.counter("n") is not None    # same family: fine
        with pytest.raises(ValueError):
            reg.gauge("n")

    def test_reset_zeroes_in_place(self):
        reg = obs.MetricsRegistry()
        c = reg.counter("n")
        c.inc(9)
        reg.reset()
        assert c.value == 0 and reg.counter("n") is c

    def test_stats_view_is_a_mutable_mapping(self):
        reg = obs.MetricsRegistry()
        view = obs.StatsView(reg, ("a", "b"))
        view["a"] += 2
        view["b"] = 5
        assert dict(view) == {"a": 2, "b": 5}
        assert view == {"a": 2, "b": 5} and len(view) == 2
        assert sorted(view) == ["a", "b"]
        with pytest.raises(KeyError):
            view["nope"] = 1

    def test_percentile_reexported_by_clock(self):
        from repro.serving.clock import percentile
        assert percentile is obs.percentile
        assert percentile([3.0, 1.0, 2.0], 50) == 2.0

    def test_percentile_empty_is_nan(self):
        assert math.isnan(obs.percentile([], 50))
        assert math.isnan(obs.percentile([], 0))
        assert math.isnan(obs.percentile([], 100))

    def test_percentile_single_sample_is_that_sample(self):
        for q in (0, 1, 50, 99, 100):
            assert obs.percentile([7.0], q) == 7.0

    def test_percentile_all_equal(self):
        for q in (0, 50, 99, 100):
            assert obs.percentile([3.0] * 5, q) == 3.0

    def test_percentile_nearest_rank_ties(self):
        # nearest rank is exact set membership: p50 of an even-length
        # sample is the LOWER middle element (rank ceil(0.5*4) = 2),
        # and p99 of any sample shorter than 100 is its maximum
        assert obs.percentile([1.0, 2.0, 3.0, 4.0], 50) == 2.0
        assert obs.percentile([1.0, 2.0, 3.0, 4.0, 5.0], 50) == 3.0
        assert obs.percentile([1.0, 2.0, 3.0, 4.0], 99) == 4.0
        assert obs.percentile(range(1, 101), 99) == 99
        assert obs.percentile(range(1, 101), 50) == 50
        # duplicated median: ties collapse to the shared value
        assert obs.percentile([1.0, 2.0, 2.0, 9.0], 50) == 2.0
        with pytest.raises(ValueError):
            obs.percentile([1.0], 101)
        with pytest.raises(ValueError):
            obs.percentile([1.0], -1)

    def test_histogram_edge_cases(self):
        h = obs.Histogram()
        # empty: count/sum/max well-defined, quantile nan, buckets zero
        assert h.count == 0 and h.sum == 0.0 and h.max == 0.0
        assert math.isnan(h.percentile(99))
        assert h.bucket_counts() == [0] * len(obs.Histogram.BOUNDS)
        # single sample sits in every bucket at or above its bound
        h.observe(0.01)
        assert h.percentile(50) == 0.01 and h.percentile(99) == 0.01
        assert h.bucket_counts((0.005, 0.01, 0.05)) == [0, 1, 1]
        # all-equal: every quantile is the shared value
        h2 = obs.Histogram()
        for _ in range(8):
            h2.observe(2.0)
        assert h2.percentile(50) == 2.0 == h2.percentile(99)
        assert h2.count == 8 and h2.sum == 16.0 and h2.max == 2.0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

class TestExport:
    def _tracer(self):
        clk = VirtualClock()
        trc = obs.Tracer(clock=clk)
        sid = trc.begin("flush")
        b = trc.begin("bucket.assemble", track="2D:TS|ref|<f4|8",
                      tickets=(0, 1))
        clk.advance(0.001)
        trc.instant("launch", track="2D:TS|ref|<f4|8", rows=2)
        trc.end(b)
        trc.end(sid)
        return trc

    def test_chrome_events_shape(self):
        evs = obs.chrome_trace_events(self._tracer())
        meta = [e for e in evs if e["ph"] == "M"]
        assert [m["args"]["name"] for m in meta] == \
            ["serve", "2D:TS|ref|<f4|8"]     # first-seen track order
        x = [e for e in evs if e["ph"] == "X"]
        i = [e for e in evs if e["ph"] == "i"]
        assert len(x) == 2 and len(i) == 1 and i[0]["s"] == "t"
        assert x[0]["tid"] == 0 and x[1]["tid"] == 1

    def test_dump_is_byte_deterministic(self, tmp_path):
        p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
        obs.dump_chrome_trace(self._tracer(), str(p1))
        obs.dump_chrome_trace(self._tracer(), str(p2))
        assert p1.read_bytes() == p2.read_bytes()
        doc = json.loads(p1.read_text())
        assert doc["displayTimeUnit"] == "ms"

    def test_prometheus_text_sorted_and_typed(self):
        reg = obs.MetricsRegistry("srv")
        reg.counter("zeta").inc(2)
        reg.counter("alpha", help="first").inc()
        fam = reg.counter("by_tenant", labels=("tenant",))
        fam.labels(tenant="b").inc()
        fam.labels(tenant="a").inc(3)
        h = reg.histogram("lat")
        h.observe(0.5)
        text = obs.prometheus_text(reg)
        lines = text.splitlines()
        assert "# HELP srv_alpha first" in lines
        assert lines.index("# TYPE srv_alpha counter") < \
            lines.index("# TYPE srv_zeta counter")
        # label children sort by value; histograms render as cumulative
        # bucket series
        ia = lines.index('srv_by_tenant{tenant="a"} 3')
        ib = lines.index('srv_by_tenant{tenant="b"} 1')
        assert ia < ib
        assert "# TYPE srv_lat histogram" in lines
        assert 'srv_lat_bucket{le="0.25"} 0' in lines
        assert 'srv_lat_bucket{le="0.5"} 1' in lines      # 0.5 <= 0.5
        assert 'srv_lat_bucket{le="2.5"} 1' in lines
        assert 'srv_lat_bucket{le="+Inf"} 1' in lines
        assert "srv_lat_sum 0.5" in lines
        assert "srv_lat_count 1" in lines
        # bucket lines are cumulative and ordered bound-ascending
        bucket_vals = [int(ln.rsplit(" ", 1)[1]) for ln in lines
                       if ln.startswith("srv_lat_bucket")]
        assert bucket_vals == sorted(bucket_vals)
        assert obs.prometheus_text(reg) == text    # deterministic


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

class TestFlightRecorder:
    def test_bounded_window(self):
        rec = obs.FlightRecorder(capacity=4)
        trc = obs.Tracer(clock=VirtualClock(), recorder=rec)
        for k in range(10):
            trc.instant("e", k=k)
        assert len(rec) == 4 and rec.recorded == 10 and rec.dropped == 6
        snap = rec.snapshot()
        assert [e["attrs"]["k"] for e in snap] == [6, 7, 8, 9]
        rec.clear()
        assert len(rec) == 0 and rec.recorded == 0

    def test_capacity_validates(self):
        with pytest.raises(ValueError):
            obs.FlightRecorder(capacity=0)


# ---------------------------------------------------------------------------
# engine tracing: span trees, exact launch accounting, zero steering
# ---------------------------------------------------------------------------

class TestEngineTracing:
    def test_launch_instants_equal_launch_counter(self):
        srv = _fresh(backend="ref")
        trc = obs.Tracer(clock=VirtualClock())
        with obs.installed(trc):
            for _ in range(6):
                srv.submit(_chain2(), _pts(int(RNG.integers(4, 24))))
            srv.submit(tc.TransformChain.identity(2), _pts(5))
            srv.flush()
        assert trc.count("launch") == serving.stats["launches"] > 0
        assert trc.count("request.resolve") == 7

    def test_every_ticket_tree_complete_on_success(self):
        srv = _fresh(backend="ref")
        trc = obs.Tracer(clock=VirtualClock())
        with obs.installed(trc):
            tickets = [srv.submit(_chain2(), _pts(8)) for _ in range(4)]
            tickets.append(srv.submit(tc.TransformChain.identity(2),
                                      _pts(3)))
            srv.flush()
        for t in tickets:
            names = [s.name for root in trc.span_tree(t)
                     for s in root.walk()]
            assert "request.validate" in names
            assert "request.resolve" in names

    def test_rejection_tree(self):
        srv = _fresh(backend="ref")
        trc = obs.Tracer(clock=VirtualClock())
        with obs.installed(trc):
            with pytest.raises(serving.RequestError):
                srv.submit(_chain2(), np.zeros((0, 2), np.float32))
        (s,) = trc.spans_for(trc.tickets_seen()[0])
        assert s.name == "request.validate"
        assert s.attrs["outcome"] == "rejected"
        assert s.attrs["code"] == "empty"

    def test_tracing_never_steers_the_counters(self):
        # identical seeded workload, untraced vs traced: every counter
        # bit-identical (instrumentation observes, never steers)
        def serve():
            srv = _fresh(backend="ref")
            rng = np.random.default_rng(7)
            for _ in range(12):
                n = int(rng.integers(2, 40))
                pts = rng.uniform(-1, 1, (n, 2)).astype(np.float32)
                srv.submit(_chain2(), pts)
            srv.flush()
            return dict(serving.stats)

        untraced = serve()
        trc = obs.Tracer(clock=VirtualClock())
        with obs.installed(trc):
            traced = serve()
        assert untraced == traced
        assert trc.n_events > 0

    def test_bucket_tracks_and_labeled_dimensions(self):
        srv = _fresh(backend="ref")
        trc = obs.Tracer(clock=VirtualClock())
        with obs.installed(trc):
            srv.submit(_chain2(), _pts(8))
            srv.flush()
        tracks = {s.track for s in trc.spans if s.track}
        assert len(tracks) == 1
        track = tracks.pop()
        assert "ref" in track                 # structure|backend|dtype|lpad
        # the per-server labeled counter saw the bucket's rows
        kind, backend, dt, lpad = None, "ref", None, None
        for s in trc.spans:
            if s.name == "bucket.assemble":
                kind = s.attrs["kind"]
                lpad = str(s.attrs["lpad"])
        dt = track.split("|")[2]
        assert srv.metrics.value("bucket_requests", kind=kind,
                                 backend=backend, dtype=dt,
                                 size_class=lpad) == 1


class TestSpanTreesUnderFaults:
    def _traced_faulty(self, inj, n=6, **srv_kw):
        srv = _fresh(backend="ref", fault_config=_cfg(max_launch_attempts=2),
                     injector=inj, **srv_kw)
        rec = obs.FlightRecorder(capacity=128)
        trc = obs.Tracer(clock=VirtualClock(), recorder=rec)
        with obs.installed(trc):
            tickets = [srv.submit(_chain2(), _pts(8)) for _ in range(n)]
            results = srv.flush()
        return srv, trc, tickets, results

    def test_recovery_tree_for_flaky_ticket(self):
        inj = faults.FaultInjector(flaky_tickets=frozenset({0}),
                                   flaky_attempts=1)
        srv, trc, tickets, results = self._traced_faulty(inj)
        names = [s.name for root in trc.span_tree(0)
                 for s in root.walk()]
        assert "recover" in names and "request.resolve" in names
        rec_spans = [s for s in trc.spans_for(0) if s.name == "recover"]
        assert rec_spans[0].attrs["outcome"] == "recovered"
        assert str(rec_spans[0].track).startswith("recovery:")
        assert trc.count("launch") == serving.stats["launches"]

    def test_bisection_and_terminal_failure_trees(self):
        inj = faults.FaultInjector(poison_tickets=frozenset({2}))
        srv, trc, tickets, results = self._traced_faulty(inj)
        assert trc.count("recover.bisect") == serving.stats["bisections"] > 0
        # the poisoned ticket: recover spans + a launch-error resolve
        res = [s for s in trc.spans_for(2) if s.name == "request.resolve"]
        assert len(res) == 1 and res[0].attrs["outcome"] == "launch-error"
        assert isinstance(results[2], serving.LaunchError)
        # its terminal error carries the flight-recorder window
        assert isinstance(results[2].flight, list) and results[2].flight
        assert all("name" in e for e in results[2].flight)
        # the bucket neighbours all recovered, each with a complete tree
        for t in [t for t in tickets if t != 2]:
            outs = [s.attrs["outcome"] for s in trc.spans_for(t)
                    if s.name == "request.resolve"]
            assert outs == ["ok"]
        assert trc.count("launch") == serving.stats["launches"]

    def test_every_ticket_accounted_under_mixed_faults(self):
        inj = faults.FaultInjector(flaky_tickets=frozenset({0}),
                                   backend_tickets=frozenset({1}),
                                   corrupt_tickets=frozenset({3}),
                                   poison_tickets=frozenset({4}),
                                   flaky_attempts=1)
        srv, trc, tickets, results = self._traced_faulty(inj, n=8)
        for t in tickets:
            spans = trc.spans_for(t)
            assert any(s.name == "request.validate"
                       and s.attrs["outcome"] == "admitted" for s in spans)
            resolves = [s for s in spans if s.name == "request.resolve"]
            assert len(resolves) == 1, f"ticket {t} must resolve exactly once"
            assert resolves[0].attrs["outcome"] in ("ok", "launch-error")
        assert trc.count("launch") == serving.stats["launches"]
        # the poisoned ticket is terminally failed; the corrupted one may
        # also fail after bisection isolates it -- both resolve exactly
        # once (asserted above), which is the invariant under test
        assert serving.stats["failed_requests"] >= 1
        assert isinstance(results[4], serving.LaunchError)


# ---------------------------------------------------------------------------
# per-server counters vs the module aggregate (the multi-server drift fix)
# ---------------------------------------------------------------------------

class TestPerServerCounters:
    def test_two_servers_do_not_blur(self):
        serving.reset_stats()
        serving.clear_plan_cache()
        a = serving.GeometryServer(backend="ref")
        b = serving.GeometryServer(backend="ref")
        for _ in range(3):
            a.submit(_chain2(), _pts(8))
        for _ in range(5):
            b.submit(_chain2(), _pts(8))
        a.flush()
        b.flush()
        assert a.metrics.value("requests") == 3
        assert b.metrics.value("requests") == 5
        assert a.metrics.value("launches") == 1
        assert b.metrics.value("launches") == 1
        # the module view is the explicit aggregate across servers
        assert serving.stats["requests"] == 8
        assert serving.stats["launches"] == \
            a.metrics.value("launches") + b.metrics.value("launches")

    def test_reset_stats_clears_server_registry(self):
        srv = _fresh(backend="ref")
        srv.submit(_chain2(), _pts(4))
        srv.flush()
        assert srv.metrics.value("requests") == 1
        srv.reset_stats()
        assert srv.metrics.value("requests") == 0

    def test_two_async_engines_mirror_rejections_by_delta(self):
        # the old absolute mirror clobbered the module counters when two
        # engines served side by side; deltas must sum
        serving.reset_stats()
        serving.clear_plan_cache()
        clock = VirtualClock()
        cfg = serving.AdmissionConfig(max_queue_depth=1, tenant_share=1.0)
        e1 = AsyncGeometryServer(backend="ref", clock=clock, admission=cfg)
        e2 = AsyncGeometryServer(backend="ref", clock=clock, admission=cfg)
        for eng_ in (e1, e2):
            eng_.submit_async(_chain2(), _pts(4))
            for _ in range(2):
                with pytest.raises(serving.QueueFullError):
                    eng_.submit_async(_chain2(), _pts(4))
        assert e1.stats["queue_full_rejections"] == 2
        assert e2.stats["queue_full_rejections"] == 2
        assert serving.stats["queue_full_rejections"] == 4
        e1.drain()
        e2.drain()


# ---------------------------------------------------------------------------
# async front-end tracing
# ---------------------------------------------------------------------------

class TestAsyncTracing:
    def test_queue_wait_and_policy_spans(self):
        serving.reset_stats()
        serving.clear_plan_cache()
        clock = VirtualClock()
        eng_ = AsyncGeometryServer(
            backend="ref", clock=clock,
            slo=SLOConfig(max_wait_s=0.01, target_rows=4))
        trc = obs.Tracer(clock=clock)
        with obs.installed(trc):
            t = eng_.submit_async(_chain2(), _pts(6), tenant="a")
            due = eng_.next_due_in()
            clock.advance(due)
            eng_.poll()
        assert t.done()
        waits = [s for s in trc.spans if s.name == "queue.wait"]
        assert len(waits) == 1 and waits[0].ticket == t.id
        assert waits[0].duration == pytest.approx(due)
        assert 0.0 < waits[0].duration <= 0.01
        pol = [s for s in trc.spans if s.name == "policy.launch"]
        assert len(pol) == 1 and pol[0].attrs["reason"] == "deadline"
        subs = [s for s in trc.spans if s.name == "request.submit"]
        assert subs[0].attrs["outcome"] == "admitted"
        assert subs[0].ticket == t.id

    def test_fill_reason_and_admission_reject_instant(self):
        serving.reset_stats()
        serving.clear_plan_cache()
        clock = VirtualClock()
        eng_ = AsyncGeometryServer(
            backend="ref", clock=clock,
            slo=SLOConfig(max_wait_s=1.0, target_rows=2),
            admission=serving.AdmissionConfig(max_queue_depth=2,
                                              tenant_share=1.0))
        trc = obs.Tracer(clock=clock)
        with obs.installed(trc):
            eng_.submit_async(_chain2(), _pts(4))
            eng_.submit_async(_chain2(), _pts(4))
            with pytest.raises(serving.QueueFullError):
                eng_.submit_async(_chain2(), _pts(4))
            eng_.poll()                      # full bucket: due immediately
        pol = [s for s in trc.spans if s.name == "policy.launch"]
        assert [s.attrs["reason"] for s in pol] == ["fill"]
        rej = [s for s in trc.spans if s.name == "admission.reject"]
        assert len(rej) == 1 and rej[0].attrs["code"] == "queue-full"
        assert rej[0].attrs["gate"] == "depth"

    def test_registry_backed_stats_view_unchanged(self):
        serving.reset_stats()
        serving.clear_plan_cache()
        clock = VirtualClock()
        eng_ = AsyncGeometryServer(backend="ref", clock=clock)
        t = eng_.submit_async(_chain2(), _pts(4), tenant="r")
        eng_.drain()
        st = eng_.stats
        assert st["admitted"] == 1 and st["resolved"] == 1
        assert st["failed"] == 0 and st["queue_depth"] == 0
        assert st["p50_latency_s"] == st["p99_latency_s"] >= 0.0
        assert eng_.metrics.value("tenant_requests", tenant="r") == 1
        assert not serving.is_error(t.result())


# ---------------------------------------------------------------------------
# chaos soak post-mortems
# ---------------------------------------------------------------------------

class TestChaosPostmortems:
    def test_soak_attaches_postmortems(self):
        serving.reset_stats()
        serving.clear_plan_cache()
        rep = faults.run_chaos_soak(seed=3, n_requests=48)
        assert rep.lost == 0
        assert rep.postmortems, "faults fired, so post-mortems must exist"
        for pm in rep.postmortems:
            assert str(pm["track"]).startswith("recovery")
            assert pm["events"] and all("name" in e for e in pm["events"])
        json.dumps(rep.postmortems)           # plain-JSON by construction
        assert "postmortems" not in rep.counters()

    def test_soak_is_deterministic_with_postmortems(self):
        serving.reset_stats()
        serving.clear_plan_cache()
        r1 = faults.run_chaos_soak(seed=5, n_requests=32)
        serving.reset_stats()
        serving.clear_plan_cache()
        r2 = faults.run_chaos_soak(seed=5, n_requests=32)
        assert r1.counters() == r2.counters()
        assert [pm["track"] for pm in r1.postmortems] == \
            [pm["track"] for pm in r2.postmortems]
