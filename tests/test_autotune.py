"""Autotune subsystem tests: cache round-trip + winners-file determinism,
cost-model sanity against the runtime ``opcount`` byte accounting and the
MorphoSys cycle emulator, and the integration contracts -- a tuned size
grid still honours the padding-waste cap and packed-vs-per-request
equality, and every cached kernel configuration is bit-identical to the
untuned path (the knobs steer staging, never arithmetic).
"""
import os

import numpy as np
import jax.numpy as jnp
import pytest

import repro.autotune as autotune
from repro import serving
from repro.autotune import cache as tcache
from repro.autotune import costmodel, search
from repro.autotune.cache import KernelConfig, TuningCache
from repro.core import transform_chain as tc
from repro.core.morphosys import programs
from repro.kernels import opcount
from repro.serving import bucketing, workload


@pytest.fixture
def tuning_state():
    """Isolate the process-wide autotune state: every test starts disabled
    with no loaded cache and leaves no plan traced against its config."""
    autotune.set_enabled(False)
    tcache.set_cache(None)
    tcache.set_cache_path(None)
    yield
    autotune.set_enabled(None)
    tcache.set_cache(None)
    tcache.set_cache_path(None)


def _enable_with(cache: TuningCache) -> None:
    tcache.set_cache(cache)
    autotune.set_enabled(True)


#: a deterministic stand-in for the wall-clock timer: pure function of the
#: candidate's tunable fields, so search results are reproducible
def _fake_measure(cfg: KernelConfig) -> float:
    return 1.0 + sum(float(v) for v in cfg.key_fields().values()) / 1e4


# ---------------------------------------------------------------------------
# cache round-trip + determinism
# ---------------------------------------------------------------------------

def test_cache_roundtrip(tmp_path, tuning_state):
    cache = TuningCache()
    cfgs = [KernelConfig("chain_apply", block_rows=128, lane_target=1024,
                         source="tuned"),
            KernelConfig("serving_grid", grid_min_len=32,
                         grid_waste_cap=0.25, source="tuned"),
            KernelConfig("matmul", bm=256, bn=128, bk=512, source="tuned")]
    cache.put("chain_apply", "ref", "float32", 4096, cfgs[0])
    cache.put("serving_grid", "ref", "float32", 0, cfgs[1])
    cache.put("matmul", "interpret", "bfloat16", 1 << 20, cfgs[2])
    path = str(tmp_path / "winners.json")
    cache.save(path)
    loaded = TuningCache.load(path)
    assert len(loaded) == 3
    for (kernel, backend, dtype, n), cfg in (
            (("chain_apply", "ref", "float32", 4096), cfgs[0]),
            (("serving_grid", "ref", "float32", 0), cfgs[1]),
            (("matmul", "interpret", "bfloat16", 1 << 20), cfgs[2])):
        got = loaded.get(kernel, backend, dtype, n)
        assert got.key_fields() == cfg.key_fields()
        assert got.source == "cached"          # loaded winners say so
    # serialization is canonical: load -> save reproduces the same bytes
    assert loaded.to_json() == cache.to_json()


def test_cache_nearest_size_class_fallback(tuning_state):
    cache = TuningCache()
    tuned = KernelConfig("chain_apply", block_rows=64, source="tuned")
    cache.put("chain_apply", "ref", "float32", 2048, tuned)   # class p11
    # same class hits exactly; neighbouring sizes fall back to it
    assert cache.get("chain_apply", "ref", "float32", 2000) is tuned
    assert cache.get("chain_apply", "ref", "float32", 1 << 16) is tuned
    # different backend/dtype/kernel never cross-talk
    assert cache.get("chain_apply", "interpret", "float32", 2048) is None
    assert cache.get("chain_apply", "ref", "float64", 2048) is None
    assert cache.get("chain_diag", "ref", "float32", 2048) is None


def test_search_deterministic_winners_file(tmp_path, tuning_state):
    """Same inputs (workload seed, candidate spaces, measure) -> the same
    winners, serialized to byte-identical files."""
    paths = []
    for i in (0, 1):
        cache, reports = search.smoke_search("ref", measure=_fake_measure)
        # 3 float chain shapes + 2 fixed-point twins + 2 grid scales
        assert len(reports) == 7
        p = str(tmp_path / f"winners{i}.json")
        cache.save(p)
        paths.append(p)
    with open(paths[0]) as a, open(paths[1]) as b:
        assert a.read() == b.read()


def test_disabled_returns_deterministic_defaults(tuning_state):
    # even with a cache installed, disabled lookups return the defaults
    cache = TuningCache()
    cache.put("chain_apply", "ref", "float32", 0,
              KernelConfig("chain_apply", block_rows=8, source="tuned"))
    tcache.set_cache(cache)
    cfg = tcache.config_for("chain_apply", "ref", "float32", 0)
    assert cfg == tcache.DEFAULTS["chain_apply"]
    assert cfg.source == "default"
    autotune.set_enabled(True)
    assert tcache.config_for("chain_apply", "ref", "float32",
                             0).block_rows == 8


def test_committed_default_cache_loads(tuning_state):
    """The repo ships a ref-backend winners file so CI and fresh clones
    never depend on a tuning run."""
    assert os.path.exists(tcache.DEFAULT_CACHE_PATH)
    committed = TuningCache.load(tcache.DEFAULT_CACHE_PATH)
    grid = committed.get("serving_grid", "ref")
    assert grid is not None and grid.source == "cached"
    assert grid.grid_min_len >= 1
    assert 0.0 < grid.grid_waste_cap < 1.0


# ---------------------------------------------------------------------------
# cost-model sanity: bytes vs opcount, cycles vs the emulator
# ---------------------------------------------------------------------------

def test_chain_cost_matches_recorded_bytes(tuning_state):
    """The analytic byte count equals what the runtime records."""
    n, d = 500, 3
    pts = jnp.asarray(np.random.default_rng(0).standard_normal((n, d)),
                      jnp.float32)
    general = (tc.TransformChain.identity(d)
               .rotate(0.3, axis="z").translate(1.0, 2.0, 3.0))
    diag = tc.TransformChain.identity(d).scale(2.0).translate(1.0, 2.0, 3.0)
    for chain, kind in ((general, "matrix"), (diag, "diag")):
        with opcount.counting() as records:
            chain.apply(pts, backend="ref")
        (_, nbytes), = records
        assert nbytes == costmodel.chain_cost(n, d, kind).hbm_bytes


@pytest.mark.parametrize("kind", ["diag", "matrix"])
def test_packed_cost_matches_opcount(kind, tuning_state):
    for bsz, lpad, d in ((8, 64, 2), (3, 128, 3), (1, 8, 2)):
        est = costmodel.packed_chain_cost(bsz, lpad, d, kind)
        assert est.hbm_bytes == opcount.packed_chain_bytes(bsz, lpad, d,
                                                           kind=kind)


def test_grid_cost_replays_engine_bucketing(tuning_state):
    """The model's launch count equals the engine's actual schedule."""
    reqs = workload.random_workload(seed=33, n_requests=40, max_points=300)
    for min_len, cap in ((8, 0.5), (32, 0.25), (64, 0.125)):
        est = costmodel.grid_cost(costmodel.workload_shape(reqs),
                                  min_len, cap)
        srv = serving.GeometryServer(backend="ref", min_len=min_len,
                                     waste_cap=cap)
        serving.reset_stats()
        srv.serve(reqs)
        assert est.launches == serving.stats["launches"], (min_len, cap)


def test_morphosys_cycles_match_emulator(tuning_state):
    """The closed-form cycle model reproduces the emulator (and through
    it the paper's published Table 5 numbers) for the 8/64-element
    cases."""
    rng = np.random.default_rng(0)
    for n in (8, 64):
        u = rng.integers(-99, 99, n)
        v = rng.integers(-99, 99, n)
        assert costmodel.morphosys_cycles("translation", n) == \
            programs.run_translation(u, v).cycles
        assert costmodel.morphosys_cycles("scaling", n) == \
            programs.run_scaling(u, 5).cycles
    # and the published constants directly
    assert costmodel.morphosys_cycles("translation", 64) == 96
    assert costmodel.morphosys_cycles("scaling", 64) == 55


def test_perf_rows_print_in_paper_format(tuning_state):
    from repro.core import analysis
    rows = costmodel.perf_rows()
    assert {(r.algorithm, r.n_elements) for r in rows} == \
        {("translation", 8), ("translation", 64),
         ("scaling", 8), ("scaling", 64)}
    assert all(r.source == "model" for r in rows)
    table = analysis.format_table(rows)
    assert "translation" in table and "model" in table


def test_prune_is_deterministic_and_drops_infeasible(tuning_state):
    cands = search.matmul_candidates()
    cost = lambda c: costmodel.matmul_cost(1024, 1024, 1024, c)
    first = costmodel.prune(cands, cost, keep=4)
    assert first == costmodel.prune(list(reversed(cands)), cost, keep=4)
    assert len(first) == 4
    # an impossible tile never survives
    huge = KernelConfig("matmul", bm=4096, bn=4096, bk=4096)
    assert huge not in costmodel.prune(cands + [huge], cost, keep=100)


# ---------------------------------------------------------------------------
# integration: tuned grid waste cap + equality, bit-identical configs
# ---------------------------------------------------------------------------

def test_tuned_grid_satisfies_waste_cap_and_equality(tuning_state):
    """A GeometryServer running a TUNED size grid still honours the
    padding-waste cap (for requests at or above the grid floor) and the
    packed-vs-per-request equality contract."""
    cache = TuningCache()
    tuned = KernelConfig("serving_grid", grid_min_len=16,
                         grid_waste_cap=0.25, source="tuned")
    cache.put("serving_grid", "ref", "float32", 0, tuned)
    _enable_with(cache)
    reqs = workload.random_workload(seed=21, n_requests=40, max_points=400,
                                    min_points=16)
    srv = serving.GeometryServer(backend="ref")     # knobs from the cache
    assert (srv.min_len, srv.waste_cap) == (16, 0.25)
    assert srv.grid_source in ("tuned", "cached")
    serving.reset_stats()
    outs = srv.serve(reqs)
    for rep in srv.last_report:
        assert rep.waste < 0.25, rep                # the tuned cap holds
    for (chain, pts), out in zip(reqs, outs):
        exp = np.asarray(chain.apply(jnp.asarray(pts), backend="ref"))
        if chain.is_diagonal:
            np.testing.assert_array_equal(np.asarray(out), exp)
        else:
            np.testing.assert_allclose(np.asarray(out), exp,
                                       rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_apply_bit_identical_for_every_cached_config(backend, tuning_state):
    """TransformChain.apply under ANY cached kernel configuration is
    bit-identical to the untuned path: the knobs steer staging only."""
    rng = np.random.default_rng(3)
    pts = jnp.asarray(rng.standard_normal((700, 2)), jnp.float32)
    chain = (tc.TransformChain.identity(2)
             .scale(1.3, 0.8).rotate(0.4).translate(2.0, -1.0))
    diag = tc.TransformChain.identity(2).scale(1.3, 0.8).translate(2.0, -1.0)
    baseline = np.asarray(chain.apply(pts, backend=backend))
    baseline_d = np.asarray(diag.apply(pts, backend=backend))
    for cand in search.chain_candidates("chain_apply"):
        cache = TuningCache()
        cache.put("chain_apply", backend, "float32", 700,
                  KernelConfig("chain_apply", source="tuned",
                               **cand.key_fields()))
        cache.put("chain_diag", backend, "float32", 700,
                  KernelConfig("chain_diag", source="tuned",
                               **cand.key_fields()))
        _enable_with(cache)                         # clears plan caches
        np.testing.assert_array_equal(
            np.asarray(chain.apply(pts, backend=backend)), baseline)
        np.testing.assert_array_equal(
            np.asarray(diag.apply(pts, backend=backend)), baseline_d)
        autotune.set_enabled(False)


def test_server_bit_identical_under_batch_block_configs(tuning_state):
    """The GeometryServer under tuned batch-kernel block configs (same
    grid, so same bucket shapes) returns bit-identical results."""
    reqs = workload.random_workload(seed=8, n_requests=24, max_points=200)
    base = serving.GeometryServer(backend="interpret").serve(reqs)
    for bm in (8, 32, 128):
        cache = TuningCache()
        for kernel in ("chain_diag_batch", "chain_apply_batch"):
            cache.put(kernel, "interpret", "float32", 0,
                      KernelConfig(kernel, block_rows=bm, source="tuned"))
        _enable_with(cache)
        outs = serving.GeometryServer(backend="interpret").serve(reqs)
        for a, b in zip(base, outs):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        autotune.set_enabled(False)


def test_grid_for_resolution_order(tuning_state):
    # explicit knobs always win, even with a cache enabled
    cache = TuningCache()
    cache.put("serving_grid", "ref", "float32", 0,
              KernelConfig("serving_grid", grid_min_len=64,
                           grid_waste_cap=0.125, source="tuned"))
    _enable_with(cache)
    assert bucketing.grid_for("ref", min_len=4, waste_cap=0.5) == \
        (4, 0.5, "explicit")
    assert bucketing.grid_for("ref")[:2] == (64, 0.125)
    # mixed: the explicit knob wins, the other comes from the cache, and
    # the source label says so
    assert bucketing.grid_for("ref", min_len=16) == \
        (16, 0.125, "explicit+tuned")
    autotune.set_enabled(False)
    assert bucketing.grid_for("ref") == \
        (bucketing.MIN_LEN, bucketing.WASTE_CAP, "default")
    assert bucketing.grid_for("ref", waste_cap=0.25) == \
        (bucketing.MIN_LEN, 0.25, "explicit+default")


def test_set_enabled_moves_a_live_server(tuning_state):
    """Toggling the tuning cache after a server exists must move its grid
    on the next flush (the grid re-resolves per flush; plan caches are
    cleared by set_enabled itself)."""
    cache = TuningCache()
    cache.put("serving_grid", "ref", "float32", 0,
              KernelConfig("serving_grid", grid_min_len=64,
                           grid_waste_cap=0.25, source="tuned"))
    tcache.set_cache(cache)
    srv = serving.GeometryServer(backend="ref")       # built while disabled
    assert (srv.min_len, srv.grid_source) == (bucketing.MIN_LEN, "default")
    reqs = workload.random_workload(seed=4, n_requests=6, max_points=40)
    autotune.set_enabled(True)
    srv.serve(reqs)
    assert (srv.min_len, srv.waste_cap) == (64, 0.25)
    assert srv.grid_source in ("tuned", "cached")
    autotune.set_enabled(False)
    srv.serve(reqs)
    assert (srv.min_len, srv.grid_source) == (bucketing.MIN_LEN, "default")
    # explicit knobs survive every toggle
    pinned = serving.GeometryServer(backend="ref", min_len=16,
                                    waste_cap=0.5)
    autotune.set_enabled(True)
    pinned.serve(reqs)
    assert (pinned.min_len, pinned.waste_cap) == (16, 0.5)


def test_ref_backend_pins_kernel_winners_to_default(tuning_state):
    """The ref backend never reads the launch knobs, so an empirical
    search there would cache timer noise: the tuners must pin the winner
    to the default and time nothing else."""
    rep = search.tune_chain("chain_apply", "ref", n_points=256, iters=1)
    assert len(rep.trials) == 1                  # only the default ran
    assert rep.winner.key_fields() == \
        tcache.DEFAULTS["chain_apply"].key_fields()
    rep = search.tune_rmsnorm("ref", m=32, n=64, iters=1)
    assert len(rep.trials) == 1
    # an injected measure (cost-model-only tuning) still searches
    rep = search.tune_chain("chain_apply", "ref", n_points=256,
                            measure=_fake_measure)
    assert len(rep.trials) > 1


def test_workload_seed_end_to_end(tuning_state):
    """Same seed -> bit-identical request mix (chains fold identically,
    points match bitwise); different seeds -> different mixes."""
    a = workload.random_workload(seed=99, n_requests=12, max_points=64)
    b = workload.random_workload(seed=99, n_requests=12, max_points=64)
    c = workload.random_workload(seed=100, n_requests=12, max_points=64)
    for (ca, pa), (cb, pb) in zip(a, b):
        assert ca.structure == cb.structure
        np.testing.assert_array_equal(pa, pb)
        for fa, fb in zip(ca.fold(), cb.fold()):
            np.testing.assert_array_equal(fa, fb)
    assert any(pa.shape != pc.shape or not np.array_equal(pa, pc)
               for (_, pa), (_, pc) in zip(a, c))
    with pytest.raises(ValueError):
        workload.random_workload(n_requests=4)
    with pytest.raises(ValueError):
        workload.random_workload(np.random.default_rng(0), 4, seed=1)
