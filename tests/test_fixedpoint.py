"""Fixed-point (Qm.n) lane tests: the numpy Q oracle vs the jnp ref twin
vs the Pallas kernels (bit-exact everywhere -- integer arithmetic), the
M1-emulator parity on the paper's Composite I/II programs, the per-chain
quantisation error bound (hypothesis-guarded property tests plus a
deterministic seeded sweep), and the lane end-to-end through the chain
compiler and the serving engine (where packed-vs-apply equality is
BITWISE, a stronger contract than the float lane's 1-ULP one).

``hypothesis`` is an OPTIONAL dependency (see tests/README.md): the
property tests below are skipped without it; the seeded sweeps of the
same invariants always run.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dep -- skip, don't fail
    HAVE_HYPOTHESIS = False

    class _NoStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NoStrategies()

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (optional dep)")(f)

from repro import kernels, quantize, serving
from repro.core import transform_chain as tc
from repro.core.morphosys import programs
from repro.kernels import opcount
from repro.kernels.fixedpoint import ref as qref
from repro.quantize import Q8_7, Q15_0
from repro.serving import workload

RNG = np.random.default_rng(1904)

AFFINE_TEMPLATES = workload.AFFINE_TEMPLATES


def random_affine_chain(rng):
    dim, kinds = AFFINE_TEMPLATES[int(rng.integers(len(AFFINE_TEMPLATES)))]
    return workload.chain_for(rng, dim, kinds)


# ---------------------------------------------------------------------------
# formats + converters
# ---------------------------------------------------------------------------

class TestQFormat:
    def test_parse_names(self):
        fmt = quantize.as_qformat("q8.7")
        assert (fmt.m, fmt.n, fmt.name, fmt.scale) == (8, 7, "q8.7", 128)
        assert quantize.as_qformat(fmt) is fmt
        assert quantize.as_qformat("q15.0").n == 0

    @pytest.mark.parametrize("bad", ["q8.8", "q9.7", "float32", "q-1.16",
                                     "8.7", 87, None])
    def test_rejects_non_formats(self, bad):
        assert not quantize.is_qformat(bad)
        with pytest.raises(ValueError):
            quantize.as_qformat(bad)

    def test_quantize_roundtrip_exact_on_grid(self):
        # values on the Qm.n grid survive a quantize/dequantize round trip
        words = RNG.integers(-(1 << 15), 1 << 15, 256).astype(np.int16)
        vals = Q8_7.dequantize(words)
        assert (Q8_7.quantize(vals) == words).all()

    def test_quantize_saturates(self):
        assert Q8_7.quantize(1e6) == 32767
        assert Q8_7.quantize(-1e6) == -32768

    def test_jnp_quantizer_matches_numpy(self):
        x = RNG.uniform(-300, 300, 512).astype(np.float32)
        assert (np.asarray(Q8_7.quantize_jnp(x)) == Q8_7.quantize(x)).all()


# ---------------------------------------------------------------------------
# kernel bit-exactness vs the numpy Q oracle
# ---------------------------------------------------------------------------

def _rand_words(shape, rng=RNG):
    return rng.integers(-(1 << 15), 1 << 15, shape).astype(np.int16)


class TestKernelsBitExact:
    """Every execution path of the lane computes the SAME int16 words:
    int32 MAC + one rounding shift + wrap is exact and order-independent,
    so numpy oracle == jnp ref == Pallas (interpret) bit-for-bit --
    including full-range inputs where the arithmetic wraps."""

    @pytest.mark.parametrize("d", [2, 3])
    @pytest.mark.parametrize("n_frac", [0, 7])
    def test_diag_paths_agree(self, d, n_frac):
        p = _rand_words((137, d))
        s, t = _rand_words(d), _rand_words(d)
        want = qref.np_chain_diag_q(p, s, t, n_frac)
        for backend in ("ref", "interpret"):
            got = np.asarray(kernels.chain_diag_q(
                jnp.asarray(p), s, t, n_frac=n_frac, backend=backend))
            np.testing.assert_array_equal(got, want, err_msg=backend)

    @pytest.mark.parametrize("d", [2, 3])
    @pytest.mark.parametrize("n_frac", [0, 7])
    def test_matrix_paths_agree(self, d, n_frac):
        p = _rand_words((91, d))
        a, t = _rand_words((d, d)), _rand_words(d)
        want = qref.np_chain_matrix_q(p, a, t, n_frac)
        for backend in ("ref", "interpret"):
            got = np.asarray(kernels.chain_apply_q(
                jnp.asarray(p), a, t, n_frac=n_frac, backend=backend))
            np.testing.assert_array_equal(got, want, err_msg=backend)

    @pytest.mark.parametrize("backend", ["ref", "interpret"])
    def test_batch_equals_per_request(self, backend):
        b, lpad, d = 6, 24, 3
        pts3 = _rand_words((b, lpad, d))
        a, t = _rand_words((b, d, d)), _rand_words((b, d))
        batched = np.asarray(kernels.chain_apply_batch_q(
            jnp.asarray(pts3), a, t, n_frac=7, backend=backend))
        for i in range(b):
            np.testing.assert_array_equal(
                batched[i], qref.np_chain_matrix_q(pts3[i], a[i], t[i], 7))
        s = _rand_words((b, d))
        batched = np.asarray(kernels.chain_diag_batch_q(
            jnp.asarray(pts3), s, t, n_frac=7, backend=backend))
        for i in range(b):
            np.testing.assert_array_equal(
                batched[i], qref.np_chain_diag_q(pts3[i], s[i], t[i], 7))

    def test_rejects_unquantised_operands(self):
        with pytest.raises(TypeError, match="int16"):
            kernels.chain_diag_q(jnp.ones((4, 2), jnp.float32),
                                 jnp.ones(2), jnp.ones(2), n_frac=7)


# ---------------------------------------------------------------------------
# M1 emulator parity: the paper's Composite I/II programs
# ---------------------------------------------------------------------------

class TestEmulatorParity:
    """At n = 0 the lane IS the emulator's integer datapath (int16
    wrap-around is a ring homomorphism: accumulating in int32 and
    wrapping once equals the M1 ALU's per-step wrap), so the Composite
    I/II outputs match EXACTLY; with fraction bits the lane's single
    requantising shift relates it to the raw emulator accumulator by an
    exact integer identity, asserted below."""

    def test_composite_i_exact_q0(self):
        # Composite I: scaling then translation, q = c*u + v -- run as
        # the two chained M1 routines (Tables 1-2) on one 64-vector
        rng = np.random.default_rng(41)
        u = rng.integers(-30000, 30000, 64).astype(np.int16)
        v2 = rng.integers(-30000, 30000, 2).astype(np.int16)
        c = 5
        scaled = programs.run_scaling(u, c)
        emu = programs.run_translation(scaled.values, np.tile(v2, 32)).values
        chain = (tc.TransformChain.identity(2)
                 .scale(float(c)).translate(float(v2[0]), float(v2[1])))
        for backend in ("ref", "interpret"):
            ours = np.asarray(chain.apply(
                jnp.asarray(u.reshape(32, 2).astype(np.float32)),
                backend=backend, dtype="q15.0"))
            np.testing.assert_array_equal(ours.reshape(-1), emu,
                                          err_msg=backend)

    @pytest.mark.parametrize("theta", [0.35, -1.1, 2.4])
    def test_composite_ii_exact_q0(self, theta):
        # Composite II: 2x2 fixed-point rotation of 8 points (the
        # paper's 16-element case), integer coefficients
        c = int(np.round(np.cos(theta) * 127))
        s = int(np.round(np.sin(theta) * 127))
        rng = np.random.default_rng(int(abs(theta) * 100))
        pts = rng.integers(-90, 91, (2, 8)).astype(np.int16)
        emu = programs.run_rotation_points((c, s), pts).values
        # emulator [[c,-s],[s,c]] @ column-points == row-points @
        # [[c,s],[-s,c]] (same convention note as the Q7 cross-check)
        chain = tc.TransformChain.identity(2).matrix(
            np.array([[c, s], [-s, c]], np.float32))
        for backend in ("ref", "interpret"):
            ours = np.asarray(chain.apply(
                jnp.asarray(pts.T.astype(np.float32)),
                backend=backend, dtype="q15.0")).T
            np.testing.assert_array_equal(ours, emu, err_msg=backend)

    @pytest.mark.parametrize("theta", [0.35, -1.1, 2.4])
    def test_composite_ii_q8_7_shift_identity(self, theta):
        # with fraction bits: the lane's output is EXACTLY the emulator's
        # raw Q14 accumulator put through the one requantising shift
        # (no wrap here: |coef| <= 127, |word| <= 127 -> |acc| < 2^15)
        cq = int(np.round(np.cos(theta) * 128))
        sq = int(np.round(np.sin(theta) * 128))
        assert max(abs(cq), abs(sq)) <= 127   # 8-bit context immediates
        rng = np.random.default_rng(int(abs(theta) * 100) + 1)
        words = rng.integers(-127, 128, (2, 8)).astype(np.int16)
        emu = programs.run_rotation_points((cq, sq), words).values
        chain = tc.TransformChain.identity(2).matrix(
            np.array([[cq, sq], [-sq, cq]], np.float32) / 128.0)
        ours = np.asarray(chain.apply(jnp.asarray(words.T), backend="ref",
                                      dtype="q8.7")).T
        np.testing.assert_array_equal(
            ours.astype(np.int32), (emu.astype(np.int32) + 64) >> 7)


# ---------------------------------------------------------------------------
# the per-chain quantisation error bound
# ---------------------------------------------------------------------------

def _assert_bound_holds(chain, pts, fmt):
    """The lane's dequantised result sits within ``error_bound`` of the
    exact (float64) evaluation of the float32 fold, whenever ``fits``."""
    kind = chain.plan_kind
    folded = chain.fold()
    x_max = float(np.abs(pts).max())
    if not quantize.fits(folded, kind, fmt, x_max):
        return False
    got = np.asarray(chain.apply(jnp.asarray(pts), backend="ref",
                                 dtype=fmt.name))
    if kind == "diag":
        s, t = folded
        exact = pts.astype(np.float64) * s.astype(np.float64) \
            + t.astype(np.float64)
    else:
        a, t = folded
        exact = pts.astype(np.float64) @ a.astype(np.float64) \
            + t.astype(np.float64)
    bound = quantize.error_bound(folded, kind, fmt, x_max)
    assert (np.abs(got - exact) <= bound).all(), (
        np.abs(got - exact).max(axis=0), bound)
    return True


class TestErrorBound:
    def test_seeded_sweep_2d_3d(self):
        rng = np.random.default_rng(7)
        checked = 0
        for i in range(60):
            dim, kinds = AFFINE_TEMPLATES[i % len(AFFINE_TEMPLATES)]
            chain = workload.chain_for(rng, dim, kinds)
            pts = rng.uniform(-4, 4, (int(rng.integers(1, 80)),
                                      dim)).astype(np.float32)
            checked += _assert_bound_holds(chain, pts, Q8_7)
        assert checked >= 40          # fits() must not be vacuous

    @pytest.mark.skipif(not HAVE_HYPOTHESIS,
                        reason="hypothesis not installed (optional dep)")
    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 3]),
           st.integers(1, 5), st.integers(1, 48))
    def test_property_random_chains(self, seed, dim, length, n_points):
        rng = np.random.default_rng(seed)
        prims = "".join(rng.choice(list("TSRAM"), length))
        chain = workload.chain_for(rng, dim, prims)
        pts = rng.uniform(-4, 4, (n_points, dim)).astype(np.float32)
        _assert_bound_holds(chain, pts, Q8_7)

    def test_bound_generalises_q7_rotation_bound(self):
        # the historical Q7 cross-check bound (0.5*(|x|+|y|)/127) has the
        # same shape as error_bound's matrix form: half an ulp times the
        # coefficient-column mass plus the input mass
        chain = tc.TransformChain.identity(2).rotate(0.3)
        bound = quantize.error_bound(chain.fold(), "matrix", Q8_7, 90.0)
        # rows of a rotation have unit mass; d*x_max dominates
        assert (bound > 0.5 * 90.0 / 128).all()
        assert (bound < 2.0 * (90.0 + 2) / 128).all()

    def test_fits_rejects_overflow(self):
        chain = tc.TransformChain.identity(2).scale(200.0).translate(200.0)
        assert not quantize.fits(chain.fold(), "diag", Q8_7, 4.0)
        assert quantize.fits(chain.fold(), "diag", Q15_0, 4.0)


# ---------------------------------------------------------------------------
# the lane through the chain compiler
# ---------------------------------------------------------------------------

class TestChainCompilerLane:
    def test_apply_matches_oracle_bitwise(self):
        rng = np.random.default_rng(17)
        for _ in range(8):
            chain = random_affine_chain(rng)
            pts = rng.uniform(-3, 3, (50, chain.dim)).astype(np.float32)
            words = Q8_7.quantize(pts)
            got = np.asarray(chain.apply(jnp.asarray(words), backend="ref",
                                         dtype="q8.7"))
            folded_q = quantize.quantize_fold(chain.fold(), chain.plan_kind,
                                              Q8_7)
            if chain.plan_kind == "diag":
                want = qref.np_chain_diag_q(words, *folded_q, 7)
            else:
                want = qref.np_chain_matrix_q(words, *folded_q, 7)
            np.testing.assert_array_equal(got, want)

    def test_float_in_float32_out_int16_in_int16_out(self):
        chain = tc.TransformChain.identity(2).scale(1.5).translate(0.5)
        pts = RNG.uniform(-2, 2, (9, 2)).astype(np.float32)
        out_f = chain.apply(jnp.asarray(pts), backend="ref", dtype="q8.7")
        assert np.asarray(out_f).dtype == np.float32
        out_q = chain.apply(jnp.asarray(Q8_7.quantize(pts)), backend="ref",
                            dtype="q8.7")
        assert np.asarray(out_q).dtype == np.int16
        np.testing.assert_array_equal(Q8_7.quantize(np.asarray(out_f)),
                                      np.asarray(out_q))

    def test_plan_cache_no_retrace(self):
        chain = tc.TransformChain.identity(3).scale(1.1).rotate(0.4, axis=0)
        pts = RNG.uniform(-2, 2, (32, 3)).astype(np.float32)
        tc.reset_stats()
        chain.apply(jnp.asarray(pts), backend="ref", dtype="q8.7")
        assert tc.stats["compiles"] == 1
        first_traces = tc.stats["traces"]
        # same structure, fresh parameters: cache hit, no retrace
        chain2 = tc.TransformChain.identity(3).scale(0.7).rotate(1.2, axis=0)
        chain2.apply(jnp.asarray(pts), backend="ref", dtype="q8.7")
        assert tc.stats["compiles"] == 1 and tc.stats["hits"] >= 1
        assert tc.stats["traces"] == first_traces
        # the float lane compiles its OWN plan for the same structure
        chain.apply(jnp.asarray(pts), backend="ref")
        assert tc.stats["compiles"] == 2

    def test_projective_rejected_everywhere(self):
        proj = (tc.TransformChain.identity(3)
                .projective(np.eye(4, dtype=np.float32)).cull())
        pts = RNG.uniform(-1, 1, (5, 3)).astype(np.float32)
        with pytest.raises(ValueError, match="fixed-point"):
            proj.apply(jnp.asarray(pts), dtype="q8.7")
        with pytest.raises(ValueError, match="fixed-point"):
            proj.project(jnp.asarray(pts), dtype="q8.7")
        srv = serving.GeometryServer(backend="ref")
        with pytest.raises(ValueError, match="fixed-point"):
            srv.submit(proj, pts, qformat="q8.7")
        # affine chains project trivially on the q lane: mask all-True
        aff = tc.TransformChain.identity(3).scale(2.0)
        out, mask = aff.project(jnp.asarray(pts), backend="ref",
                                dtype="q8.7")
        assert mask.all() and out.shape == pts.shape

    def test_traced_params_rejected(self):
        import jax
        pts = jnp.zeros((4, 2), jnp.float32)

        def f(theta):
            c = tc.TransformChain.identity(2).rotate(theta)
            return c.apply(pts, dtype="q8.7").sum()

        with pytest.raises(NotImplementedError):
            jax.jit(f)(jnp.float32(0.3))

    def test_byte_accounting_halves(self):
        chain = tc.TransformChain.identity(2).scale(1.2).rotate(0.5)
        pts = RNG.uniform(-2, 2, (256, 2)).astype(np.float32)
        with opcount.counting() as rec_f:
            chain.apply(jnp.asarray(pts), backend="ref")
        with opcount.counting() as rec_q:
            chain.apply(jnp.asarray(pts), backend="ref", dtype="q8.7")
        (f_name, f_bytes), = rec_f
        (q_name, q_bytes), = rec_q
        assert f_name == "chain_fused_matrix"
        assert q_name == "chain_fused_matrix_q"
        assert q_bytes * 2 == f_bytes
        assert f_bytes == opcount.fused_chain_bytes(256, 2, kind="matrix")
        assert q_bytes == opcount.fused_chain_bytes(256, 2, kind="matrix",
                                                    itemsize=2)


# ---------------------------------------------------------------------------
# the lane through the serving engine
# ---------------------------------------------------------------------------

class TestServingLane:
    def test_packed_equals_apply_bitwise(self):
        # integer arithmetic: the q lane's packed-vs-apply equality is
        # EXACT on every plan kind (the float lane's 1-ULP matrix-plan
        # exception does not exist here)
        reqs = workload.random_workload(seed=23, n_requests=24,
                                        max_points=96,
                                        templates=AFFINE_TEMPLATES)
        srv = serving.GeometryServer(backend="ref")
        results = srv.serve(reqs, qformat="q8.7")
        for (chain, pts), got in zip(reqs, results):
            want = np.asarray(chain.apply(jnp.asarray(pts), backend="ref",
                                          dtype="q8.7"))
            np.testing.assert_array_equal(got, want)
            assert got.dtype == np.float32

    def test_mixed_submissions_share_bucket(self):
        chain = tc.TransformChain.identity(2).scale(1.3).translate(0.5)
        pts = RNG.uniform(-2, 2, (20, 2)).astype(np.float32)
        srv = serving.GeometryServer(backend="ref")
        serving.reset_stats()
        srv.submit(chain, pts, qformat="q8.7")
        srv.submit(chain, Q8_7.quantize(pts), qformat="q8.7")
        out_f, out_q = srv.flush()
        assert serving.stats["launches"] == 1
        assert out_f.dtype == np.float32 and out_q.dtype == np.int16
        np.testing.assert_array_equal(Q8_7.quantize(out_f), out_q)

    def test_q_and_float_lanes_bucket_separately(self):
        chain = tc.TransformChain.identity(2).scale(1.3)
        pts = RNG.uniform(-2, 2, (16, 2)).astype(np.float32)
        srv = serving.GeometryServer(backend="ref")
        serving.reset_stats()
        srv.submit(chain, pts)
        srv.submit(chain, pts, qformat="q8.7")
        srv.flush()
        assert serving.stats["launches"] == 2
        assert serving.stats["buckets"] == 2

    def test_packed_byte_accounting_uses_2byte_words(self):
        chain = tc.TransformChain.identity(2).scale(1.3).rotate(0.2)
        pts = RNG.uniform(-2, 2, (16, 2)).astype(np.float32)
        srv = serving.GeometryServer(backend="ref")
        with opcount.counting() as rec:
            srv.submit(chain, pts, qformat="q8.7")
            srv.flush()
        (name, nbytes), = [r for r in rec if r[0].startswith("serve_")]
        lpad = serving.padded_length(16, min_len=srv.min_len,
                                     waste_cap=srv.waste_cap)
        assert nbytes == opcount.packed_chain_bytes(1, lpad, 2, itemsize=2,
                                                    kind="matrix")

    def test_identity_passes_and_empty_rejects(self):
        """PR 6: the q lane shares the submit boundary -- identity
        requests pass through, empty ones raise the typed error."""
        srv = serving.GeometryServer(backend="ref")
        pts = RNG.uniform(-1, 1, (4, 2)).astype(np.float32)
        t0 = srv.submit(tc.TransformChain.identity(2), pts, qformat="q8.7")
        with pytest.raises(serving.errors.EmptyPointsError):
            srv.submit(tc.TransformChain.identity(2).scale(2.0),
                       np.zeros((0, 2), np.float32), qformat="q8.7")
        res = srv.flush()
        np.testing.assert_array_equal(res[t0], pts)
        assert len(res) == 1


# ---------------------------------------------------------------------------
# graphics: affine viewing chains quantise, projective ones reject
# ---------------------------------------------------------------------------

class TestGraphicsLane:
    def test_affine_viewing_chain_quantises(self):
        from repro import graphics
        cam = graphics.Camera(eye=(0.0, 0.0, 5.0), target=(0.0, 0.0, 0.0))
        chain = graphics.viewing_chain(
            3, model=tc.TransformChain.identity(3).scale(0.5),
            camera=cam, projection=False, cull=False)
        assert not chain.is_projective and chain.plan_kind == "matrix"
        pts = RNG.uniform(-1, 1, (24, 3)).astype(np.float32)
        got = np.asarray(chain.apply(jnp.asarray(pts), backend="ref",
                                     dtype="q8.7"))
        folded = chain.fold()
        bound = quantize.error_bound(folded, "matrix", Q8_7, 1.0)
        exact = pts.astype(np.float64) @ folded[0].astype(np.float64) \
            + folded[1].astype(np.float64)
        assert (np.abs(got - exact) <= bound).all()

    def test_projective_viewing_chain_rejects(self):
        from repro import graphics
        cam = graphics.Camera(eye=(0.0, 0.0, 5.0), target=(0.0, 0.0, 0.0))
        chain = graphics.viewing_chain(3, camera=cam)
        pts = RNG.uniform(-1, 1, (8, 3)).astype(np.float32)
        with pytest.raises(ValueError, match="fixed-point"):
            chain.apply(jnp.asarray(pts), dtype="q8.7")


# ---------------------------------------------------------------------------
# autotune integration
# ---------------------------------------------------------------------------

class TestAutotuneIntegration:
    def test_defaults_exist_for_q_kernels(self):
        from repro.autotune import cache as tcache
        for k in ("chain_diag_q", "chain_apply_q", "chain_diag_batch_q",
                  "chain_apply_batch_q"):
            assert k in tcache.TUNABLE_KERNELS
            cfg = tcache.config_for(k, "ref", "q8.7", 1024)
            assert cfg.kernel == k and cfg.source == "default"

    def test_cost_model_halves_bytes(self):
        from repro.autotune import costmodel
        f32 = costmodel.chain_cost(4096, 3, "matrix")
        q = costmodel.chain_cost(4096, 3, "matrix_q")
        assert q.hbm_bytes * 2 == f32.hbm_bytes
        assert q.kernel == "chain_apply_q"
        pf = costmodel.packed_chain_cost(8, 64, 3, "diag")
        pq = costmodel.packed_chain_cost(8, 64, 3, "diag_q")
        assert pq.hbm_bytes * 2 == pf.hbm_bytes
        assert pq.kernel == "chain_diag_batch_q"

    def test_committed_cache_covers_q_lane(self):
        from repro.autotune import cache as tcache
        committed = tcache.TuningCache.load(tcache.DEFAULT_CACHE_PATH)
        assert committed.get("chain_diag_q", "ref", "q8.7", 2048) is not None
        assert committed.get("chain_apply_q", "ref", "q8.7",
                             2048) is not None
