"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests on the
transform-engine invariants.

``hypothesis`` is an OPTIONAL dependency: when it is not installed the
property tests below are skipped (deterministic seeded variants of the
same invariants run in ``test_transform_chain.py``) and everything else
in this module still collects and runs.  See ``tests/README.md``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dep -- skip, don't fail
    HAVE_HYPOTHESIS = False

    class _NoStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NoStrategies()

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (optional dep)")(f)

from repro import kernels
from repro.core import transform_engine as te
from repro.kernels.flash_attention import attention_reference

RNG = np.random.default_rng(42)


def randn(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# affine family (paper 5.1-5.2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (7, 130), (256, 512),
                                   (3, 5, 100), (1, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_affine_matches_ref(shape, dtype):
    x = randn(shape, dtype)
    s = randn((shape[-1],), dtype)
    t = randn((shape[-1],), dtype)
    got = kernels.affine(x, s, t, backend="interpret")
    exp = kernels.affine(x, s, t, backend="ref")
    np.testing.assert_allclose(np.float32(got), np.float32(exp), **tol(dtype))


@pytest.mark.parametrize("shape", [(64, 128), (33, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vecadd_matches_ref(shape, dtype):
    x, z = randn(shape, dtype), randn(shape, dtype)
    got = kernels.vecadd(x, z, backend="interpret")
    np.testing.assert_allclose(np.float32(got), np.float32(x + z), **tol(dtype))


def test_scale_is_affine_with_zero_shift():
    x = randn((16, 128))
    s = randn((128,))
    np.testing.assert_allclose(
        kernels.scale(x, s, backend="interpret"),
        kernels.affine(x, s, jnp.zeros(()), backend="interpret"))


# ---------------------------------------------------------------------------
# matmul (paper 5.3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mkn", [(17, 100, 33), (128, 128, 128),
                                 (256, 1024, 512), (1, 8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_matches_ref(mkn, dtype):
    m, k, n = mkn
    x, y = randn((m, k), dtype), randn((k, n), dtype)
    got = kernels.matmul(x, y, backend="interpret", out_dtype=jnp.float32)
    exp = kernels.matmul(x, y, backend="ref", out_dtype=jnp.float32)
    np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-4)


def test_matmul_fp32_accumulation():
    """bf16 inputs accumulate in fp32 (matches the oracle, not bf16 accum)."""
    k = 4096
    x = jnp.ones((8, k), jnp.bfloat16) * 0.01
    y = jnp.ones((k, 128), jnp.bfloat16) * 0.01
    got = kernels.matmul(x, y, backend="interpret", out_dtype=jnp.float32)
    assert np.allclose(got, k * 0.01 * 0.01, rtol=2e-2)


# ---------------------------------------------------------------------------
# rope (rotation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 100, 128), (2, 17, 64), (1, 8, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rope_matches_ref(shape, dtype):
    x = randn(shape, dtype)
    cos, sin = kernels.rope_tables(jnp.arange(shape[-2]), shape[-1])
    got = kernels.rope(x, cos, sin, backend="interpret")
    exp = kernels.rope(x, cos, sin, backend="ref")
    np.testing.assert_allclose(np.float32(got), np.float32(exp), **tol(dtype))


def test_rope_preserves_norm():
    """Rotation is orthogonal: per-pair norms are invariant."""
    x = randn((2, 64, 128))
    cos, sin = kernels.rope_tables(jnp.arange(64), 128)
    y = kernels.rope(x, cos, sin, backend="interpret")
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(33, 1600), (100, 768), (8, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = randn(shape, dtype)
    g = randn((shape[-1],))
    got = kernels.rmsnorm(x, g, backend="interpret")
    exp = kernels.rmsnorm(x, g, backend="ref")
    np.testing.assert_allclose(np.float32(got), np.float32(exp), **tol(dtype))


# ---------------------------------------------------------------------------
# flash attention (composite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [
    dict(b=2, hq=4, hkv=4, s=256, t=256),
    dict(b=1, hq=8, hkv=2, s=130, t=130),                 # GQA + ragged
    dict(b=1, hq=2, hkv=2, s=384, t=384, window=128),     # SWA
    dict(b=2, hq=4, hkv=2, s=1, t=512, q_offset=511),     # decode
    dict(b=1, hq=2, hkv=1, s=64, t=256, q_offset=192),    # chunked prefill
])
def test_flash_matches_oracle(case):
    window = case.get("window")
    q_offset = case.get("q_offset", 0)
    q = randn((case["b"], case["hq"], case["s"], 64))
    k = randn((case["b"], case["hkv"], case["t"], 64))
    v = randn((case["b"], case["hkv"], case["t"], 64))
    got = kernels.attention(q, k, v, causal=True, window=window,
                            q_offset=q_offset, backend="interpret")
    exp = attention_reference(q, k, v, scale=64 ** -0.5, causal=True,
                              window=window, q_offset=q_offset)
    np.testing.assert_allclose(got, exp, atol=1e-5)
    blockwise = kernels.attention(q, k, v, causal=True, window=window,
                                  q_offset=q_offset, backend="ref",
                                  block_kv=128)
    np.testing.assert_allclose(blockwise, exp, atol=1e-5)


def test_flash_bf16():
    q = randn((1, 4, 128, 64), jnp.bfloat16)
    k = randn((1, 2, 128, 64), jnp.bfloat16)
    v = randn((1, 2, 128, 64), jnp.bfloat16)
    got = kernels.attention(q, k, v, backend="interpret")
    exp = attention_reference(q, k, v, scale=64 ** -0.5)
    np.testing.assert_allclose(np.float32(got), np.float32(exp), atol=2e-2)


# ---------------------------------------------------------------------------
# property tests (hypothesis): transform-engine invariants
# ---------------------------------------------------------------------------

coords = st.floats(-100.0, 100.0, allow_nan=False, width=32)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(coords, coords), min_size=1, max_size=32),
       st.floats(-3.0, 3.0, allow_nan=False, width=32))
def test_rotation_preserves_distances(pts, theta):
    p = jnp.asarray(np.array(pts, np.float32))
    q = te.rotate(p, theta)
    np.testing.assert_allclose(
        jnp.linalg.norm(q, axis=-1), jnp.linalg.norm(p, axis=-1),
        rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(coords, coords), min_size=1, max_size=16),
       st.tuples(coords, coords), st.tuples(coords, coords))
def test_translate_composes_additively(pts, t1, t2):
    p = jnp.asarray(np.array(pts, np.float32))
    a = te.translate(te.translate(p, jnp.asarray(t1)), jnp.asarray(t2))
    b = te.translate(p, jnp.asarray(t1) + jnp.asarray(t2))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(coords, coords), min_size=1, max_size=16),
       st.floats(0.1, 4.0), st.floats(0.1, 4.0),
       st.floats(-3.0, 3.0, allow_nan=False, width=32),
       st.tuples(coords, coords))
def test_composite_matches_sequential(pts, sx, sy, theta, t):
    """The paper's 'General Composite Algorithm': one homogeneous matmul
    equals the sequential primitive applications."""
    p = jnp.asarray(np.array(pts, np.float32))
    tf = (te.Transform2D.identity()
          .then_scale(sx, sy).then_rotate(theta).then_translate(*t))
    via_matrix = tf.apply(p)
    via_seq = te.translate(
        te.rotate(te.scale(p, jnp.asarray([sx, sy], jnp.float32)), theta),
        jnp.asarray(t))
    np.testing.assert_allclose(via_matrix, via_seq, rtol=1e-3, atol=1e-2)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5), st.integers(1, 4))
def test_affine_fusion_equals_two_pass(rows8, cols128):
    """Fused y = s*x + t == scale-then-translate (two frame-buffer passes
    on the M1, one fused pass here)."""
    m, n = rows8 * 8, cols128 * 128
    x = randn((m, n))
    s = randn((n,))
    t = randn((n,))
    fused = kernels.affine(x, s, t, backend="interpret")
    two_pass = kernels.translate(kernels.scale(x, s, backend="interpret"),
                                 t, backend="interpret")
    np.testing.assert_allclose(fused, two_pass, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# beyond-paper optimized paths (EXPERIMENTS.md section Perf)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [
    dict(b=2, hq=4, hkv=2, s=512, win=128),
    dict(b=1, hq=5, hkv=5, s=384, win=128),     # heads not 2^k (hymba-like)
    dict(b=1, hq=2, hkv=1, s=300, win=128),     # ragged tail
])
def test_banded_swa_matches_oracle(case):
    from repro.kernels.flash_attention.ref import banded_swa_attention
    q = randn((case["b"], case["hq"], case["s"], 64))
    k = randn((case["b"], case["hkv"], case["s"], 64))
    v = randn((case["b"], case["hkv"], case["s"], 64))
    got = banded_swa_attention(q, k, v, scale=0.125, window=case["win"])
    exp = attention_reference(q, k, v, scale=0.125, causal=True,
                              window=case["win"])
    np.testing.assert_allclose(got, exp, atol=1e-5)


def test_banded_swa_grad_finite():
    from repro.kernels.flash_attention.ref import banded_swa_attention
    q = randn((1, 2, 256, 32))
    k = randn((1, 2, 256, 32))
    v = randn((1, 2, 256, 32))
    g = jax.grad(lambda qq: banded_swa_attention(
        qq, k, v, scale=0.17, window=128).sum())(q)
    assert bool(jnp.isfinite(g).all())


# ---------------------------------------------------------------------------
# SSD intra-chunk kernel (kernels/ssd)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dims", [(3, 16, 4, 8, 8), (2, 32, 5, 16, 8),
                                  (1, 64, 2, 32, 16)])
def test_ssd_intra_kernel_matches_ref(dims):
    from repro.kernels.ssd import ops as ssd_ops
    bc, lc, h, p, n = dims
    rng = np.random.default_rng(11)
    xdt = jnp.asarray(rng.standard_normal((bc, lc, h, p)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((bc, lc, n)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((bc, lc, n)) * 0.3, jnp.float32)
    cum = jnp.cumsum(
        -jnp.abs(jnp.asarray(rng.standard_normal((bc, lc, h)),
                             jnp.float32)) * 0.1, axis=1)
    y1, s1 = ssd_ops.ssd_intra(xdt, b, c, cum, backend="interpret")
    y2, s2 = ssd_ops.ssd_intra(xdt, b, c, cum, backend="ref")
    np.testing.assert_allclose(y1, y2, atol=1e-5)
    np.testing.assert_allclose(s1, s2, atol=1e-5)


def test_ssm_forward_kernel_backend_matches_ref_backend():
    """Full Mamba-2 layer: interpret-mode Pallas SSD == jnp SSD path."""
    from repro.kernels import dispatch
    from repro.models import ssm
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=64,
                      ssm_state=8, ssm_headdim=8, ssm_chunk=8,
                      dtype="float32")
    p = ssm.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32)) * 0.5
    y_ref = ssm.forward(p, x, cfg)
    with dispatch.use_backend("interpret"):
        y_krn = ssm.forward(p, x, cfg)
    np.testing.assert_allclose(np.float32(y_krn), np.float32(y_ref),
                               atol=1e-4)
