"""Projective graphics-pipeline tests: the homogeneous fold, the fused
``chain_project_*`` kernels against a numpy homogeneous oracle (bit-for-bit
on the ref backend), cull-mask edge cases (w <= 0, points exactly on
frustum planes), plan-cache no-retrace behaviour, the Camera/Viewport
pipeline semantics, and projective serving through the GeometryServer.

``hypothesis`` is an OPTIONAL dependency (see tests/README.md): the
property tests below are skipped without it; deterministic seeded sweeps
of the same invariants always run.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dep -- skip, don't fail
    HAVE_HYPOTHESIS = False

    class _NoStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NoStrategies()

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (optional dep)")(f)

from repro import graphics, kernels, serving
from repro.core import transform_chain as tc
from repro.kernels import opcount
from repro.serving import workload

RNG = np.random.default_rng(1904)


# ---------------------------------------------------------------------------
# oracles
# ---------------------------------------------------------------------------

def np_project(h, lo, hi, pts):
    """The numpy homogeneous oracle: q_h = [p, 1] @ H unrolled with the
    SAME accumulation order as the jnp ref (left fold over m, then the
    translation row), guarded divide, inclusive bounds.  float32
    throughout -- the ref backend must match this bit for bit."""
    h = np.asarray(h, np.float32)
    lo = np.asarray(lo, np.float32)
    hi = np.asarray(hi, np.float32)
    d = pts.shape[-1]
    pf = pts.astype(np.float32)
    cols = [sum(pf[..., m] * h[m, c] for m in range(d)) + h[d, c]
            for c in range(d)]
    w = sum(pf[..., m] * h[m, d] for m in range(d)) + h[d, d]
    w_ok = w > 0.0
    safe = np.where(w_ok, w, np.float32(1.0))
    v = np.stack([c / safe for c in cols], axis=-1).astype(np.float32)
    inside = w_ok & np.all((v >= lo) & (v <= hi), axis=-1)
    return v, inside


def sequential_oracle64(chain, pts):
    """Independent per-primitive float64 oracle: walk the chain on
    homogeneous (q, w) coordinates, testing cull primitives in their own
    coordinate space.  Returns (projected, inside, w) in float64."""
    d = chain.dim
    q = np.asarray(pts, np.float64)
    w = np.ones(q.shape[:-1], np.float64)
    inside = np.ones(q.shape[:-1], bool)
    for (kind, axis), val in zip(chain.kinds, chain.params):
        if kind == "T":
            q = q + w[..., None] * np.broadcast_to(
                np.asarray(val, np.float64), (d,))
        elif kind == "S":
            q = q * np.broadcast_to(np.asarray(val, np.float64), (d,))
        elif kind == "A":
            s = np.broadcast_to(np.asarray(val[0], np.float64), (d,))
            t = np.broadcast_to(np.asarray(val[1], np.float64), (d,))
            q = q * s + w[..., None] * t
        elif kind == "R":
            c, s = np.cos(float(val)), np.sin(float(val))
            if d == 2:
                r = np.array([[c, s], [-s, c]])
            else:
                r = np.eye(3)
                i, j = [(1, 2), (2, 0), (0, 1)][axis]
                r[i, i] = r[j, j] = c
                r[i, j], r[j, i] = s, -s
            q = q @ r
        elif kind == "M":
            m = np.asarray(val, np.float64)
            if m.shape == (d + 1, d + 1):
                q = q @ m[:d, :d] + w[..., None] * m[d, :d]
            else:
                q = q @ m
        elif kind == "P":
            m = np.asarray(val, np.float64)
            qh = np.concatenate([q, w[..., None]], axis=-1) @ m
            q, w = qh[..., :d], qh[..., d]
        else:                               # "C"
            lo = np.broadcast_to(np.asarray(val[0], np.float64), (d,))
            hi = np.broadcast_to(np.asarray(val[1], np.float64), (d,))
            ndc = q / np.where(w > 0, w, 1.0)[..., None]
            inside &= (w > 0) & np.all((ndc >= lo) & (ndc <= hi), axis=-1)
    inside &= w > 0
    return q / np.where(w > 0, w, 1.0)[..., None], inside, w


def random_projective_chain(rng, dim, length):
    """A random chain guaranteed projective: affine primitives plus at
    least one gentle projective matrix; an optional trailing cull (only
    T/S/A may follow it, per the fold's contract)."""
    chain = tc.TransformChain.identity(dim)
    p_at = int(rng.integers(0, length))
    for i in range(length):
        kind = "P" if i == p_at else \
            str(rng.choice(["T", "S", "R", "A", "M", "P"]))
        if kind == "T":
            chain = chain.translate(*rng.uniform(-2, 2, dim).tolist())
        elif kind == "S":
            chain = chain.scale(*rng.uniform(0.3, 1.8, dim).tolist())
        elif kind == "R":
            theta = float(rng.uniform(-np.pi, np.pi))
            chain = chain.rotate(theta) if dim == 2 else \
                chain.rotate(theta, axis=int(rng.integers(3)))
        elif kind == "A":
            chain = chain.affine(rng.uniform(0.3, 1.8, dim).tolist(),
                                 rng.uniform(-1, 1, dim).tolist())
        elif kind == "M":
            m = np.eye(dim + 1, dtype=np.float32)
            m[:dim, :dim] += rng.uniform(-0.3, 0.3, (dim, dim))
            m[dim, :dim] = rng.uniform(-1, 1, dim)
            chain = chain.matrix(m)
        else:
            m = np.eye(dim + 1, dtype=np.float32)
            m[:dim, :dim] += rng.uniform(-0.2, 0.2, (dim, dim))
            m[dim, :dim] = rng.uniform(-0.5, 0.5, dim)
            m[:dim, dim] = rng.uniform(-0.03, 0.03, dim)
            chain = chain.projective(m)
    if rng.random() < 0.5:
        chain = chain.cull(float(rng.uniform(-8, -3)),
                           float(rng.uniform(3, 8)))
        chain = chain.affine(rng.uniform(0.5, 1.5, dim).tolist(),
                             rng.uniform(-1, 1, dim).tolist())
    return chain


# ---------------------------------------------------------------------------
# fused == numpy homogeneous oracle, bit-for-bit on ref
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim", [2, 3])
@pytest.mark.parametrize("length", [1, 2, 4, 7])
def test_ref_matches_numpy_oracle_bitwise(dim, length):
    """The ref-backend kernel entry IS the numpy homogeneous oracle, bit
    for bit (the fold is shared numpy; the eager entry runs op-for-op
    what the oracle runs).  The jitted plan path (``chain.project``)
    additionally agrees to last-ULP scale -- XLA:CPU reserves per-program
    freedom in contracting multiply-adds (see the chain compiler's
    folding note), which is the repo-wide standing exception."""
    rng = np.random.default_rng(10 * dim + length)
    for _ in range(3):
        chain = random_projective_chain(rng, dim, length)
        n = int(rng.integers(1, 300))
        pts = rng.uniform(-1.5, 1.5, (n, dim)).astype(np.float32)
        exp, mexp = np_project(*chain.fold(), pts)
        got, mask = kernels.chain_project(jnp.asarray(pts), *chain.fold(),
                                          backend="ref")
        np.testing.assert_array_equal(np.asarray(got), exp)
        np.testing.assert_array_equal(np.asarray(mask), mexp)
        got_p, mask_p = chain.project(jnp.asarray(pts), backend="ref")
        np.testing.assert_allclose(np.asarray(got_p), exp,
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(mask_p), mexp)


@pytest.mark.parametrize("dim", [2, 3])
def test_fused_matches_float64_sequential_oracle(dim):
    """The fold itself is correct: an independent per-primitive float64
    walk agrees with the one-matrix fold (away from w ~ 0, where the
    float32 fold legitimately loses relative precision)."""
    rng = np.random.default_rng(77 + dim)
    for length in (2, 4, 6):
        chain = random_projective_chain(rng, dim, length)
        pts = rng.uniform(-1.5, 1.5, (123, dim)).astype(np.float32)
        got, mask = chain.project(jnp.asarray(pts), backend="ref")
        exp, mexp, w64 = sequential_oracle64(chain, pts)
        ok = np.abs(w64) > 0.2
        np.testing.assert_allclose(np.asarray(got)[ok], exp[ok],
                                   rtol=2e-4, atol=2e-4)
        far = np.abs(w64) > 1e-3            # mask can only flip at w ~ 0
        assert (np.asarray(mask) == mexp)[far].all()


@pytest.mark.parametrize("dim", [2, 3])
def test_interpret_kernel_matches_ref(dim):
    rng = np.random.default_rng(5 + dim)
    for length in (1, 3, 5):
        chain = random_projective_chain(rng, dim, length)
        for n in (1, 7, 129, 1000):
            pts = rng.uniform(-1.5, 1.5, (n, dim)).astype(np.float32)
            got_i, m_i = chain.project(jnp.asarray(pts), backend="interpret")
            got_r, m_r = chain.project(jnp.asarray(pts), backend="ref")
            np.testing.assert_allclose(np.asarray(got_i), np.asarray(got_r),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_array_equal(np.asarray(m_i), np.asarray(m_r))


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_batch_kernel_matches_per_request(backend):
    """chain_project_batch over a packed (B, L, d) batch reproduces each
    row's single-chain chain_project."""
    rng = np.random.default_rng(23)
    for d in (2, 3):
        bsz, l = 5, 40
        pts3 = rng.uniform(-1.5, 1.5, (bsz, l, d)).astype(np.float32)
        hs, los, his = [], [], []
        for _ in range(bsz):
            h, lo, hi = random_projective_chain(rng, d, 3).fold()
            hs.append(h), los.append(lo), his.append(hi)
        h3, lo2, hi2 = np.stack(hs), np.stack(los), np.stack(his)
        out, mask = kernels.chain_project_batch(
            jnp.asarray(pts3), h3, lo2, hi2, backend=backend)
        for b in range(bsz):
            exp, mexp = kernels.chain_project(
                jnp.asarray(pts3[b]), hs[b], los[b], his[b],
                backend=backend)
            np.testing.assert_allclose(np.asarray(out[b]), np.asarray(exp),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_array_equal(np.asarray(mask[b]),
                                          np.asarray(mexp))


@settings(max_examples=25, deadline=None)
@given(dim=st.sampled_from([2, 3]), length=st.integers(1, 6),
       seed=st.integers(0, 2 ** 31 - 1), n=st.integers(1, 200))
def test_hypothesis_fused_equals_numpy_oracle(dim, length, seed, n):
    rng = np.random.default_rng(seed)
    chain = random_projective_chain(rng, dim, length)
    pts = rng.uniform(-1.5, 1.5, (n, dim)).astype(np.float32)
    got, mask = kernels.chain_project(jnp.asarray(pts), *chain.fold(),
                                      backend="ref")
    exp, mexp = np_project(*chain.fold(), pts)
    np.testing.assert_array_equal(np.asarray(got), exp)
    np.testing.assert_array_equal(np.asarray(mask), mexp)


# ---------------------------------------------------------------------------
# cull-mask edge cases
# ---------------------------------------------------------------------------

def test_w_nonpositive_is_culled_and_finite():
    """Points behind the center of projection (w < 0) and AT it (w == 0)
    are masked out, and their coordinates stay finite (guarded divide)."""
    # w = z: the z coordinate is the homogeneous weight
    h = np.eye(4, dtype=np.float32)
    h[2, 3], h[3, 3] = 1.0, 0.0
    chain = tc.TransformChain.identity(3).projective(h)
    pts = np.array([[1.0, 2.0, 4.0],      # w = 4  -> inside
                    [1.0, 2.0, -1.0],     # w = -1 -> culled
                    [1.0, 2.0, 0.0]],     # w = 0 exactly -> culled
                   np.float32)
    out, mask = chain.project(jnp.asarray(pts), backend="ref")
    np.testing.assert_array_equal(np.asarray(mask), [True, False, False])
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out)[0], [0.25, 0.5, 1.0],
                               rtol=1e-6)
    out_i, mask_i = chain.project(jnp.asarray(pts), backend="interpret")
    np.testing.assert_array_equal(np.asarray(mask_i), [True, False, False])
    assert np.isfinite(np.asarray(out_i)).all()


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_points_on_frustum_planes_are_inside(backend):
    """The cull is inclusive: NDC exactly +-1 is inside; one ulp beyond
    is outside."""
    eps = np.float32(np.finfo(np.float32).eps)
    chain = tc.TransformChain.identity(2).cull(-1.0, 1.0)
    pts = np.array([[1.0, -1.0],          # both coords ON planes -> inside
                    [1.0 + 2 * eps, 0.0],  # just beyond +1 -> outside
                    [0.0, -1.0 - 2 * eps],  # just beyond -1 -> outside
                    [0.5, 0.5]], np.float32)
    out, mask = chain.project(jnp.asarray(pts), backend=backend)
    np.testing.assert_array_equal(np.asarray(mask),
                                  [True, False, False, True])
    # a cull-only chain projects through H = I: points pass unchanged
    np.testing.assert_array_equal(np.asarray(out), pts)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_mask_is_per_point_not_per_coordinate(backend):
    """One out-of-bounds coordinate culls the WHOLE point (the in-kernel
    group-AND across the point's d lanes)."""
    chain = tc.TransformChain.identity(3).cull(-1.0, 1.0)
    pts = np.array([[0.0, 0.0, 0.0],
                    [0.0, 5.0, 0.0],      # only y out of bounds
                    [0.0, 0.0, -5.0]],    # only z out of bounds
                   np.float32)
    _, mask = chain.project(jnp.asarray(pts), backend=backend)
    np.testing.assert_array_equal(np.asarray(mask), [True, False, False])


def test_cull_bounds_fold_through_viewport():
    """cull(-1, 1) followed by a viewport affine culls against the
    MAPPED bounds: the same points survive with and without the viewport
    suffix (negative scales flip the bounds correctly too)."""
    rng = np.random.default_rng(3)
    pts = rng.uniform(-2, 2, (200, 2)).astype(np.float32)
    base = tc.TransformChain.identity(2).scale(0.7, 1.3).cull(-1.0, 1.0)
    _, mask0 = base.project(jnp.asarray(pts), backend="ref")
    for s in ((8.0, 4.0), (-8.0, 4.0), (3.0, -2.0)):
        suff = base.affine(s, (1.0, -2.0))
        _, mask1 = suff.project(jnp.asarray(pts), backend="ref")
        np.testing.assert_array_equal(np.asarray(mask1), np.asarray(mask0))


def test_matrix_rejects_perspective_column():
    """A perspective matrix must go through projective(): matrix() would
    silently drop the perspective column (no divide), so the fold rejects
    a non-affine homogeneous matrix outright."""
    persp = graphics.perspective(np.pi / 3, 1.0, 0.5, 40.0)
    with pytest.raises(ValueError, match="projective"):
        tc.TransformChain.identity(3).matrix(persp).fold()
    with pytest.raises(ValueError, match="projective"):
        # same trap inside a projective chain's M primitive
        tc.TransformChain.identity(3).matrix(persp).cull().fold()
    # affine homogeneous matrices keep working through matrix()
    ok = tc.TransformChain.identity(3).matrix(graphics.look_at(
        (1.0, 2.0, 3.0), (0.0, 0.0, 0.0)))
    ok.fold()


def test_projected_mask_never_inherited_by_derived_arrays():
    """.mask describes exactly the array flush() returned: ANY derived
    array -- slice, transpose, reshape, reversal, fancy index (a shape
    check could not catch the same-shape reorderings) -- reads it as
    None instead of silently pairing points with another point's
    inside/outside flag."""
    res = serving.engine._projected(
        np.arange(18, dtype=np.float32).reshape(6, 3),
        np.array([1, 0, 1, 0, 1, 0], bool))
    assert res.mask is not None and res.mask.shape == (6,)
    assert res[:4].mask is None              # shorter slice
    assert res.T.mask is None                # transpose
    assert res.reshape(-1).mask is None      # reshape
    assert res[::-1].mask is None            # same-shape reordering
    assert res[np.argsort(res[:, 0])[::-1]].mask is None  # fancy index
    assert (res * 2).mask is None            # ufunc result


def test_fold_rejects_nonaffine_after_cull():
    base = tc.TransformChain.identity(2).cull()
    for bad in (base.rotate(0.3),
                base.matrix(np.eye(2, dtype=np.float32)),
                base.projective(np.eye(3, dtype=np.float32))):
        with pytest.raises(ValueError):
            bad.fold()
    with pytest.raises(ValueError):       # wrong projective matrix shape
        tc.TransformChain.identity(2).projective(np.eye(4)).fold()


# ---------------------------------------------------------------------------
# plan cache / API surface
# ---------------------------------------------------------------------------

def test_projective_plan_cache_no_retrace():
    tc.clear_plan_cache()
    tc.reset_stats()
    pts = jnp.asarray(RNG.standard_normal((50, 3)), jnp.float32)
    rng = np.random.default_rng(0)
    chain = random_projective_chain(rng, 3, 4)
    assert chain.plan_kind == "projective"
    chain.project(pts, backend="ref")
    assert tc.stats["compiles"] == 1 and tc.stats["traces"] == 1
    # same structure, same shape, repeated project/apply (apply shares
    # the plan with project): cache hits, no retrace
    chain.project(pts, backend="ref")
    chain.apply(pts, backend="ref")
    assert tc.stats["compiles"] == 1
    assert tc.stats["traces"] == 1, "seen structure+shape must not retrace"
    # new shape retraces once, no recompile
    chain.project(jnp.asarray(RNG.standard_normal((7, 3)), jnp.float32),
                  backend="ref")
    assert tc.stats["compiles"] == 1 and tc.stats["traces"] == 2


def test_apply_equals_project_points_and_affine_project_is_trivial():
    rng = np.random.default_rng(9)
    pts = jnp.asarray(rng.standard_normal((40, 2)), jnp.float32)
    proj = random_projective_chain(rng, 2, 3)
    np.testing.assert_array_equal(
        np.asarray(proj.apply(pts, backend="ref")),
        np.asarray(proj.project(pts, backend="ref")[0]))
    affine = tc.TransformChain.identity(2).scale(2.0).translate(1.0, -1.0)
    out, mask = affine.project(pts, backend="ref")
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(affine.apply(pts,
                                                          backend="ref")))
    assert np.asarray(mask).all()
    with pytest.raises(ValueError):
        proj.folded()                      # no (A, t) form


def test_traced_params_rejected_for_projective():
    import jax
    pts = jnp.asarray(RNG.standard_normal((8, 2)), jnp.float32)

    def f(theta):
        return (tc.TransformChain.identity(2).rotate(theta)
                .projective(np.eye(3, dtype=np.float32))
                .apply(pts)).sum()

    with pytest.raises(NotImplementedError):
        jax.grad(f)(0.3)


# ---------------------------------------------------------------------------
# one-launch / byte accounting (the acceptance claim)
# ---------------------------------------------------------------------------

def test_projective_chain_is_one_launch_and_fewer_bytes():
    """A composite chain ending in a perspective projection executes as
    ONE fused kernel launch; staged per-primitive dispatch pays one
    launch and one HBM round-trip per stage."""
    n = 4096
    pts = jnp.asarray(RNG.standard_normal((n, 3)) * 0.5, jnp.float32)
    cam = graphics.Camera(eye=(2.0, 1.0, 4.0), near=0.5, far=30.0)
    chain = graphics.viewing_chain(
        model=tc.TransformChain.identity(3).rotate(0.4, axis="y")
        .scale(1.2).translate(0.1, 0.0, 0.0),
        camera=cam, viewport=graphics.Viewport(0, 0, 640, 480))
    singles = [tc.TransformChain(chain.dim, (ka,), (p,))
               for ka, p in zip(chain.kinds, chain.params)]
    with opcount.counting() as staged:
        q = pts
        for single in singles:
            q = single.apply(q, backend="ref")
    with opcount.counting() as fused:
        chain.project(pts, backend="ref")
    assert len(fused) == 1                 # the whole pipeline: one launch
    assert len(staged) == len(chain)       # one launch per stage
    (op, nbytes), = fused
    assert op == "chain_fused_projective"
    d = 3
    assert nbytes == 3 * pts.nbytes + 4 * ((d + 1) ** 2 + 2 * d)
    assert nbytes < opcount.total_bytes(staged)


def test_packed_projective_bytes_match_opcount():
    from repro.autotune import costmodel
    for bsz, lpad, d in ((8, 64, 2), (3, 128, 3)):
        est = costmodel.packed_chain_cost(bsz, lpad, d, "projective")
        assert est.hbm_bytes == opcount.packed_chain_bytes(
            bsz, lpad, d, kind="projective")
        assert est.kernel == "chain_project_batch"


# ---------------------------------------------------------------------------
# serving: projective buckets through the GeometryServer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_server_buckets_projective_chains_into_one_launch(backend):
    """Many requests sharing one viewing-chain structure = ONE launch,
    and every result carries the same mask per-request project returns."""
    serving.reset_stats()
    serving.clear_plan_cache()
    rng = np.random.default_rng(31)
    cam = graphics.Camera(eye=(0.0, 1.0, 5.0), near=0.5, far=25.0)
    reqs = []
    for _ in range(10):
        model = (tc.TransformChain.identity(3)
                 .rotate(float(rng.uniform(-1, 1)), axis="y")
                 .scale(float(rng.uniform(0.8, 1.2))))
        chain = graphics.viewing_chain(
            model=model, camera=cam,
            viewport=graphics.Viewport(0, 0, 64, 48))
        pts = rng.uniform(-1.5, 1.5,
                          (int(rng.integers(33, 64)), 3)).astype(np.float32)
        reqs.append((chain, pts))        # every length pads to lpad=64
    srv = serving.GeometryServer(backend=backend)
    outs = srv.serve(reqs)
    assert serving.stats["launches"] == 1
    assert srv.last_report[0].kind == "projective"
    for (chain, pts), out in zip(reqs, outs):
        assert isinstance(out, serving.Projected)
        exp, mexp = chain.project(jnp.asarray(pts), backend=backend)
        np.testing.assert_array_equal(np.asarray(out.mask),
                                      np.asarray(mexp))
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-5, atol=1e-5)


def test_server_mixed_affine_projective_workload_saves_launches():
    """The acceptance workload: a mixed affine+projective 64-request mix
    serves in far fewer launches than requests, projective buckets
    included."""
    serving.reset_stats()
    serving.clear_plan_cache()
    reqs = workload.random_workload(seed=2207, n_requests=64,
                                    max_points=512)
    n_proj = sum(1 for c, _ in reqs if c.is_projective)
    assert n_proj > 0, "the template pool must include projective chains"
    srv = serving.GeometryServer(backend="ref")
    outs = srv.serve(reqs)
    assert serving.stats["requests"] == 64
    assert serving.stats["launches"] < 64
    assert any(r.kind == "projective" for r in srv.last_report)
    for (chain, pts), out in zip(reqs, outs):
        if chain.is_projective:
            assert isinstance(out, serving.Projected)
            assert out.mask.shape == pts.shape[:-1]


def test_serving_records_projective_packed_bytes():
    serving.reset_stats()
    serving.clear_plan_cache()
    rng = np.random.default_rng(7)
    chain_rng = np.random.default_rng(2)
    reqs = [(workload.chain_for(chain_rng, 2, "TSP"),
             rng.uniform(-1, 1, (60, 2)).astype(np.float32))
            for _ in range(8)]                    # one bucket, lpad=64
    srv = serving.GeometryServer(backend="ref")
    with opcount.counting() as records:
        srv.serve(reqs)
    serve_records = [r for r in records if r[0] == "serve_bucket_projective"]
    assert len(serve_records) == serving.stats["launches"] == 1
    (_, nbytes), = serve_records
    assert nbytes == opcount.packed_chain_bytes(8, 64, 2, kind="projective")


def test_empty_projective_request_rejected_at_submit():
    """PR 6: an empty projective request is refused with a typed error at
    the submit boundary instead of passing through silently (an empty
    result is indistinguishable from a lost one)."""
    serving.reset_stats()
    serving.clear_plan_cache()
    srv = serving.GeometryServer(backend="ref")
    chain = workload.chain_for(np.random.default_rng(0), 3, "TSRP")
    with pytest.raises(serving.errors.EmptyPointsError) as ei:
        srv.submit(chain, np.zeros((0, 3), np.float32))
    assert ei.value.ticket == 0
    assert srv.flush() == []
    assert serving.stats["launches"] == 0
    assert serving.stats["rejected_requests"] == 1


# ---------------------------------------------------------------------------
# Camera / Viewport semantics
# ---------------------------------------------------------------------------

def test_look_at_centers_target_and_culls_behind():
    cam = graphics.Camera(eye=(3.0, 2.0, 5.0), target=(0.5, -0.5, 1.0),
                          fov_y=np.pi / 2, near=0.1, far=100.0)
    vp = graphics.Viewport(0.0, 0.0, 640.0, 480.0)
    chain = graphics.viewing_chain(camera=cam, viewport=vp)
    eye = np.asarray(cam.eye, np.float32)
    tgt = np.asarray(cam.target, np.float32)
    behind = eye + (eye - tgt)               # mirrored through the eye
    out, mask = chain.project(
        jnp.asarray(np.stack([tgt, behind])), backend="ref")
    assert bool(mask[0]) and not bool(mask[1])   # target visible, not behind
    np.testing.assert_allclose(np.asarray(out)[0, :2], [320.0, 240.0],
                               atol=1e-3)        # target -> viewport center


def test_perspective_near_far_map_to_depth_range():
    cam = graphics.Camera(eye=(0.0, 0.0, 0.0), target=(0.0, 0.0, -1.0),
                          fov_y=np.pi / 2, near=1.0, far=10.0)
    vp = graphics.Viewport(0.0, 0.0, 2.0, 2.0, depth=(0.0, 1.0))
    chain = graphics.viewing_chain(camera=cam, viewport=vp)
    pts = np.array([[0.0, 0.0, -1.0],        # on the near plane
                    [0.0, 0.0, -10.0],       # on the far plane
                    [0.0, 0.0, -0.5],        # nearer than near -> culled
                    [0.0, 0.0, -20.0]],      # beyond far -> culled
                   np.float32)
    out, mask = chain.project(jnp.asarray(pts), backend="ref")
    np.testing.assert_array_equal(np.asarray(mask),
                                  [True, True, False, False])
    np.testing.assert_allclose(np.asarray(out)[0, 2], 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out)[1, 2], 1.0, atol=1e-5)


def test_orthographic_keeps_w_one_and_culls_on_bounds():
    h = graphics.orthographic(-2.0, 2.0, -1.0, 1.0, 1.0, 10.0)
    chain = tc.TransformChain.identity(3).projective(h).cull()
    pts = np.array([[0.0, 0.0, -5.0],
                    [3.0, 0.0, -5.0],        # x outside the box
                    [0.0, 0.0, -20.0]],      # beyond far
                   np.float32)
    out, mask = chain.project(jnp.asarray(pts), backend="ref")
    np.testing.assert_array_equal(np.asarray(mask), [True, False, False])
    # z = -5 with near=1, far=10: z' = -2z/(f-n) - (f+n)/(f-n) = -1/9
    np.testing.assert_allclose(np.asarray(out)[0], [0.0, 0.0, -1.0 / 9.0],
                               atol=1e-5)


def test_camera_validation():
    with pytest.raises(ValueError):
        graphics.look_at((0, 0, 0), (0, 0, 0))          # degenerate view
    with pytest.raises(ValueError):
        graphics.perspective(0.0, 1.0, 0.1, 10.0)       # bad fov
    with pytest.raises(ValueError):
        graphics.perspective(1.0, 1.0, 5.0, 1.0)        # near >= far
    with pytest.raises(ValueError):
        graphics.Viewport().scale_offset(4)
    with pytest.raises(ValueError):
        graphics.viewing_chain(2, camera=graphics.Camera())  # 3D cam, 2D


def test_workload_projective_templates_are_reproducible():
    """The seeded workload's projective templates fold bit-identically
    across draws with the same seed (the serving/autotune benches rely
    on it)."""
    a = workload.random_workload(seed=41, n_requests=22, max_points=64)
    b = workload.random_workload(seed=41, n_requests=22, max_points=64)
    assert any(c.is_projective for c, _ in a)
    for (ca, pa), (cb, pb) in zip(a, b):
        assert ca.structure == cb.structure
        np.testing.assert_array_equal(pa, pb)
        for fa, fb in zip(ca.fold(), cb.fold()):
            np.testing.assert_array_equal(fa, fb)
