"""Fault tolerance: atomic checkpoints, resume-exactness, retention."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import (
    CheckpointManager, latest_step, load_checkpoint, save_checkpoint,
)
from repro.launch.train import train_loop


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                       "c": jnp.ones((2, 2), jnp.bfloat16)}}


class TestStore:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        save_checkpoint(str(tmp_path), 10, t)
        restored, step = load_checkpoint(str(tmp_path), jax.eval_shape(lambda: t))
        assert step == 10
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_latest_step_ignores_incomplete(self, tmp_path):
        save_checkpoint(str(tmp_path), 5, _tree())
        # a crashed save: directory without manifest
        os.makedirs(tmp_path / "step_000000009")
        assert latest_step(str(tmp_path)) == 5

    def test_atomic_tmp_never_visible(self, tmp_path):
        save_checkpoint(str(tmp_path), 7, _tree())
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))

    def test_retention_gc(self, tmp_path):
        for s in range(1, 6):
            save_checkpoint(str(tmp_path), s, _tree(), keep=2)
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path))
        assert steps == [4, 5]

    def test_structure_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree())
        with pytest.raises(ValueError):
            load_checkpoint(str(tmp_path), {"different": jnp.zeros(3)})

    def test_manifest_contents(self, tmp_path):
        save_checkpoint(str(tmp_path), 3, _tree(), extra={"arch": "yi-6b"})
        with open(tmp_path / "step_000000003" / "manifest.json") as f:
            m = json.load(f)
        assert m["step"] == 3 and m["extra"]["arch"] == "yi-6b"


class TestManagerAsync:
    def test_async_save_completes(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), interval=2, keep=3)
        t = _tree()
        assert not mgr.maybe_save(1, t)      # off-interval
        assert mgr.maybe_save(2, t)
        mgr.wait()
        assert latest_step(str(tmp_path)) == 2


class TestResume:
    def test_kill_and_resume_matches_uninterrupted(self, tmp_path):
        """Train 10 steps; separately train 6, 'crash', resume to 10.
        Histories and final params must agree -- the restart contract."""
        from repro.optim import AdamWConfig
        cfg = configs.get("mamba2-130m").reduced()
        kw = dict(global_batch=8, seq_len=64, log_every=100,
                  ckpt_interval=3, seed=11,
                  # fixed horizon: the LR schedule must not depend on how
                  # many steps this particular incarnation will run
                  opt_cfg=AdamWConfig(total_steps=10, warmup_steps=2))
        p_full, h_full = train_loop(cfg, steps=10,
                                    ckpt_dir=str(tmp_path / "full"), **kw)
        p1, h1 = train_loop(cfg, steps=6, ckpt_dir=str(tmp_path / "r"), **kw)
        # crash after step 6 (checkpoint exists at step 6); resume
        assert latest_step(str(tmp_path / "r")) == 6
        p2, h2 = train_loop(cfg, steps=10, ckpt_dir=str(tmp_path / "r"),
                            resume=True, **kw)
        np.testing.assert_allclose(h1[:6] + h2, h_full, rtol=2e-4, atol=2e-4)
        for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.float32(a), np.float32(b),
                                       rtol=2e-3, atol=2e-3)
