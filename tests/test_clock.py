"""Deterministic scheduler tests: VirtualClock semantics, the shared
nearest-rank percentile, the deadline-times-fill flush policy pinned
against hand-computed instants, per-tenant fairness under starvation,
typed backpressure rejection codes, and p50/p99 latency telemetry pinned
against hand-computed values on a fixed arrival script.

Everything here is exact (``==`` on floats): the clock is virtual, the
policy is arithmetic, and pinning the numbers is the point -- a
scheduler that can only be tested statistically is a scheduler whose
regressions ship.
"""
import math

import numpy as np
import pytest

from repro import serving
from repro.serving import workload
from repro.serving.admission import (AdmissionConfig, AdmissionController,
                                     QueueFullError, RateLimitError,
                                     TokenBucket)
from repro.serving.async_engine import AsyncGeometryServer, SLOConfig
from repro.serving.clock import MonotonicClock, VirtualClock, percentile


def _fresh_async(**kw):
    serving.reset_stats()
    serving.clear_plan_cache()
    kw.setdefault("clock", VirtualClock())
    return AsyncGeometryServer(**kw)


def _pts(rng, n, dim):
    return rng.uniform(-1, 1, (n, dim)).astype(np.float32)


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------

def test_virtual_clock_advances_only_on_request():
    clk = VirtualClock()
    assert clk.now() == 0.0
    assert clk.advance(1.5) == 1.5
    assert clk.now() == 1.5
    clk.sleep(0.5)
    assert clk.now() == 2.0
    clk.sleep(0.0)                      # no-op, not an error
    assert clk.now() == 2.0


def test_virtual_clock_never_rewinds():
    clk = VirtualClock(start=10.0)
    with pytest.raises(ValueError):
        clk.advance(-0.1)
    assert clk.advance_to(3.0) == 10.0   # past instants are a no-op
    assert clk.advance_to(12.5) == 12.5


def test_monotonic_clock_is_monotone():
    clk = MonotonicClock()
    a = clk.now()
    clk.sleep(0.001)
    assert clk.now() >= a


# ---------------------------------------------------------------------------
# the shared percentile definition (nearest rank)
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank_pinned():
    xs = [4, 1, 3, 2]                   # order must not matter
    assert percentile(xs, 0) == 1
    assert percentile(xs, 25) == 1
    assert percentile(xs, 50) == 2
    assert percentile(xs, 75) == 3
    assert percentile(xs, 99) == 4
    assert percentile(xs, 100) == 4
    assert percentile([7.0], 50) == 7.0


def test_percentile_edge_cases():
    assert math.isnan(percentile([], 50))
    with pytest.raises(ValueError):
        percentile([1], 101)
    with pytest.raises(ValueError):
        percentile([1], -1)


# ---------------------------------------------------------------------------
# the deadline-times-fill flush policy, pinned
# ---------------------------------------------------------------------------

def test_deadline_shrinks_with_fill():
    """due_in = max_wait * (1 - fill) - age, hand-computed per submit."""
    rng = np.random.default_rng(0)
    chain = workload.chain_for(rng, 2, "TST")
    eng = _fresh_async(backend="ref",
                       slo=SLOConfig(max_wait_s=0.01, target_rows=4))
    eng.submit_async(chain, _pts(rng, 3, 2))
    assert eng.next_due_in() == pytest.approx(0.0075)   # fill 1/4
    eng.submit_async(chain, _pts(rng, 3, 2))
    assert eng.next_due_in() == pytest.approx(0.005)    # fill 2/4
    eng.submit_async(chain, _pts(rng, 3, 2))
    assert eng.next_due_in() == pytest.approx(0.0025)   # fill 3/4
    eng.submit_async(chain, _pts(rng, 3, 2))
    assert eng.next_due_in() == 0.0                     # full: due NOW
    assert eng.poll() == 4


def test_deadline_expiry_flushes_partial_bucket():
    rng = np.random.default_rng(1)
    chain = workload.chain_for(rng, 2, "TST")
    clk = VirtualClock()
    eng = _fresh_async(backend="ref", clock=clk,
                       slo=SLOConfig(max_wait_s=0.01, target_rows=4))
    t = eng.submit_async(chain, _pts(rng, 3, 2))
    clk.advance(0.0074)
    assert eng.poll() == 0              # 0.1 ms early: not due yet
    clk.advance(0.0001)
    assert eng.poll() == 1              # deadline 0.0075 reached
    assert t.latency == pytest.approx(0.0075)


def test_deadline_expiry_flush_ordering():
    """Two groups past deadline in one poll: the one whose oldest
    request has waited longest launches first (visible in the flush's
    bucket report order)."""
    rng = np.random.default_rng(2)
    late = workload.chain_for(rng, 2, "TST")     # submitted first
    fresh = workload.chain_for(rng, 3, "TRS")    # submitted second
    clk = VirtualClock()
    eng = _fresh_async(backend="ref", clock=clk,
                       slo=SLOConfig(max_wait_s=0.01, target_rows=4))
    eng.submit_async(late, _pts(rng, 3, 2))
    clk.advance(0.002)
    eng.submit_async(fresh, _pts(rng, 3, 3))
    clk.advance(0.008)                  # both deadlines have passed
    assert eng.poll() == 2
    structures = [r.structure for r in eng.server.last_report]
    assert structures == ["2D:TST", "3D:TRS"]

    # and in the mirror order when arrival order flips
    eng2 = _fresh_async(backend="ref", clock=VirtualClock(),
                        slo=SLOConfig(max_wait_s=0.01, target_rows=4))
    eng2.submit_async(fresh, _pts(rng, 3, 3))
    eng2.clock.advance(0.002)
    eng2.submit_async(late, _pts(rng, 3, 2))
    eng2.clock.advance(0.008)
    assert eng2.poll() == 2
    assert [r.structure for r in eng2.server.last_report] \
        == ["3D:TRS", "2D:TST"]


def test_poll_leaves_undue_groups_queued():
    rng = np.random.default_rng(3)
    a = workload.chain_for(rng, 2, "TST")
    b = workload.chain_for(rng, 3, "TRS")
    clk = VirtualClock()
    eng = _fresh_async(backend="ref", clock=clk,
                       slo=SLOConfig(max_wait_s=0.01, target_rows=4))
    eng.submit_async(a, _pts(rng, 3, 2))
    clk.advance(0.005)
    tb = eng.submit_async(b, _pts(rng, 3, 3))
    clk.advance(0.0025)                 # a's deadline (0.0075) fires
    assert eng.poll() == 1
    assert not tb.done()
    assert eng.stats["waiting_groups"] == 1
    assert eng.next_due_in() == pytest.approx(0.005)   # b due at 0.0125


# ---------------------------------------------------------------------------
# admission: fairness, backpressure, and typed rejection codes
# ---------------------------------------------------------------------------

def test_tenant_fair_share_prevents_starvation():
    """A flooding tenant saturates ITS share while a light tenant still
    admits -- then the global bound closes the queue for everyone."""
    clk = VirtualClock()
    ctrl = AdmissionController(
        AdmissionConfig(max_queue_depth=8, tenant_share=0.5), clk)
    admitted_heavy = 0
    for _ in range(10):                  # heavy tenant floods
        try:
            ctrl.admit("heavy")
            admitted_heavy += 1
        except QueueFullError:
            pass
    assert admitted_heavy == 4           # ceil(8 * 0.5)
    for _ in range(4):                   # light tenant is NOT starved
        ctrl.admit("light")
    with pytest.raises(QueueFullError):  # now the queue itself is full
        ctrl.admit("light")
    assert ctrl.queue_full_rejections == 7
    # releases reopen the gate (for a tenant still under its own cap)
    ctrl.release("light")
    ctrl.admit("light")
    assert ctrl.depth == 8


def test_rejection_codes_are_stable_and_typed():
    rng = np.random.default_rng(4)
    chain = workload.chain_for(rng, 2, "TST")
    eng = _fresh_async(
        backend="ref",
        admission=AdmissionConfig(max_queue_depth=2, tenant_share=1.0))
    eng.submit_async(chain, _pts(rng, 2, 2))
    eng.submit_async(chain, _pts(rng, 2, 2))
    with pytest.raises(QueueFullError) as exc:
        eng.submit_async(chain, _pts(rng, 2, 2))
    assert exc.value.code == "queue-full"
    assert isinstance(exc.value, serving.RequestError)
    assert eng.stats["queue_full_rejections"] == 1
    assert serving.stats["queue_full_rejections"] == 1
    eng.drain()                          # frees the queue
    eng.submit_async(chain, _pts(rng, 2, 2))


def test_token_bucket_refills_in_clock_time():
    b = TokenBucket(rate=100.0, burst=2.0)
    assert b.take(0.0) and b.take(0.0)
    assert not b.take(0.0)               # burst exhausted
    assert b.next_admissible_in(0.0) == pytest.approx(0.01)
    assert b.take(0.01)                  # one token refilled
    assert not b.take(0.01)


def test_rate_limited_engine_rejects_with_typed_error():
    rng = np.random.default_rng(5)
    chain = workload.chain_for(rng, 2, "TST")
    clk = VirtualClock()
    eng = _fresh_async(
        backend="ref", clock=clk,
        admission=AdmissionConfig(tenant_rate=100.0, tenant_burst=2.0))
    eng.submit_async(chain, _pts(rng, 2, 2), tenant="t0")
    eng.submit_async(chain, _pts(rng, 2, 2), tenant="t0")
    with pytest.raises(RateLimitError) as exc:
        eng.submit_async(chain, _pts(rng, 2, 2), tenant="t0")
    assert exc.value.code == "rate-limit"
    # a DIFFERENT tenant has its own bucket
    eng.submit_async(chain, _pts(rng, 2, 2), tenant="t1")
    # and clock time refills t0's
    clk.advance(0.01)
    eng.submit_async(chain, _pts(rng, 2, 2), tenant="t0")
    assert eng.stats["rate_limit_rejections"] == 1
    assert serving.stats["rate_limit_rejections"] == 1


def test_depth_rejection_spends_no_rate_token():
    clk = VirtualClock()
    ctrl = AdmissionController(
        AdmissionConfig(max_queue_depth=1, tenant_share=1.0,
                        tenant_rate=10.0, tenant_burst=2.0), clk)
    ctrl.admit("t")
    with pytest.raises(QueueFullError):
        ctrl.admit("t")                  # depth gate fires first
    ctrl.release("t")
    ctrl.admit("t")                      # the second token is still there
    assert ctrl.rate_limit_rejections == 0


# ---------------------------------------------------------------------------
# latency telemetry pinned on a fixed arrival script
# ---------------------------------------------------------------------------

def test_p50_p99_pinned_on_fixed_arrival_script():
    """Arrivals at t = 0, 1, 2, 3 ms into a 4-row bucket: the 4th fill
    triggers the flush at t = 3 ms, so latencies are exactly
    [3, 2, 1, 0] ms -- p50 = 1 ms (nearest rank), p99 = 3 ms, and the
    sustained rate is 4 requests over 3 ms."""
    rng = np.random.default_rng(6)
    chain = workload.chain_for(rng, 2, "TST")
    clk = VirtualClock()
    eng = _fresh_async(backend="ref", clock=clk,
                       slo=SLOConfig(max_wait_s=0.05, target_rows=4))
    tickets = []
    for k in range(4):
        clk.advance_to(k * 0.001)
        tickets.append(eng.submit_async(chain, _pts(rng, 3, 2)))
    assert eng.next_due_in() == 0.0
    assert eng.poll() == 4
    assert [t.latency for t in tickets] == \
        pytest.approx([0.003, 0.002, 0.001, 0.0])
    st = eng.stats
    assert st["p50_latency_s"] == pytest.approx(0.001)
    assert st["p99_latency_s"] == pytest.approx(0.003)
    assert st["max_latency_s"] == pytest.approx(0.003)
    assert st["sustained_rps"] == pytest.approx(4 / 0.003)


def test_queue_depth_telemetry():
    rng = np.random.default_rng(7)
    chain = workload.chain_for(rng, 2, "TST")
    eng = _fresh_async(backend="ref")
    for _ in range(3):
        eng.submit_async(chain, _pts(rng, 2, 2))
    st = eng.stats
    assert st["queue_depth"] == 3
    assert st["max_queue_depth_seen"] == 3
    assert st["resolved"] == 0
    eng.drain()
    st = eng.stats
    assert st["queue_depth"] == 0
    assert st["max_queue_depth_seen"] == 3   # high-water mark sticks
    assert st["resolved"] == 3
    assert serving.stats["admitted_requests"] == 3


def test_slo_config_validation():
    with pytest.raises(ValueError):
        SLOConfig(max_wait_s=-0.001)
    with pytest.raises(ValueError):
        SLOConfig(target_rows=0)
    with pytest.raises(ValueError):
        AdmissionConfig(max_queue_depth=0)
    with pytest.raises(ValueError):
        AdmissionConfig(tenant_share=0.0)
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0, burst=2.0)
