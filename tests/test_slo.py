"""SLO burn-rate monitor tests: pinned alert instants under a scripted
virtual clock, Prometheus exposition of alert state, and the async
front-end wiring.

The design invariant under test: the monitor re-evaluates on EVERY
observation through the injectable clock, so the alert fires AT the
event that crossed the threshold -- a bit-deterministic virtual-second
the tests pin to exact floats.
"""
import numpy as np
import pytest

from repro import obs, serving
from repro.core import transform_chain as tc
from repro.obs.slo import (DEFAULT_RULES, LATENCY, REJECTIONS, BurnRule,
                           SLOMonitor)
from repro.serving.async_engine import AsyncGeometryServer, SLOConfig
from repro.serving.clock import VirtualClock

RNG = np.random.default_rng(81)

#: one second-scale rule so tests script whole-second event trains:
#: burn >= 2 on the trailing 10 s AND the trailing 2 s
RULE = BurnRule(long_s=10.0, short_s=2.0, threshold=2.0)


def _monitor(clock, **kw):
    kw.setdefault("latency_slo_s", 0.05)
    kw.setdefault("latency_target", 0.9)
    kw.setdefault("rejection_target", 0.9)
    kw.setdefault("rules", (RULE,))
    return SLOMonitor(clock, **kw)


def _chain2():
    return tc.TransformChain.identity(2).translate(0.5, -0.25).scale(1.5)


def _pts(n=8, dim=2):
    return RNG.uniform(-1, 1, (n, dim)).astype(np.float32)


class TestBurnRule:
    def test_validation(self):
        with pytest.raises(ValueError):
            BurnRule(long_s=1.0, short_s=2.0, threshold=1.0)
        with pytest.raises(ValueError):
            BurnRule(long_s=1.0, short_s=0.0, threshold=1.0)
        with pytest.raises(ValueError):
            BurnRule(long_s=2.0, short_s=1.0, threshold=0.0)
        assert DEFAULT_RULES[0].threshold == 14.4

    def test_monitor_validation(self):
        clk = VirtualClock()
        with pytest.raises(ValueError):
            SLOMonitor(clk, latency_slo_s=0.1, rules=())
        with pytest.raises(ValueError):
            SLOMonitor(clk, latency_slo_s=0.1, latency_target=1.0)


class TestPinnedAlertInstants:
    def _script(self, mon, clock):
        """good@1, bad@2, good@3..5: the canonical fire/resolve train."""
        for t, latency in ((1.0, 0.01), (2.0, 0.10), (3.0, 0.01),
                           (4.0, 0.01), (5.0, 0.01)):
            clock.advance_to(t)
            mon.observe_latency(latency)

    def test_latency_alert_fires_and_resolves_at_exact_instants(self):
        clock = VirtualClock()
        mon = _monitor(clock)
        self._script(mon, clock)
        alert = mon.alerts[LATENCY]
        # the bad event at t=2 put burn at 5.0 (>2) on both windows ->
        # fires AT that observation; the short window goes clean once
        # the t=2 event ages out of the trailing 2 s -> resolves at t=5
        assert alert.fired_at == [2.0]
        assert alert.resolved_at == [5.0]
        assert not alert.active and alert.fired == 1

    def test_counters_round_trip_instants_in_us(self):
        clock = VirtualClock()
        mon = _monitor(clock)
        self._script(mon, clock)
        c = mon.counters()
        assert c["latency_alerts_fired"] == 1
        assert c["latency_alert_active"] == 0
        assert c["latency_first_fire_us"] == 2_000_000.0
        assert c["latency_first_resolve_us"] == 5_000_000.0
        assert c["latency_bad_events"] == 1
        assert c["latency_events"] == 5
        assert c["rejections_events"] == 0

    def test_rerun_is_bit_identical(self):
        outs = []
        for _ in range(2):
            clock = VirtualClock()
            mon = _monitor(clock)
            self._script(mon, clock)
            outs.append((mon.counters(),
                         obs.prometheus_text(mon.metrics)))
        assert outs[0] == outs[1]

    def test_rejection_objective_fires(self):
        clock = VirtualClock()
        mon = _monitor(clock)
        clock.advance_to(1.0)
        mon.observe_admission()
        clock.advance_to(2.0)
        mon.observe_rejection()
        assert mon.alerts[REJECTIONS].fired_at == [2.0]
        assert mon.alerts[LATENCY].fired_at == []

    def test_single_bad_blip_after_healthy_history_does_not_page(self):
        # one bad event against a healthy long window: burn(long) stays
        # under threshold, so the multi-window rule does not page
        clock = VirtualClock()
        mon = _monitor(clock)
        for k in range(10):
            clock.advance_to(float(k + 1))
            mon.observe_latency(0.01)
        clock.advance_to(11.0)
        mon.observe_latency(0.10)      # 1 bad of 11 in the long window
        assert mon.alerts[LATENCY].fired_at == []
        assert mon.burn_rate(LATENCY, RULE.long_s) < RULE.threshold

    def test_window_trimming_bounds_memory(self):
        clock = VirtualClock()
        mon = _monitor(clock)
        for k in range(100):
            clock.advance_to(float(k))
            mon.observe_latency(0.01)
        # horizon is the longest window (10 s): old events are dropped
        assert len(mon._events[LATENCY]) <= 12
        assert mon.counters()["latency_events"] == 100

    def test_slo_instants_reach_the_tracer(self):
        clock = VirtualClock()
        trc = obs.Tracer(clock=clock)
        mon = _monitor(clock)
        with obs.installed(trc):
            self._script(mon, clock)
        fires = [s for s in trc.spans if s.name == "slo.fire"]
        resolves = [s for s in trc.spans if s.name == "slo.resolve"]
        assert len(fires) == 1 and fires[0].t0 == 2.0
        assert fires[0].attrs["objective"] == LATENCY
        assert len(resolves) == 1 and resolves[0].t0 == 5.0


class TestPrometheusExport:
    def test_alert_state_in_exposition(self):
        clock = VirtualClock()
        mon = _monitor(clock)
        clock.advance_to(1.0)
        mon.observe_latency(0.01)
        clock.advance_to(2.0)
        mon.observe_latency(0.10)          # fires
        text = obs.prometheus_text(mon.metrics)
        assert '# TYPE slo_alert_active gauge' in text
        assert 'slo_alert_active{objective="latency"} 1' in text
        assert 'slo_alerts_fired{objective="latency"} 1' in text
        assert 'slo_bad_events{objective="latency"} 1' in text
        assert 'slo_burn_rate{objective="latency",window="2s"} 5.0' \
            in text
        assert 'slo_burn_rate{objective="latency",window="10s"} 5.0' \
            in text


class TestAsyncWiring:
    def _engine(self, clock, mon, **kw):
        serving.reset_stats()
        serving.clear_plan_cache()
        return AsyncGeometryServer(
            backend="ref", clock=clock, slo_monitor=mon,
            slo=SLOConfig(max_wait_s=0.01, target_rows=4), **kw)

    def test_latency_and_admission_events_flow(self):
        clock = VirtualClock()
        mon = _monitor(clock, latency_slo_s=1.0)
        eng_ = self._engine(clock, mon)
        for _ in range(3):
            eng_.submit_async(_chain2(), _pts(6))
        eng_.drain()
        c = mon.counters()
        assert c["rejections_events"] == 3     # three admissions, no bad
        assert c["rejections_bad_events"] == 0
        assert c["latency_events"] == 3        # three resolutions
        assert mon.alerts[LATENCY].fired_at == []

    def test_rejections_feed_the_monitor(self):
        clock = VirtualClock()
        mon = _monitor(clock)
        eng_ = self._engine(
            clock, mon,
            admission=serving.AdmissionConfig(max_queue_depth=1,
                                              tenant_share=1.0))
        eng_.submit_async(_chain2(), _pts(4))
        with pytest.raises(serving.QueueFullError):
            eng_.submit_async(_chain2(), _pts(4))
        eng_.drain()
        c = mon.counters()
        assert c["rejections_events"] == 2
        assert c["rejections_bad_events"] == 1

    def test_default_is_unmonitored(self):
        serving.reset_stats()
        serving.clear_plan_cache()
        eng_ = AsyncGeometryServer(backend="ref", clock=VirtualClock())
        assert eng_.slo_monitor is None
        eng_.submit_async(_chain2(), _pts(4))
        eng_.drain()
