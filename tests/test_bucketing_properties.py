"""Property tests for the size-bucketing grid (``serving.bucketing``):
the documented guarantees -- result >= n, padding waste strictly under
the cap for any n >= min_len, power-of-two rungs at the default cap,
monotonicity in n, ``grid_for`` echoing explicit knobs -- checked over
randomised inputs with hypothesis, plus deterministic seeded sweeps of
the same invariants (and the q-lane size-class contract against the
engine's real bucket keys) that always run.

``hypothesis`` is an OPTIONAL dependency (see tests/README.md): the
property tests are skipped without it; the seeded sweeps always run.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dep -- skip, don't fail
    HAVE_HYPOTHESIS = False

    class _NoStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NoStrategies()

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (optional dep)")(f)

from repro import serving
from repro.kernels import dispatch
from repro.serving import bucketing, workload
from repro.serving.engine import GeometryServer


# ---------------------------------------------------------------------------
# hypothesis properties (skipped without the optional dep)
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(n=st.integers(0, 5000),
       min_len=st.integers(1, 128),
       waste_cap=st.floats(0.05, 0.95))
def test_padded_length_bounds(n, min_len, waste_cap):
    lpad = bucketing.padded_length(n, min_len=min_len, waste_cap=waste_cap)
    assert lpad >= n
    assert lpad >= min_len
    if n >= min_len:
        # the documented contract: waste strictly under the cap
        assert bucketing.waste_fraction(n, lpad) < waste_cap
    else:
        # short requests pad to the grid floor -- the floor bounds them
        assert lpad == min_len


@settings(max_examples=200, deadline=None)
@given(n=st.integers(1, 5000))
def test_default_grid_is_pure_powers_of_two(n):
    """waste_cap=0.5 degenerates to doubling: every rung is
    min_len * 2**k (the paper's power-of-two frame-buffer banks)."""
    lpad = bucketing.padded_length(n)
    assert lpad % bucketing.MIN_LEN == 0
    rung = lpad // bucketing.MIN_LEN
    assert rung & (rung - 1) == 0        # a power of two


@settings(max_examples=100, deadline=None)
@given(n=st.integers(0, 3000),
       min_len=st.integers(1, 64),
       waste_cap=st.floats(0.05, 0.95))
def test_padded_length_monotone_in_n(n, min_len, waste_cap):
    """A longer request never gets a shorter pad (grids are ascending);
    equal-length requests always share a size class."""
    a = bucketing.padded_length(n, min_len=min_len, waste_cap=waste_cap)
    b = bucketing.padded_length(n + 1, min_len=min_len, waste_cap=waste_cap)
    assert b >= a
    assert bucketing.padded_length(n, min_len=min_len,
                                   waste_cap=waste_cap) == a


@settings(max_examples=50, deadline=None)
@given(min_len=st.integers(1, 256), waste_cap=st.floats(0.05, 0.95),
       n=st.integers(0, 4096))
def test_grid_for_echoes_explicit_knobs(min_len, waste_cap, n):
    """Explicit arguments always win over cache/defaults, and say so."""
    got = bucketing.grid_for("ref", min_len=min_len, waste_cap=waste_cap,
                             n=n)
    assert got == (min_len, waste_cap, "explicit")


# ---------------------------------------------------------------------------
# deterministic seeded sweeps of the same invariants (always run)
# ---------------------------------------------------------------------------

def test_padded_length_seeded_sweep():
    rng = np.random.default_rng(0xB0C5)
    for _ in range(500):
        n = int(rng.integers(0, 5000))
        min_len = int(rng.integers(1, 128))
        waste_cap = float(rng.uniform(0.05, 0.95))
        lpad = bucketing.padded_length(n, min_len=min_len,
                                       waste_cap=waste_cap)
        assert lpad >= max(n, min_len)
        if n >= min_len:
            assert bucketing.waste_fraction(n, lpad) < waste_cap
        nxt = bucketing.padded_length(n + 1, min_len=min_len,
                                      waste_cap=waste_cap)
        assert nxt >= lpad


def test_grid_source_labels():
    assert bucketing.grid_for("ref", min_len=8, waste_cap=0.5) \
        == (8, 0.5, "explicit")
    m, c, source = bucketing.grid_for("ref")
    assert (m, c) == (bucketing.MIN_LEN, bucketing.WASTE_CAP)
    assert source in ("default", "cached", "tuned")
    # one knob explicit, the other resolved
    m, c, source = bucketing.grid_for("ref", min_len=16)
    assert m == 16 and source.startswith("explicit+")


def test_q_lane_size_classes_match_float_lane():
    """A q8.7 and a float32 request of the same length land in the SAME
    size class (one grid for both lanes) but in DIFFERENT buckets keyed
    by the format name -- checked against the engine's real bucket keys.
    """
    serving.reset_stats()
    serving.clear_plan_cache()
    srv = GeometryServer(backend="ref")
    backend = dispatch.resolve(srv.backend)
    rng = np.random.default_rng(0xB0C6)
    chain = workload.chain_for(rng, 2, "TST")
    for n in (1, 7, 8, 9, 31, 32, 200):
        pts = rng.uniform(-1, 1, (n, 2)).astype(np.float32)
        pf = srv.validate(chain, pts)
        pq = srv.validate(chain, pts, qformat="q8.7")
        kf = srv._bucket_key(pf, backend)
        kq = srv._bucket_key(pq, backend)
        # same structure, same padded size class...
        assert kf[0] == kq[0] and kf[3] == kq[3]
        assert kf[3] == bucketing.padded_length(n)
        # ...different dtype lane: the format name, not the submit dtype
        assert kq[2] == "q8.7" and kf[2] != kq[2]
