"""Scene-graph fold-CSE correctness: the bitwise + counting contracts.

What is pinned here (see ``docs/scene_graph.md``):

  * **bitwise**: any interleaving of node edits and world-fold queries
    yields folds bit-identical to folding every world chain from
    scratch with ``fold_structure`` (the carry fold re-runs the same
    loop, so equality is exact, not approximate) -- seeded sweeps plus
    a hypothesis property over random trees and edit/query schedules;
  * **counting**: fold executions per "frame" equal the dirty-subtree
    size (O(changed nodes), the benchmark's gated claim), reverting a
    node to previously-folded content costs ZERO folds (content-hash
    cache), and a second scene sharing the ``FoldCache`` serves its
    common subchains from the first scene's entries;
  * **stability**: content digests are pure functions of chain content
    -- equal across processes (no ``PYTHONHASHSEED`` dependence) and
    across graphs built in different orders, and the cached fold bytes
    are identical to the scratch fold bytes;
  * **serving**: ``submit_scene`` / ``submit_scene_async`` results are
    bitwise equal to submitting the node's world chain, bitwise equal
    to per-request ``apply`` on diagonal float32 plans and on the q8.7
    lane for every plan kind, and within the engine's documented
    last-ULP envelope on float matrix plans.

``hypothesis`` is an OPTIONAL dependency (see tests/README.md): the
property tests are skipped without it; the seeded sweeps always run.
"""
import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dep -- skip, don't fail
    HAVE_HYPOTHESIS = False

    class _NoStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NoStrategies()

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (optional dep)")(f)

from repro import scene, serving
from repro.core import transform_chain as tc
from repro.obs import trace as obst
from repro.serving.async_engine import AsyncGeometryServer
from repro.serving.clock import VirtualClock


def _bytes_eq(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return a.dtype == b.dtype and a.shape == b.shape \
        and a.tobytes() == b.tobytes()


def _fold_eq(fa, fb) -> bool:
    return len(fa) == len(fb) and all(_bytes_eq(x, y)
                                      for x, y in zip(fa, fb))


def _scratch_fold(graph, name):
    c = graph.world_chain(name)
    return tc.fold_structure(c.structure, c.params)


def _rand_local(rng, dim, *, kinds="TSAR", max_len=3):
    """A random local chain (possibly empty) over the given kind set."""
    c = tc.TransformChain.identity(dim)
    for _ in range(int(rng.integers(0, max_len + 1))):
        k = kinds[int(rng.integers(len(kinds)))]
        if k == "T":
            c = c.translate(*rng.standard_normal(dim).astype(np.float32))
        elif k == "S":
            c = c.scale(*(rng.uniform(0.5, 2.0, dim).astype(np.float32)))
        elif k == "A":
            c = c.affine(rng.uniform(0.5, 2.0, dim).astype(np.float32),
                         rng.standard_normal(dim).astype(np.float32))
        else:
            axis = int(rng.integers(3)) if dim == 3 else None
            c = c.rotate(float(rng.uniform(-3, 3)), axis=axis)
    return c


def _rand_tree(rng, dim, n_nodes, **local_kw):
    """Random forest: each node parents under a uniformly random earlier
    node (or is a root); returns (graph, names)."""
    g = scene.SceneGraph(dim, cache=scene.FoldCache())
    names = []
    for i in range(n_nodes):
        parent = None
        if names and rng.uniform() < 0.8:
            parent = names[int(rng.integers(len(names)))]
        names.append(g.add(f"n{i}", _rand_local(rng, dim, **local_kw),
                           parent=parent))
    return g, names


# ---------------------------------------------------------------------------
# carry folds: piecewise == one-pass, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim", [2, 3])
def test_fold_carry_piecewise_bitwise(dim):
    rng = np.random.default_rng(101 + dim)
    for _ in range(20):
        c = _rand_local(rng, dim, max_len=6)
        if not len(c):
            continue
        kind = tc.plan_kind_of(c.structure)
        one = tc.fold_structure(c.structure, c.params)
        for cut in range(len(c.kinds) + 1):
            carry = tc.fold_carry_identity(kind, dim)
            carry = tc.fold_carry_extend(kind, dim, carry,
                                         c.kinds[:cut], c.params[:cut])
            carry = tc.fold_carry_extend(kind, dim, carry,
                                         c.kinds[cut:], c.params[cut:])
            assert _fold_eq(one, tc.fold_carry_finish(kind, carry))


def test_fold_carry_projective_bitwise():
    c = (tc.TransformChain.identity(3)
         .translate(1.0, 2.0, 3.0).rotate(0.3, axis=1)
         .projective(np.eye(4, dtype=np.float32)
                     + np.float32(0.01) * np.ones((4, 4), np.float32))
         .cull((-1, -1, -1), (1, 1, 1)).scale(2.0).translate(1.0, 1.0, 1.0))
    kind = tc.plan_kind_of(c.structure)
    assert kind == "projective"
    one = tc.fold_structure(c.structure, c.params)
    carry = tc.fold_carry_identity(kind, 3)
    for i in range(len(c.kinds)):
        carry = tc.fold_carry_extend(kind, 3, carry, c.kinds[i:i + 1],
                                     c.params[i:i + 1])
    assert _fold_eq(one, tc.fold_carry_finish(kind, carry))


def test_fold_carry_kind_restrictions():
    c = tc.TransformChain.identity(2).rotate(0.5)
    with pytest.raises(ValueError):
        tc.fold_carry_extend("diag", 2, tc.fold_carry_identity("diag", 2),
                             c.kinds, c.params)
    p = tc.TransformChain.identity(2).cull((-1, -1), (1, 1))
    with pytest.raises(ValueError):
        tc.fold_carry_extend("matrix", 2,
                             tc.fold_carry_identity("matrix", 2),
                             p.kinds, p.params)
    with pytest.raises(ValueError):
        tc.fold_carry_identity("banded", 2)


def test_fold_carry_after_cull_restriction_survives_resume():
    # a cull in the carried prefix must still reject a following rotation
    pre = tc.TransformChain.identity(2).cull((-1, -1), (1, 1))
    carry = tc.fold_carry_extend(
        "projective", 2, tc.fold_carry_identity("projective", 2),
        pre.kinds, pre.params)
    rot = tc.TransformChain.identity(2).rotate(0.3)
    with pytest.raises(ValueError):
        tc.fold_carry_extend("projective", 2, carry, rot.kinds, rot.params)


# ---------------------------------------------------------------------------
# graph structure + dirty bits
# ---------------------------------------------------------------------------

def test_graph_structure_errors():
    g = scene.SceneGraph(2, cache=scene.FoldCache())
    g.add("a")
    with pytest.raises(ValueError):
        g.add("a")                                  # duplicate
    with pytest.raises(KeyError):
        g.add("b", parent="nope")                   # unknown parent
    with pytest.raises(KeyError):
        g.world_fold("nope")                        # unknown node
    with pytest.raises(ValueError):
        g.add("c", tc.TransformChain.identity(3))   # dim mismatch
    with pytest.raises(ValueError):
        g.add("")                                   # empty name
    with pytest.raises(TypeError):
        g.add("d", local="not a chain")


def test_subtree_and_dirty_propagation():
    g = scene.SceneGraph(2, cache=scene.FoldCache())
    g.add("r", tc.TransformChain.identity(2).translate(1.0))
    g.add("a", tc.TransformChain.identity(2).scale(2.0), parent="r")
    g.add("b", tc.TransformChain.identity(2).scale(3.0), parent="r")
    g.add("a1", tc.TransformChain.identity(2).translate(5.0), parent="a")
    assert g.subtree("a") == ["a", "a1"]
    assert sorted(g.leaves()) == ["a1", "b"]
    for n in g.names():
        g.world_fold(n)
        assert not g.dirty(n)
    assert g.set_local("a", tc.TransformChain.identity(2).scale(4.0)) == 2
    assert g.dirty("a") and g.dirty("a1")
    assert not g.dirty("r") and not g.dirty("b")
    # editing while already dirty does not recount
    assert g.set_local("a", tc.TransformChain.identity(2).scale(5.0)) == 0


def test_identity_world_chain():
    g = scene.SceneGraph(2, cache=scene.FoldCache())
    g.add("r")
    g.add("c", parent="r")
    assert len(g.world_chain("c")) == 0
    assert g.world_kind("c") == "diag"
    s, t = g.world_fold("c")
    assert _bytes_eq(s, np.ones(2, np.float32))
    assert _bytes_eq(t, np.zeros(2, np.float32))


# ---------------------------------------------------------------------------
# (a) edits + queries interleaved == scratch folds, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim,kinds", [(2, "TSA"), (3, "TSAR"), (2, "TSAR")])
def test_world_folds_bitwise_vs_scratch_seeded(dim, kinds):
    rng = np.random.default_rng(2026)
    for trial in range(8):
        g, names = _rand_tree(rng, dim, 12, kinds=kinds)
        for step in range(12):
            if rng.uniform() < 0.4:
                g.set_local(names[int(rng.integers(len(names)))],
                            _rand_local(rng, dim, kinds=kinds))
            q = names[int(rng.integers(len(names)))]
            assert _fold_eq(g.world_fold(q), _scratch_fold(g, q))
        for n in names:                     # full sweep at the end
            assert _fold_eq(g.world_fold(n), _scratch_fold(g, n))


def test_world_folds_bitwise_projective_scene():
    g = scene.SceneGraph(3, cache=scene.FoldCache())
    g.add("model", tc.TransformChain.identity(3).rotate(0.3, axis=2))
    g.add("camera",
          tc.TransformChain.identity(3).translate(0.0, 0.0, -5.0),
          parent="model")
    proj = np.eye(4, dtype=np.float32)
    proj[2, 3] = np.float32(-1.0)
    proj[3, 3] = np.float32(0.0)
    g.add("clip", tc.TransformChain.identity(3).projective(proj),
          parent="camera")
    g.add("vp", tc.TransformChain.identity(3)
          .cull((-1, -1, -1), (1, 1, 1)).scale(100.0, 100.0, 1.0),
          parent="clip")
    for n in g.names():
        assert _fold_eq(g.world_fold(n), _scratch_fold(g, n))
    assert g.world_kind("vp") == "projective"
    g.set_local("camera",
                tc.TransformChain.identity(3).translate(0.0, 1.0, -7.0))
    for n in g.names():
        assert _fold_eq(g.world_fold(n), _scratch_fold(g, n))


if HAVE_HYPOTHESIS:
    _ops = st.lists(st.tuples(st.sampled_from(["edit", "query"]),
                              st.integers(0, 9),
                              st.integers(0, 2 ** 16)),
                    min_size=1, max_size=25)

    @settings(max_examples=30, deadline=None)
    @given(tree_seed=st.integers(0, 2 ** 16), ops=_ops)
    def test_world_folds_bitwise_vs_scratch_property(tree_seed, ops):
        rng = np.random.default_rng(tree_seed)
        g, names = _rand_tree(rng, 3, 10)
        for op, idx, seed in ops:
            name = names[idx % len(names)]
            if op == "edit":
                g.set_local(name, _rand_local(
                    np.random.default_rng(seed), 3))
            else:
                assert _fold_eq(g.world_fold(name), _scratch_fold(g, name))
        for n in names:
            assert _fold_eq(g.world_fold(n), _scratch_fold(g, n))


# ---------------------------------------------------------------------------
# (b) fold counts == dirty-subtree size per frame
# ---------------------------------------------------------------------------

def _resolve_all_leaves(g):
    for n in g.leaves():
        g.world_fold(n)


def test_fold_count_equals_dirty_subtree():
    # locals get content-unique parameters on purpose: two siblings with
    # EQUAL content share one digest and fold once (that CSE is tested
    # separately); here every node must be its own fold unit so the
    # folds == nodes / folds == dirtied arithmetic is exact
    g = scene.SceneGraph(3, cache=scene.FoldCache())
    g.add("root", tc.TransformChain.identity(3).translate(0.5, 0.0, 0.0))
    g.add("cam", tc.TransformChain.identity(3).rotate(0.2, axis=0),
          parent="root")
    for b in range(4):
        g.add(f"b{b}", tc.TransformChain.identity(3)
              .scale(np.float32(1.0 + b)), parent="cam")
        for leaf in range(3):
            g.add(f"b{b}/l{leaf}", tc.TransformChain.identity(3)
                  .translate(np.float32(leaf), np.float32(b), 0.0),
                  parent=f"b{b}")
    scene.reset_stats()
    _resolve_all_leaves(g)
    # cold frame: every node folds exactly once (in the leaves' kind)
    assert scene.stats["folds"] == len(g)
    assert scene.stats["cache_misses"] == scene.stats["folds"]
    assert scene.stats["refolds"] == 0
    # animated frames: folds == dirtied, exactly, frame after frame
    for frame in range(5):
        before = dict(scene.stats)
        edit = f"b{frame % 4}"
        dirtied = g.set_local(
            edit, tc.TransformChain.identity(3)
            .scale(np.float32(1.0 + 0.1 * frame))
            .translate(np.float32(frame), 0.0, 0.0))
        assert dirtied == len(g.subtree(edit)) == 4
        _resolve_all_leaves(g)
        assert scene.stats["folds"] - before["folds"] == dirtied
        assert scene.stats["refolds"] - before["refolds"] == dirtied
        assert scene.stats["dirtied"] - before["dirtied"] == dirtied
    # a clean re-query costs nothing
    before = dict(scene.stats)
    _resolve_all_leaves(g)
    assert scene.stats["folds"] == before["folds"]


def test_revert_to_cached_content_costs_zero_folds():
    g = scene.SceneGraph(2, cache=scene.FoldCache())
    old = tc.TransformChain.identity(2).scale(2.0)
    g.add("r", tc.TransformChain.identity(2).translate(1.0, 0.0))
    g.add("c", old, parent="r")
    g.world_fold("c")
    g.set_local("c", tc.TransformChain.identity(2).scale(3.0))
    g.world_fold("c")
    scene.reset_stats()
    # revert: same CONTENT as the first local -> digest matches -> hit
    assert g.set_local("c", tc.TransformChain.identity(2).scale(2.0)) == 1
    f = g.world_fold("c")
    assert scene.stats["folds"] == 0
    assert scene.stats["cse_hits"] == 1
    assert _fold_eq(f, _scratch_fold(g, "c"))


# ---------------------------------------------------------------------------
# (c) content keys: cross-process / cross-graph stability, shared-cache CSE
# ---------------------------------------------------------------------------

_DIGEST_SNIPPET = """
import numpy as np
from repro import scene
from repro.core import transform_chain as tc
g = scene.SceneGraph(3, cache=scene.FoldCache())
g.add("w", tc.TransformChain.identity(3).translate(1.0, 2.0, 3.0))
g.add("c", tc.TransformChain.identity(3).rotate(0.25, axis=1), parent="w")
f = g.world_fold("c")
print(g.world_digest("c"))
print(np.asarray(f[0]).tobytes().hex())
print(np.asarray(f[1]).tobytes().hex())
"""


def test_content_keys_and_folds_stable_across_processes():
    out = subprocess.run(
        [sys.executable, "-c", _DIGEST_SNIPPET],
        capture_output=True, text=True, check=True).stdout.split()
    g = scene.SceneGraph(3, cache=scene.FoldCache())
    g.add("w", tc.TransformChain.identity(3).translate(1.0, 2.0, 3.0))
    g.add("c", tc.TransformChain.identity(3).rotate(0.25, axis=1),
          parent="w")
    f = g.world_fold("c")
    assert out[0] == g.world_digest("c")
    assert out[1] == np.asarray(f[0]).tobytes().hex()
    assert out[2] == np.asarray(f[1]).tobytes().hex()


def test_digest_is_content_not_construction_order():
    a = scene.SceneGraph(2, cache=scene.FoldCache())
    a.add("r", tc.TransformChain.identity(2).scale(2.0))
    a.add("x", tc.TransformChain.identity(2).translate(1.0, 0.0),
          parent="r")
    a.add("y", tc.TransformChain.identity(2).translate(0.0, 1.0),
          parent="r")
    b = scene.SceneGraph(2, cache=scene.FoldCache())
    b.add("r2", tc.TransformChain.identity(2).scale(2.0))
    b.add("y2", tc.TransformChain.identity(2).translate(0.0, 1.0),
          parent="r2")
    b.add("x2", tc.TransformChain.identity(2).translate(1.0, 0.0),
          parent="r2")
    assert a.world_digest("x") == b.world_digest("x2")
    assert a.world_digest("y") == b.world_digest("y2")
    assert a.world_digest("x") != a.world_digest("y")
    # shape framing: scalar-broadcast 1.0 and explicit (1.0, 1.0) params
    # are different content even though they fold to equal values
    c1 = tc.TransformChain.identity(2).translate(1.0)
    c2 = tc.TransformChain.identity(2).translate(1.0, 1.0)
    assert scene.chain_digest(2, c1.kinds, c1.params) \
        != scene.chain_digest(2, c2.kinds, c2.params)


def test_cse_across_scenes_sharing_a_cache():
    shared = scene.FoldCache()
    prefix = tc.TransformChain.identity(3).rotate(0.4, axis=1) \
        .translate(0.0, 0.0, -5.0)
    leafc = tc.TransformChain.identity(3).scale(2.0)
    a = scene.SceneGraph(3, cache=shared)
    a.add("cam", prefix)
    a.add("obj", leafc, parent="cam")
    b = scene.SceneGraph(3, cache=shared)
    b.add("cam", prefix)
    b.add("obj", leafc, parent="cam")
    scene.reset_stats()
    fa = a.world_fold("obj")
    folds_a = scene.stats["folds"]
    assert folds_a == 2
    fb = b.world_fold("obj")
    # scene b resolves entirely from scene a's entries: zero new folds
    assert scene.stats["folds"] == folds_a
    assert scene.stats["cse_hits"] >= 1
    assert _fold_eq(fa, fb)


# ---------------------------------------------------------------------------
# serving integration: submit_scene / submit_scene_async equality
# ---------------------------------------------------------------------------

def _diag_scene(rng):
    g = scene.SceneGraph(2, cache=scene.FoldCache())
    g.add("view", tc.TransformChain.identity(2).scale(0.5)
          .translate(1.0, 2.0))
    leaves = [g.add(f"n{i}", tc.TransformChain.identity(2)
                    .affine(np.float32(1.0 + i), (np.float32(i), 0.0)),
                    parent="view")
              for i in range(5)]
    return g, leaves


def _matrix_scene(rng):
    g = scene.SceneGraph(3, cache=scene.FoldCache())
    g.add("world", tc.TransformChain.identity(3).translate(0.0, 0.0, 1.0))
    g.add("camera", tc.TransformChain.identity(3).rotate(0.4, axis=1)
          .translate(0.0, 0.0, -5.0), parent="world")
    leaves = []
    for b in range(4):
        g.add(f"b{b}", tc.TransformChain.identity(3)
              .scale(np.float32(1.0 + b)), parent="camera")
        leaves.append(g.add(f"b{b}/leaf", tc.TransformChain.identity(3)
                            .affine(0.5, (np.float32(b), 0.0, 0.0)),
                            parent=f"b{b}"))
    return g, leaves


def test_submit_scene_float32_bitwise_on_diag_plans():
    rng = np.random.default_rng(11)
    g, leaves = _diag_scene(rng)
    serving.reset_stats()
    srv = serving.GeometryServer(backend="ref")
    pts = {n: rng.standard_normal((8, 2)).astype(np.float32)
           for n in leaves}
    tickets = {n: srv.submit_scene(g, n, pts[n]) for n in leaves}
    res = srv.flush()
    for n in leaves:
        oracle = g.world_chain(n).apply(pts[n], backend="ref")
        assert _bytes_eq(res[tickets[n]], oracle)


def test_submit_scene_equals_submit_chain_bitwise():
    # scene-cached fold vs per-request fold, same server, same buckets:
    # identical requests land in one packed batch -> results are bitwise
    # equal on EVERY plan kind (the fold itself is bitwise by the carry
    # construction; identical batch rows cannot diverge)
    rng = np.random.default_rng(12)
    g, leaves = _matrix_scene(rng)
    srv = serving.GeometryServer(backend="ref")
    pts = {n: rng.standard_normal((16, 3)).astype(np.float32)
           for n in leaves}
    via_scene = {n: srv.submit_scene(g, n, pts[n]) for n in leaves}
    via_chain = {n: srv.submit(g.world_chain(n), pts[n]) for n in leaves}
    res = srv.flush()
    for n in leaves:
        assert _bytes_eq(res[via_scene[n]], res[via_chain[n]])
        # and within the engine's documented last-ULP envelope of apply
        np.testing.assert_allclose(
            np.asarray(res[via_scene[n]]),
            np.asarray(g.world_chain(n).apply(pts[n], backend="ref")),
            rtol=2e-6, atol=2e-6)


def test_submit_scene_q8_7_bitwise_every_plan_kind():
    rng = np.random.default_rng(13)
    for build in (_diag_scene, _matrix_scene):
        g, leaves = build(rng)
        dim = g.dim
        srv = serving.GeometryServer(backend="ref")
        pts = {n: rng.uniform(-2, 2, (12, dim)).astype(np.float32)
               for n in leaves}
        tickets = {n: srv.submit_scene(g, n, pts[n], qformat="q8.7")
                   for n in leaves}
        res = srv.flush()
        for n in leaves:
            oracle = g.world_chain(n).apply(pts[n], backend="ref",
                                            dtype="q8.7")
            assert _bytes_eq(res[tickets[n]], oracle)


def test_submit_scene_projective_equals_chain():
    g = scene.SceneGraph(3, cache=scene.FoldCache())
    g.add("cam", tc.TransformChain.identity(3).translate(0.0, 0.0, -4.0))
    proj = np.eye(4, dtype=np.float32)
    proj[2, 3] = np.float32(-1.0)
    proj[3, 3] = np.float32(0.0)
    g.add("clip", tc.TransformChain.identity(3).projective(proj),
          parent="cam")
    g.add("vp", tc.TransformChain.identity(3)
          .cull((-1, -1, -1), (1, 1, 1)).scale(50.0, 50.0, 1.0),
          parent="clip")
    rng = np.random.default_rng(14)
    pts = rng.uniform(-1, 1, (32, 3)).astype(np.float32)
    srv = serving.GeometryServer(backend="ref")
    t_scene = srv.submit_scene(g, "vp", pts)
    t_chain = srv.submit(g.world_chain("vp"), pts)
    res = srv.flush()
    assert _bytes_eq(res[t_scene], res[t_chain])
    assert _bytes_eq(res[t_scene].mask, res[t_chain].mask)


def test_submit_scene_identity_node_passthrough():
    g = scene.SceneGraph(2, cache=scene.FoldCache())
    g.add("r")
    pts = np.arange(8, dtype=np.float32).reshape(4, 2)
    srv = serving.GeometryServer(backend="ref")
    t = srv.submit_scene(g, "r", pts)
    res = srv.flush()
    assert _bytes_eq(res[t], pts)


def test_submit_scene_async_bitwise():
    rng = np.random.default_rng(15)
    g, leaves = _matrix_scene(rng)
    srv = AsyncGeometryServer(backend="ref", clock=VirtualClock())
    pts = {n: rng.uniform(-2, 2, (8, 3)).astype(np.float32)
           for n in leaves}
    tickets = {n: srv.submit_scene_async(g, n, pts[n], qformat="q8.7")
               for n in leaves}
    srv.drain()
    for n in leaves:
        oracle = g.world_chain(n).apply(pts[n], backend="ref",
                                        dtype="q8.7")
        assert _bytes_eq(tickets[n].result(), oracle)


def test_submit_scene_cse_counters_move_not_refolds():
    rng = np.random.default_rng(16)
    g, leaves = _matrix_scene(rng)
    for n in leaves:
        g.world_fold(n)                 # warm the cache
    scene.reset_stats()
    srv = serving.GeometryServer(backend="ref")
    for n in leaves:
        srv.submit_scene(g, n, rng.standard_normal((4, 3))
                         .astype(np.float32))
    srv.flush()
    assert scene.stats["folds"] == 0
    assert scene.stats["cse_hits"] == len(leaves)


# ---------------------------------------------------------------------------
# obs integration: instants mirror the counters
# ---------------------------------------------------------------------------

def test_scene_trace_instants_match_counters():
    clock = VirtualClock()
    trc = obst.Tracer(clock=clock)
    obst.install(trc)
    try:
        g = scene.SceneGraph(2, cache=scene.FoldCache())
        scene.reset_stats()
        g.add("r", tc.TransformChain.identity(2).scale(2.0))
        g.add("c", tc.TransformChain.identity(2).translate(1.0, 0.0),
              parent="r")
        g.world_fold("c")               # 2 cold folds
        g.world_fold("c")               # 1 cse hit
        g.set_local("c", tc.TransformChain.identity(2).translate(2.0, 0.0))
        g.world_fold("c")               # 1 refold (+1 cse hit at "r")
        assert trc.count("scene.fold") == scene.stats["folds"] \
            - scene.stats["refolds"] == 2
        assert trc.count("scene.refold") == scene.stats["refolds"] == 1
        assert trc.count("scene.cse_hit") == scene.stats["cse_hits"] == 2
    finally:
        obst.install(None)
