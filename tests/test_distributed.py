"""Sharding rules, optimizer, compression, HLO analyzer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import hlo_analysis
from repro.distributed import sharding
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.optim.adamw import global_norm


class FakeMesh:
    def __init__(self, shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestParamSpecs:
    def test_column_parallel(self):
        s = sharding.param_spec("['layers']['attn']['wq']", 3,
                                ("data",), "model")
        assert s == P(None, ("data",), "model")

    def test_row_parallel(self):
        s = sharding.param_spec("['layers']['attn']['wo']", 3,
                                ("data",), "model")
        assert s == P(None, "model", ("data",))

    def test_embed(self):
        s = sharding.param_spec("['embed']", 2, ("pod", "data"), "model")
        assert s == P("model", ("pod", "data"))

    def test_moe_expert_weights_keep_expert_dim_replicated(self):
        s = sharding.param_spec("['layers']['moe']['w_gate']", 4,
                                ("data",), "model")
        assert s == P(None, None, ("data",), "model")

    def test_norm_gains_replicated(self):
        s = sharding.param_spec("['layers']['attn_norm']", 2,
                                ("data",), "model")
        assert s == P(None, None)

    def test_sanitize_drops_nondividing_axis(self):
        shapes = {"embed": jax.ShapeDtypeStruct((50280, 768), jnp.float32)}
        specs = {"embed": P("model", "data")}
        fixed = sharding.sanitize_specs(shapes, specs, MESH)
        assert fixed["embed"] == P(None, "data")   # 50280 % 16 != 0

    def test_sanitize_keeps_dividing_axis(self):
        shapes = {"w": jax.ShapeDtypeStruct((128256, 8192), jnp.float32)}
        specs = {"w": P("model", "data")}
        assert sharding.sanitize_specs(shapes, specs, MESH)["w"] == \
            P("model", "data")


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params)
        cfg = AdamWConfig(peak_lr=0.2, warmup_steps=5, total_steps=200,
                          weight_decay=0.0)
        for _ in range(200):
            g = {"w": 2 * state["master"]["w"]}
            params, state, _ = adamw_update(g, state, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_weight_decay_skips_1d(self):
        params = {"gain": jnp.ones((4,)), "w": jnp.ones((4, 4))}
        state = adamw_init(params)
        cfg = AdamWConfig(peak_lr=0.0, warmup_steps=0, total_steps=10,
                          weight_decay=0.5)
        g = jax.tree.map(jnp.zeros_like, params)
        newp, _, _ = adamw_update(g, state, params, cfg)
        # lr=0 -> nothing moves even with decay (decay scales with lr)
        np.testing.assert_array_equal(newp["gain"], params["gain"])

    def test_grad_clipping(self):
        params = {"w": jnp.zeros((3,))}
        state = adamw_init(params)
        cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, total_steps=10)
        _, _, m = adamw_update({"w": jnp.full((3,), 100.0)}, state, params, cfg)
        assert float(m["grad_norm"]) > 100

    def test_schedule_shape(self):
        lr0 = warmup_cosine(0, peak_lr=1.0, warmup_steps=10, total_steps=100)
        lr10 = warmup_cosine(10, peak_lr=1.0, warmup_steps=10, total_steps=100)
        lr100 = warmup_cosine(100, peak_lr=1.0, warmup_steps=10,
                              total_steps=100)
        assert float(lr0) == 0.0
        assert float(lr10) == 1.0
        assert 0.05 < float(lr100) < 0.15

    def test_global_norm(self):
        t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
        assert float(global_norm(t)) == 5.0

    def test_master_weights_preserve_bf16_params(self):
        params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        state = adamw_init(params)
        assert state["master"]["w"].dtype == jnp.float32
        g = {"w": jnp.full((4, 4), 1e-3, jnp.bfloat16)}
        newp, state, _ = adamw_update(g, state, params,
                                      AdamWConfig(warmup_steps=0))
        assert newp["w"].dtype == jnp.bfloat16


class TestCompression:
    def test_int8_error_feedback_converges(self):
        from repro.distributed.compression import quantize_int8
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal(256), jnp.float32) * 1e-3
        err = jnp.zeros_like(g)
        acc_q = jnp.zeros_like(g)
        for _ in range(50):   # same grad repeatedly: EF must not drift
            q, scale, err = quantize_int8(g, err)
            acc_q = acc_q + q.astype(jnp.float32) * scale
        np.testing.assert_allclose(acc_q / 50, g, atol=float(jnp.abs(g).max()) * 0.02)

    def test_quantize_roundtrip_bounded(self):
        from repro.distributed.compression import dequantize_int8, quantize_int8
        g = jnp.linspace(-1, 1, 100)
        q, scale, err = quantize_int8(g, jnp.zeros_like(g))
        assert q.dtype == jnp.int8
        np.testing.assert_allclose(dequantize_int8(q, scale), g, atol=0.01)
        np.testing.assert_allclose(g - dequantize_int8(q, scale), err,
                                   atol=1e-7)


class TestHloAnalysis:
    def test_plain_matmul_flops_exact(self):
        m, k, n = 64, 128, 32
        comp = jax.jit(lambda a, b: a @ b).lower(
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32)).compile()
        res = hlo_analysis.analyze(comp.as_text())
        assert res["flops"] == 2 * m * k * n

    def test_scan_trip_count_multiplies(self):
        def scanned(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, x, None, length=10)[0]
        comp = jax.jit(scanned).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32),
            jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
        res = hlo_analysis.analyze(comp.as_text())
        assert res["flops"] == 10 * 2 * 32 ** 3

    def test_collectives_empty_on_single_device(self):
        comp = jax.jit(lambda x: x * 2).lower(
            jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
        res = hlo_analysis.analyze(comp.as_text())
        assert sum(res["collective_bytes"].values()) == 0
