"""End-to-end behaviour: training convergence, serving, data determinism."""
import jax
import numpy as np

from repro import configs
from repro.data import DataConfig, SyntheticLMData
from repro.launch.serve import serve_batch
from repro.launch.train import train_loop
from repro.models import build


def test_train_loss_decreases():
    """20 steps on a reduced mamba2 must show a real loss drop."""
    cfg = configs.get("mamba2-130m").reduced()
    _, history = train_loop(cfg, steps=20, global_batch=8, seq_len=64,
                            log_every=100)
    first, last = np.mean(history[:3]), np.mean(history[-3:])
    assert last < first - 0.2, (first, last)


def test_train_loss_decreases_dense_moe():
    cfg = configs.get("granite-moe-3b-a800m").reduced()
    _, history = train_loop(cfg, steps=15, global_batch=8, seq_len=64,
                            log_every=100)
    assert np.mean(history[-3:]) < np.mean(history[:3]) - 0.1


def test_serve_batched_generates():
    cfg = configs.get("yi-6b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 16)).astype(np.int32)
    gen = serve_batch(cfg, params, prompts, gen_tokens=8, model=model)
    assert gen.shape == (4, 8)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()


def test_serve_greedy_is_deterministic():
    cfg = configs.get("mamba2-130m").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 12)).astype(np.int32)
    g1 = serve_batch(cfg, params, prompts, gen_tokens=6, model=model)
    g2 = serve_batch(cfg, params, prompts, gen_tokens=6, model=model)
    np.testing.assert_array_equal(g1, g2)


class TestDataPipeline:
    def test_step_seekable_determinism(self):
        """batch(step) is a pure function -- the restart contract."""
        cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8, seed=3)
        d1, d2 = SyntheticLMData(cfg), SyntheticLMData(cfg)
        for step in (0, 7, 1000):
            b1, b2 = d1.global_batch(step), d2.global_batch(step)
            np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_steps_differ(self):
        d = SyntheticLMData(DataConfig(vocab_size=100, seq_len=32,
                                       global_batch=8))
        assert not np.array_equal(d.global_batch(0)["tokens"],
                                  d.global_batch(1)["tokens"])

    def test_host_shards_concatenate_to_global(self):
        d = SyntheticLMData(DataConfig(vocab_size=100, seq_len=16,
                                       global_batch=8))
        g = d.global_batch(5)["tokens"]
        parts = [d.local_batch(5, h, 4)["tokens"] for h in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts), g)

    def test_labels_are_next_tokens_structure(self):
        """Stream has learnable next-token structure (Markov component)."""
        d = SyntheticLMData(DataConfig(vocab_size=97, seq_len=256,
                                       global_batch=4))
        b = d.global_batch(0)
        follow = (b["tokens"] * 31 + 7) % 97
        frac = (b["labels"] == follow).mean()
        assert 0.3 < frac < 0.7   # ~half the transitions are deterministic
