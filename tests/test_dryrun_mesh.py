"""Dry-run / elastic tests that need >1 host device: run in subprocesses so
the 8-device XLA flag never leaks into this process (smoke tests must see 1
device, per the assignment)."""
import json
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_cell_lowers_and_compiles_on_small_mesh():
    """The dry-run machinery end-to-end on a 4x2 mesh with a reduced arch."""
    out = _run("""
        import jax, json
        from repro.launch import cells
        from repro.launch.mesh import make_mesh
        from repro import hlo_analysis
        mesh = make_mesh((4, 2), ("data", "model"))
        # full-size configs are exercised by the real dry-run; here a small
        # arch proves the machinery under pytest time budgets.
        cell = cells.build_cell("mamba2-130m", "decode_32k", mesh)
        comp = cell.lowered.compile()
        mem = comp.memory_analysis()
        ana = hlo_analysis.analyze(comp.as_text())
        print(json.dumps({
            "temps": mem.temp_size_in_bytes,
            "flops": ana["flops"],
            "collective": sum(ana["collective_bytes"].values()),
        }))
    """)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["collective"] > 0          # sharded decode must communicate


def test_train_step_lowers_multipod_axes():
    """(pod, data, model) mesh on 8 devices: the pod axis must shard."""
    out = _run("""
        import jax, json
        from repro.launch import cells
        from repro.launch.mesh import make_mesh
        from repro import hlo_analysis
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cell = cells.build_cell("hymba-1.5b", "decode_32k", mesh)
        comp = cell.lowered.compile()
        ana = hlo_analysis.analyze(comp.as_text())
        print(json.dumps({"collective": sum(ana["collective_bytes"].values())}))
    """)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["collective"] > 0


def test_elastic_shrink_resume():
    """Checkpoint on an 8-device mesh, resume on 4 devices: loss continues
    from the same value and the global batch is preserved."""
    out = _run("""
        import json, tempfile, jax
        import numpy as np
        from repro import configs
        from repro.launch.train import train_loop
        from repro.launch.mesh import make_mesh

        cfg = configs.get("mamba2-130m").reduced()
        d = tempfile.mkdtemp()
        mesh8 = make_mesh((8, 1), ("data", "model"))
        _, h1 = train_loop(cfg, steps=6, global_batch=8, seq_len=64,
                           mesh=mesh8, ckpt_dir=d, ckpt_interval=3,
                           log_every=100, seed=5)
        mesh4 = make_mesh((4, 1), ("data", "model"),
                          devices=jax.devices()[:4])
        _, h2 = train_loop(cfg, steps=10, global_batch=8, seq_len=64,
                           mesh=mesh4, ckpt_dir=d, resume=True,
                           ckpt_interval=3, log_every=100, seed=5)
        print(json.dumps({"h1": h1, "h2": h2}))
    """)
    rec = json.loads(out.strip().splitlines()[-1])
    # resumed first-step loss must continue the trajectory, not restart at init
    assert rec["h2"][0] < rec["h1"][0] - 0.2
    assert len(rec["h2"]) == 4   # steps 6..9
