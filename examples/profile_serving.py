"""Profiled serving example: trace a seeded workload, fold the span
stream into the attribution report, and check the cost model's
predictions against the observed launch traffic.

This is the paper's deliverable -- a performance analysis with
predicted-vs-measured accounting -- applied to the serving stack: every
dispatched launch carries the cost model's predicted HBM bytes / FLOPs /
M1-cycle projection, and the profiler folds the stream into per-stage
self/total time plus per-kernel launch tables.  On the virtual clock
every counter below is a pure function of the seed.

    PYTHONPATH=src python examples/profile_serving.py
    PYTHONPATH=src python examples/profile_serving.py --requests 128 \
        --markdown report.md
"""
import argparse

from repro import serving
from repro.obs.profile import Profile, profile_smoke_workload
from repro.serving import engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--seed", type=int, default=17)
    ap.add_argument("--markdown", default=None, metavar="OUT.md",
                    help="also write the full report here")
    args = ap.parse_args()

    engine.reset_stats()
    tracer, _server = profile_smoke_workload(args.requests,
                                             seed=args.seed)
    prof = Profile.from_tracer(tracer)

    print(f"served {args.requests} requests: {prof.launches} launches "
          f"across {len(prof.buckets)} buckets, "
          f"{prof.n_events} trace events\n")

    print("attribution tree (count / total ms / self ms):")
    for depth, node in prof.root.walk():
        if node is prof.root:
            continue
        print(f"  {'  ' * (depth - 1)}{node.name:<24} {node.count:>5} "
              f"{node.total_s * 1e3:>10.3f} {node.self_s * 1e3:>10.3f}")

    print("\nmodel error (observed vs predicted HBM bytes per kernel):")
    for key in sorted(prof.kernels):
        g = prof.kernels[key]
        print(f"  {g.key:<24} {g.launches:>3} launches  "
              f"observed {g.hbm_bytes:>8}  predicted "
              f"{g.pred_hbm_bytes:>8}  "
              f"ratio {g.hbm_bytes / g.pred_hbm_bytes:.6f}")

    assert prof.launches == serving.stats["launches"], \
        "attribution tree disagrees with the engine's launch counter"
    assert prof.byte_ratio_exact, \
        "observed/predicted byte ratio drifted from 1.0"
    print(f"\nattribution exact: True; byte ratio exact: "
          f"{prof.byte_ratio_exact} over {len(prof.byte_ratios)} launches")

    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(prof.render_markdown())
        print(f"wrote {args.markdown}")


if __name__ == "__main__":
    main()
