"""Quickstart: the paper's pipeline end to end in two minutes.

1. run the paper's routines on the MorphoSys M1 emulator (cycle-exact
   against Table 5 where the paper prints listings),
2. run the same linear-algebra primitives through the TPU transform engine
   (Pallas kernel bodies validated in interpret mode),
3. train a tiny LM a few steps -- the same primitives as model substrate.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro import configs, kernels
from repro.core import transform_engine as te
from repro.core.morphosys import programs
from repro.launch.train import train_loop

# -- 1. the paper's routines on the emulated M1 -------------------------------
u = np.arange(64, dtype=np.int16)
v = 1000 - u
r = programs.run_translation(u, v)
print(f"M1 64-elem translation: {r.cycles} cycles "
      f"(paper Table 5: 96), correct={np.array_equal(r.values, u + v)}")
r = programs.run_scaling(u, 5)
print(f"M1 64-elem scaling:     {r.cycles} cycles "
      f"(paper Table 5: 55), correct={np.array_equal(r.values, (5 * u).astype(np.int16))}")

# -- 2. the same transforms on the TPU mapping ---------------------------------
pts = jnp.asarray(np.random.default_rng(0).standard_normal((1000, 2)),
                  jnp.float32)
tf = (te.Transform2D.identity()
      .then_scale(2.0, 0.5).then_rotate(0.3).then_translate(1.0, -2.0))
composite = tf.apply(pts, backend="interpret")      # Pallas kernel body
sequential = te.translate(
    te.rotate(te.scale(pts, jnp.asarray([2.0, 0.5])), 0.3),
    jnp.asarray([1.0, -2.0]))
print(f"TPU composite == sequential primitives: "
      f"{bool(jnp.allclose(composite, sequential, atol=1e-4))}")

# rotation is the paper's matrix primitive; RoPE is its descendant
x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 16, 64)),
                jnp.float32)
cos, sin = kernels.rope_tables(jnp.arange(16), 64)
y = kernels.rope(x, cos, sin, backend="interpret")
print(f"RoPE preserves norms (rotation!): "
      f"{bool(jnp.allclose(jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-4))}")

# -- 3. the primitives as model substrate ---------------------------------------
cfg = configs.get("mamba2-130m").reduced()
_, history = train_loop(cfg, steps=10, global_batch=8, seq_len=64,
                        log_every=5)
print(f"tiny-LM loss: {history[0]:.2f} -> {history[-1]:.2f}")
