"""Batched serving example: prefill a batch of prompts, decode in lock-step.

Uses the reduced yi-6b config so it runs on CPU; on TPU drop --reduced and
the same code path serves the full model under the production mesh (the
decode_32k dry-run cell lowers exactly this step).

    PYTHONPATH=src python examples/serve_batched.py --batch 8 --gen-tokens 24
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.launch.serve import serve_batch
from repro.models import build


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)

    t0 = time.time()
    gen = serve_batch(cfg, params, prompts, gen_tokens=args.gen_tokens,
                      model=model)
    dt = time.time() - t0
    print(f"served {args.batch} requests x {args.gen_tokens} tokens "
          f"in {dt:.2f}s ({args.batch * args.gen_tokens / dt:.1f} tok/s)")
    print("sample generations:\n", gen[:3])


if __name__ == "__main__":
    main()
