"""Projected wireframe cube through the serving engine: the graphics
companion paper's 3D viewing pipeline, end to end.

Each of the cube's 12 edges is one serving request carrying the SAME
viewing-chain structure (model spin -> look-at camera -> perspective ->
NDC frustum cull -> viewport), so the GeometryServer buckets all of them
into a single fused kernel launch: one HBM pass projects every edge,
divides by w, culls, and maps to screen coordinates -- the mask rides
back on each result as ``Projected.mask``.

    PYTHONPATH=src python examples/render_pipeline.py
"""
import numpy as np

from repro import graphics, serving
from repro.core.transform_chain import TransformChain

WIDTH, HEIGHT = 64, 28
SAMPLES_PER_EDGE = 32


def cube_edges() -> list[np.ndarray]:
    """12 edges of the unit cube centered at the origin, each sampled to
    an (N, 3) float32 polyline."""
    c = [-1.0, 1.0]
    corners = np.array([[x, y, z] for x in c for y in c for z in c],
                       np.float32)
    pairs = [(a, b) for a in range(8) for b in range(a + 1, 8)
             if np.sum(np.abs(corners[a] - corners[b])) == 2.0]
    ts = np.linspace(0.0, 1.0, SAMPLES_PER_EDGE, dtype=np.float32)[:, None]
    return [corners[a] * (1 - ts) + corners[b] * ts for a, b in pairs]


def frame_chain(angle: float) -> TransformChain:
    """One frame's viewing chain: 7 primitives, ONE projective plan."""
    model = (TransformChain.identity(3)
             .rotate(angle, axis="y").rotate(0.4, axis="x").scale(1.0))
    cam = graphics.Camera(eye=(0.0, 0.6, 4.5), target=(0.0, 0.0, 0.0),
                          fov_y=np.pi / 3, aspect=WIDTH / HEIGHT / 2.2,
                          near=0.5, far=20.0)
    return graphics.viewing_chain(
        model=model, camera=cam,
        viewport=graphics.Viewport(0.0, 0.0, WIDTH, HEIGHT))


def rasterize(results) -> str:
    grid = [[" "] * WIDTH for _ in range(HEIGHT)]
    for res in results:
        pts = np.asarray(res)[np.asarray(res.mask)]
        for x, y, _z in pts:
            xi, yi = int(x), int(y)
            if 0 <= xi < WIDTH and 0 <= yi < HEIGHT:
                grid[HEIGHT - 1 - yi][xi] = "#"
    return "\n".join("".join(row) for row in grid)


def main() -> None:
    edges = cube_edges()
    server = serving.GeometryServer(backend="ref")
    for angle in (0.5, 1.1):
        serving.reset_stats()
        chain = frame_chain(angle)
        results = server.serve([(chain, edge) for edge in edges])
        st = serving.stats
        inside = sum(int(np.sum(r.mask)) for r in results)
        total = sum(len(e) for e in edges)
        print(f"--- frame angle={angle}: {st['requests']} edge requests -> "
              f"{st['launches']} fused launch(es) "
              f"({len(chain)} primitives folded per chain; "
              f"{inside}/{total} samples inside the frustum) ---")
        print(rasterize(results))
    # the second frame reused the compiled projective batch plan: same
    # structure, fresh parameters -> no recompiles
    print(f"\nplan cache after both frames: "
          f"{serving.stats['plan_compiles']} compiles this flush "
          f"(structure was cached from frame 1)")


if __name__ == "__main__":
    main()
