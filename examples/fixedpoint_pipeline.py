"""Fixed-point lane demo: the same composite chain on float32 and q8.7.

Runs the paper's translate/scale/rotate composite over one point cloud on
both execution lanes, showing:

  * the HBM byte economy (the int16 lane moves HALF the bytes -- counted
    by ``repro.kernels.opcount``, not asserted by prose);
  * the per-chain quantisation error bound from ``repro.quantize`` and
    the actual error sitting inside it;
  * batched serving of a mixed affine workload through the
    ``GeometryServer`` on both lanes -- same bucketing, same launch
    count, half the bytes.

    PYTHONPATH=src python examples/fixedpoint_pipeline.py
"""
import jax.numpy as jnp
import numpy as np

from repro import quantize, serving
from repro.core.transform_chain import TransformChain
from repro.kernels import opcount
from repro.serving import workload


def main() -> None:
    rng = np.random.default_rng(0)
    chain = (TransformChain.identity(2)
             .translate(1.0, -2.0).scale(1.5, 0.5).rotate(0.3))
    pts = rng.uniform(-3, 3, (4096, 2)).astype(np.float32)

    with opcount.counting() as rec_f:
        out_f = np.asarray(chain.apply(jnp.asarray(pts), backend="ref"))
    with opcount.counting() as rec_q:
        out_q = np.asarray(chain.apply(jnp.asarray(pts), backend="ref",
                                       dtype="q8.7"))
    bytes_f = opcount.total_bytes(rec_f)
    bytes_q = opcount.total_bytes(rec_q)
    print(f"fused composite over {len(pts)} points:")
    print(f"  float32 lane: {bytes_f:7d} HBM bytes")
    print(f"  q8.7 lane:    {bytes_q:7d} HBM bytes "
          f"({bytes_q / bytes_f:.2f}x)")

    folded = chain.fold()
    bound = quantize.error_bound(folded, chain.plan_kind, "q8.7",
                                 float(np.abs(pts).max()))
    err = np.abs(out_q - out_f).max(axis=0)
    assert quantize.fits(folded, chain.plan_kind, "q8.7",
                         float(np.abs(pts).max()))
    assert (err <= bound + np.float32(1e-5)).all(), (err, bound)
    print(f"  max |q - f32| per coord: {err} (bound {bound})")

    # batched serving: same workload, both lanes
    reqs = workload.random_workload(seed=7, n_requests=32, max_points=256,
                                    templates=workload.AFFINE_TEMPLATES)
    for qformat in (None, "q8.7"):
        srv = serving.GeometryServer(backend="ref")
        serving.reset_stats()
        with opcount.counting() as rec:
            srv.serve(reqs, qformat=qformat)
        nbytes = opcount.total_bytes(
            [r for r in rec if r[0].startswith("serve_bucket")])
        lane = qformat or "float32"
        print(f"served 32 requests on {lane:7s}: "
              f"{serving.stats['launches']} launches, {nbytes} HBM bytes")


if __name__ == "__main__":
    main()
