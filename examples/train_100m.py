"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpointing and restart, on whatever devices exist.

The config is the assigned mamba2-130m (129M params) at a CPU-feasible
batch; on TPU the same script runs the full shape by raising
--global-batch/--seq-len.  Demonstrates: data pipeline -> pjit'd microbatch
train step -> AdamW -> async checkpoints -> resume.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse

from repro import configs
from repro.launch.train import train_loop
from repro.optim import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = configs.get("mamba2-130m")       # 129M params, full config
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.0f}M params, "
          f"batch {args.global_batch} x {args.seq_len}")
    _, history = train_loop(
        cfg, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, ckpt_dir=args.ckpt_dir, ckpt_interval=100,
        resume=True, log_every=10,
        opt_cfg=AdamWConfig(peak_lr=6e-4, warmup_steps=30,
                            total_steps=args.steps))
    print(f"loss: {history[0]:.3f} -> {history[-1]:.3f} "
          f"over {len(history)} steps")


if __name__ == "__main__":
    main()
