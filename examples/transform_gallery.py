"""The paper's Figures 4-6 as code: translate / scale / rotate / composite
applied to a point-cloud 'image', on both execution substrates:

  * the MorphoSys M1 emulator (16-bit fixed point, cycle-counted),
  * the TPU transform engine (Pallas kernels in interpret mode).

    PYTHONPATH=src python examples/transform_gallery.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import transform_chain as tc
from repro.core import transform_engine as te
from repro.core.morphosys import programs


def ascii_plot(points: np.ndarray, title: str, size: int = 24) -> None:
    grid = [[" "] * size for _ in range(size)]
    p = np.asarray(points)
    lo, hi = p.min() - 1e-6, p.max() + 1e-6
    ij = ((p - lo) / (hi - lo) * (size - 1)).astype(int)
    for x, y in ij:
        grid[size - 1 - y][x] = "#"
    print(f"--- {title} (extent [{lo:.1f}, {hi:.1f}]) ---")
    print("\n".join("".join(r) for r in grid))


def house() -> np.ndarray:
    xs = np.linspace(-2, 2, 12)
    base = [(x, -1.0) for x in xs] + [(x, 1.0) for x in xs]
    base += [(-2.0, y) for y in np.linspace(-1, 1, 8)]
    base += [(2.0, y) for y in np.linspace(-1, 1, 8)]
    base += [(x, 1.0 + (2 - abs(x))) for x in np.linspace(-2, 2, 12)]
    return np.array(base, np.float32)


def main() -> None:
    pts = house()
    ascii_plot(pts, "original (Figure 4 image)")

    # Figure 5: translation -- vector-vector op
    ascii_plot(np.asarray(te.translate(jnp.asarray(pts), jnp.asarray([3.0, 2.0]))),
               "translated by (3, 2) -- paper 5.1")

    # Figure 6: scaling -- vector-scalar op
    ascii_plot(np.asarray(te.scale(jnp.asarray(pts), jnp.asarray([2.0, 0.5]))),
               "scaled (2, 0.5) -- paper 5.2")

    # rotation -- matrix op (5.3)
    ascii_plot(np.asarray(te.rotate(jnp.asarray(pts), np.pi / 4)),
               "rotated 45deg -- paper 5.3")

    # composite: the chain compiler folds the whole pipeline into ONE
    # fused kernel pass (the paper's General Composite Algorithm)
    tf = (te.Transform2D.identity().then_rotate(np.pi / 6)
          .then_scale(1.5, 1.5).then_translate(2.0, -1.0))
    ascii_plot(np.asarray(tf.apply(jnp.asarray(pts))),
               "composite (rotate+scale+translate) -- one fused pass")
    print(f"chain plan: {len(tf.chain)} primitives folded -> "
          f"1 {tf.chain.plan_kind} kernel launch (plan cache: {tc.stats})")

    # a pure translate/scale chain folds to a diagonal plan: the matrix
    # algorithm (and the MXU) is never involved
    diag = (tc.TransformChain.identity(2)
            .translate(1.0, 1.0).scale(0.5, 2.0).translate(-2.0, 0.0))
    ascii_plot(np.asarray(diag.apply(jnp.asarray(pts))),
               "diagonal chain (translate+scale+translate) -- VPU-only plan")
    print(f"diagonal chain: is_diagonal={diag.is_diagonal}, "
          f"plan={diag.plan_kind}")

    # projective pipeline (graphics companion paper): lift the house into
    # 3D, view it through camera -> perspective -> cull -> viewport -- the
    # whole chain folds to one (H, lo, hi) plan, one fused launch with the
    # perspective divide and frustum-cull mask in-kernel
    from repro import graphics
    pts3 = np.concatenate([pts, np.zeros((len(pts), 1), np.float32)], axis=1)
    cam = graphics.Camera(eye=(4.0, 3.0, 8.0), target=(0.0, 1.0, 0.0),
                          fov_y=np.pi / 4, near=0.5, far=30.0)
    view = graphics.viewing_chain(
        camera=cam, viewport=graphics.Viewport(0.0, 0.0, 24.0, 24.0))
    projected, mask = view.project(jnp.asarray(pts3))
    ascii_plot(np.asarray(projected)[np.asarray(mask)][:, :2],
               "perspective-projected house (camera+divide+cull+viewport) "
               "-- one projective plan")
    print(f"projective chain: {len(view)} primitives, plan={view.plan_kind}, "
          f"{int(np.sum(np.asarray(mask)))}/{len(pts3)} points in frustum")

    # the same ops on the emulated M1, fixed point, with cycle counts
    fp = (pts * 100).astype(np.int16)   # Q7-ish fixed point
    fp = np.pad(fp, ((0, (-len(fp)) % 64), (0, 0)))[:64]  # one full RC array
    r = programs.run_translation(fp[:64, 0], fp[:64, 1])
    print(f"\nM1 emulator: 64-elem translation in {r.cycles} cycles "
          f"(Table 5: 96)")
    r = programs.run_scaling(fp[:64, 0], 2)
    print(f"M1 emulator: 64-elem scaling in {r.cycles} cycles (Table 5: 55)")
    pts8 = np.stack([np.arange(8), np.arange(8)]).astype(np.int16)
    r = programs.run_rotation_points((3, 4), pts8)   # scaled rotation matrix
    print(f"M1 emulator: 8-point rotation in {r.cycles} cycles "
          f"(2x2 matrix algorithm)")


if __name__ == "__main__":
    main()
