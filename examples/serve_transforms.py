"""Geometry-serving demo: many composite-transform requests, few launches.

A miniature of the serving story end to end: a handful of *chain shapes*
(sprite placement, 3D pose, a custom projective touch-up) each arrive many
times with fresh parameters and differently-sized point sets.  The
GeometryServer buckets them by structure + size class, so the whole
workload runs in a handful of fused kernel launches -- and every result is
checked against its own per-request ``TransformChain.apply``.

    PYTHONPATH=src python examples/serve_transforms.py
    PYTHONPATH=src python examples/serve_transforms.py --smoke   # CI

``--smoke`` shrinks the workload so CI can execute this documented command
in seconds.  ``--autotune`` turns on the tuning cache
(``repro.autotune.set_enabled``): the server's size grid and the chain
kernels' launch parameters come from the committed winners file instead
of the hardcoded defaults -- results are identical either way (the knobs
steer staging, never arithmetic), only the schedule changes.
"""
import argparse

import numpy as np
import jax.numpy as jnp

from repro import serving
from repro.core.transform_chain import TransformChain


def sprite_place(rng) -> TransformChain:
    """2D sprite placement: scale, spin, drop -- the paper's composite."""
    return (TransformChain.identity(2)
            .scale(*rng.uniform(0.5, 2.0, 2).tolist())
            .rotate(float(rng.uniform(-np.pi, np.pi)))
            .translate(*rng.uniform(-10, 10, 2).tolist()))


def pose_3d(rng) -> TransformChain:
    """3D pose: yaw about z, then scale and offset."""
    return (TransformChain.identity(3)
            .rotate(float(rng.uniform(-np.pi, np.pi)), axis="z")
            .scale(float(rng.uniform(0.5, 1.5)))
            .translate(*rng.uniform(-5, 5, 3).tolist()))


def nudge_2d(rng) -> TransformChain:
    """Diagonal-only touch-up: folds to one affine, never builds a matrix."""
    return (TransformChain.identity(2)
            .translate(*rng.uniform(-1, 1, 2).tolist())
            .scale(*rng.uniform(0.9, 1.1, 2).tolist())
            .translate(*rng.uniform(-1, 1, 2).tolist()))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload; what CI runs")
    ap.add_argument("--autotune", action="store_true",
                    help="serve under the tuning-cache size grid instead "
                         "of the default (results are bit-compatible; "
                         "the launch schedule changes)")
    args = ap.parse_args()
    if args.autotune:
        import repro.autotune
        repro.autotune.set_enabled(True)
    n_requests = 12 if args.smoke else args.requests
    max_pts = 64 if args.smoke else 512

    rng = np.random.default_rng(0)
    makers = [sprite_place, pose_3d, nudge_2d]
    requests = []
    for i in range(n_requests):
        chain = makers[i % len(makers)](rng)
        n = int(rng.lognormal(np.log(max_pts / 4), 0.6))
        pts = rng.standard_normal((max(1, min(n, max_pts)), chain.dim))
        requests.append((chain, pts.astype(np.float32)))

    serving.reset_stats()
    server = serving.GeometryServer(backend="ref")
    results = server.serve(requests)

    stats = serving.stats
    print(f"served {stats['requests']} requests in {stats['launches']} "
          f"launches ({stats['buckets']} plan buckets, "
          f"{stats['plan_compiles']} plans compiled)")
    for rep in server.last_report:
        print(f"  bucket {rep.structure:<8} plan={rep.kind:<6} "
              f"lpad={rep.lpad:<4} requests={rep.requests:<3} "
              f"waste={rep.waste:.0%}")

    # every packed result checked against its own per-request apply
    for (chain, pts), out in zip(requests, results):
        expect = np.asarray(chain.apply(jnp.asarray(pts), backend="ref"))
        np.testing.assert_allclose(out, expect, rtol=2e-6, atol=2e-6)
    print(f"all {n_requests} packed results match per-request apply")


if __name__ == "__main__":
    main()
