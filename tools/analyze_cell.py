import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Per-cell profile from the compiled dry-run: top HBM instructions, top
dot FLOPs, collective breakdown with op_names -- the 'profiler' of the
hypothesis->change->measure loop (no real TPU, so the lowered IR is the
profile; see system prompt / DESIGN.md)."""
import argparse
import re


from repro import hlo_analysis as H
from repro.launch import cells
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=18)
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cell = cells.build_cell(args.arch, args.shape, mesh)
    text = cell.lowered.compile().as_text()
    if args.save_hlo:
        open(args.save_hlo, "w").write(text)
    mod = H.Module(text)

    def opname(line):
        m = re.search(r'op_name="([^"]+)"', line)
        return (m.group(1)[-80:] if m else "")

    mem_rows, dot_rows, coll_rows = [], [], []
    for c in mod.computations.values():
        m = mod.mult.get(c.name, 0)
        if m == 0:
            continue
        for i in c.instrs:
            if not c.is_fusion and i.opcode not in H.Module._SKIP_MEM \
                    and "-done" not in i.opcode:
                mem_rows.append((2 * mod._effective_out_bytes(i) * m, i, m))
            if i.opcode in ("dot", "convolution"):
                shapes = H._out_elems_dims(i.out_shape_text)
                oe = sum(int(__import__("numpy").prod(d)) if d else 1
                         for _, d in shapes)
                dot_rows.append((2 * oe * mod._contraction_size(i) * m, i, m))
            op = i.opcode[:-6] if i.opcode.endswith("-start") else i.opcode
            if op in H.COLLECTIVES and not c.is_fusion:
                coll_rows.append(((mod._operand_bytes(i) or i.out_bytes) * m,
                                  i, m))

    print(f"== {args.arch} x {args.shape} "
          f"({'2x16x16' if args.multi_pod else '16x16'}) ==")
    print(f"total hbm: {sum(r[0] for r in mem_rows)/1e12:.2f} TB | "
          f"flops: {mod.flops()/1e12:.2f} T | "
          f"coll: {sum(r[0] for r in coll_rows)/1e9:.1f} GB")
    print("\n-- top HBM --")
    for b, i, m in sorted(mem_rows, key=lambda r: -r[0])[:args.top]:
        print(f"{b/1e9:9.1f} GB x{m:6.0f} {i.opcode:14s} "
              f"{i.out_shape_text[:46]:<46s} {opname(i.line)}")
    print("\n-- top dot flops --")
    for f, i, m in sorted(dot_rows, key=lambda r: -r[0])[:args.top]:
        print(f"{f/1e12:9.2f} T  x{m:6.0f} {i.out_shape_text[:46]:<46s} "
              f"{opname(i.line)}")
    print("\n-- top collectives --")
    for b, i, m in sorted(coll_rows, key=lambda r: -r[0])[:args.top]:
        print(f"{b/1e9:9.1f} GB x{m:6.0f} {i.opcode:22s} "
              f"{i.out_shape_text[:40]:<40s} {opname(i.line)}")


if __name__ == "__main__":
    main()
