"""Trend gate: directional drift across the committed BENCH trajectory.

The exact-match gate (``tools/check_bench.py``) pins a fresh run against
the LATEST committed ``benchmarks/BENCH_*.json`` -- it cannot see a PR
that regresses a counter and commits the regressed value, because the
fresh run matches the new record exactly.  This gate reads the WHOLE
committed trajectory (``repro.obs.bench_history``) and fails when any
lower-is-better counter (launches, padded points, HBM bytes, lost
requests, failures) worsened between consecutive committed records for
the same row.  CI runs it in the profile-smoke lane:

    PYTHONPATH=src python tools/bench_trend.py

Exit status 0 = trajectory clean; 1 = directional regressions (each
printed); 2 = fewer than two committed records (nothing to compare).
``--report`` writes the markdown drift summary; ``--series row field``
prints one counter's trajectory.
"""
from __future__ import annotations

import argparse
import os
import sys

# keep `python tools/bench_trend.py` working from the repo root without
# PYTHONPATH (the src layout, same shim as benchmarks/run.py)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.obs import bench_history  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python tools/bench_trend.py")
    ap.add_argument("--bench-dir",
                    default=os.path.join(_ROOT, "benchmarks"),
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--report", default=None, metavar="OUT.md",
                    help="write the markdown drift summary here")
    ap.add_argument("--series", nargs=2, default=None,
                    metavar=("ROW", "FIELD"),
                    help="print one counter's trajectory and exit")
    args = ap.parse_args(argv)

    history = bench_history.load_history(args.bench_dir)
    if args.series:
        row, field = args.series
        for name, value in bench_history.series(history, row, field):
            print(f"{name}: {value}")
        return 0
    if len(history) < 2:
        print(f"bench_trend: only {len(history)} committed record(s) in "
              f"{args.bench_dir}; nothing to compare", file=sys.stderr)
        return 2
    regressions = bench_history.find_regressions(history)
    print(f"bench_trend: {len(history)} committed records "
          f"({history[0].name} .. {history[-1].name})")
    if args.report:
        with open(args.report, "w") as f:
            f.write(bench_history.drift_report(history))
        print(f"bench_trend: wrote {args.report}")
    for r in regressions:
        print(f"  REGRESSION: {r}", file=sys.stderr)
    if regressions:
        return 1
    print("  directional counters never worsened -- trajectory clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
