"""Perf-regression gate: a fresh benchmark run vs the committed BENCH json.

CI runs ``benchmarks/run.py --smoke ... --out <scratch>.json`` and then

    python tools/check_bench.py <scratch>.json

which compares the fresh rows against the LATEST committed
``benchmarks/BENCH_*.json`` (lexicographically last filename -- the
timestamped naming makes that the newest).  Only DETERMINISTIC counter
fields are compared -- launch counts, HBM-byte totals, cycle counts,
parity/match flags, padding ratios, config labels -- and they must match
EXACTLY: every one of them is a pure function of committed code plus
seeded workloads, so any drift is a real behaviour change (a bucketing
regression, a byte-accounting change, a lost fusion), not timer noise.
Wall-clock fields (``us_per_call``, ``speedup_*``, ``elems_per_us``,
...) are ignored.

Rows are matched by name over the INTERSECTION of the two files, so a
committed record produced with more flags than the fresh run (extra row
groups) gates only on what the fresh run reproduced; the ``--require``
names (and a minimum overlap) guard against the intersection silently
collapsing to nothing.

Exit status 0 = gate passed; 1 = mismatches (each printed); 2 = the
comparison itself is invalid (no committed record, no overlap, missing
required rows).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

#: derived fields that are deterministic given the committed code +
#: seeded workloads (everything else -- wall clocks and ratios of wall
#: clocks -- is noise and never gated on)
DETERMINISTIC_FIELDS = frozenset({
    "requests", "launches", "launches_saved", "buckets", "shards",
    "cycles", "parity", "match", "model", "paper", "emulator",
    "hbm_bytes", "hbm_passes", "points", "padding_waste",
    "payload_points", "padded_points", "projective_requests",
    "projective_buckets", "points_inside", "primitives_folded",
    "byte_ratio_vs_f32", "byte_ratio_vs_staged", "config", "plan",
    "fusion_saves", "paper_speedup", "predicted_launches_default",
    "predicted_launches_tuned", "measured_launches_default",
    "measured_launches_tuned", "model_launches_exact",
    # fault-tolerance counters (chaos_* rows): the seeded soak's recovery
    # machinery is deterministic end-to-end, so every counter -- and
    # above all lost=0 / mismatches=0 -- gates exactly
    "malformed", "rejected_at_submit", "resolved", "failed_requests",
    "lost", "mismatches", "faulted_buckets", "launch_failures", "retries",
    "backend_fallbacks", "bisections", "recovered_requests", "q_fallbacks",
    "injected_launch_faults", "injected_corruptions", "launches_clean",
    "launches_chaos", "extra_launches",
    # continuous-batching counters (soak_* rows): arrivals, admission
    # decisions, flush scheduling, and even the latency percentiles are
    # VIRTUAL-clock quantities -- pure functions of the seed -- so they
    # gate exactly alongside the launch economy ("virtual" in the name
    # is the marker separating them from never-gated wall-clock fields)
    "admitted", "rate_limited", "queue_full", "failed", "polls",
    "p50_virtual_us", "p99_virtual_us", "virtual_rps",
    # observability (soak_trace{,_overhead} rows): span/event counts of
    # the virtual-clock traced soak are exact, and counters_identical=1
    # pins that tracing never steers the serving stack
    "trace_spans", "trace_events", "counters_identical",
    # profiler attribution (profile_attrib rows): the folded span
    # stream's counters plus the two exactness flags -- the attribution
    # tree reproduces stats["launches"] and every observed/predicted
    # HBM-byte ratio is exactly 1.0 (shared opcount/costmodel formula)
    "events", "spans", "kernels", "launch_buckets", "pred_hbm_bytes",
    "pred_flops", "pred_m1_cycles", "byte_ratio_exact",
    "attribution_exact",
    # SLO burn-rate monitor (slo_burn rows): the scripted error-budget
    # train's alert count and its exact virtual fire/resolve instants,
    # plus the monitored async drive's event flow
    "latency_alerts_fired", "latency_first_fire_us",
    "latency_first_resolve_us", "latency_bad_events",
    "served_latency_events", "served_rejections_events",
    "served_alerts_fired",
    # scene-graph counters (scene_* rows): the animated edit schedule is
    # fixed and the fold CSE is content-keyed, so fold work (== dirtied
    # subtree sizes), cache hits, and the bitwise equality flags are all
    # exact -- folds drifting up means the incremental-refold claim broke
    "frames", "nodes", "leaves", "dirtied", "folds", "folds_per_frame",
    "cse_hits", "refolds", "equal", "scene_vs_chain_equal",
    "fold_ratio_vs_scene", "scene_folds_per_frame",
})

#: rows whose presence (in BOTH files) the gate insists on -- the launch
#: economy, the fixed-point byte claim, and the fault-recovery counters
#: cannot quietly fall out of the comparison
DEFAULT_REQUIRED = (
    "chain_serving_batched_smoke",
    "fixedpoint_serving_q8_7_smoke",
    "chaos_soak_smoke",
    "scene_anim_smoke",
)

MIN_OVERLAP = 10


def latest_committed(bench_dir: str) -> str | None:
    paths = sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))
    return paths[-1] if paths else None


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        doc = json.load(f)
    return {row["name"]: row for row in doc.get("rows", [])}


def compare(fresh: dict[str, dict], committed: dict[str, dict],
            required=DEFAULT_REQUIRED) -> tuple[list[str], list[str]]:
    """Returns (mismatches, validity_errors)."""
    errors = []
    overlap = sorted(set(fresh) & set(committed))
    if len(overlap) < MIN_OVERLAP:
        errors.append(f"only {len(overlap)} overlapping rows (< "
                      f"{MIN_OVERLAP}): the comparison is vacuous")
    for name in required:
        if name not in fresh:
            errors.append(f"required row {name!r} missing from the fresh "
                          "run")
        if name not in committed:
            errors.append(f"required row {name!r} missing from the "
                          "committed record")
    mismatches = []
    for name in overlap:
        f_row, c_row = fresh[name], committed[name]
        for key in sorted(set(c_row) & DETERMINISTIC_FIELDS):
            # a deterministic counter the committed row carries must also
            # exist in the fresh row -- a renamed/dropped field must fail
            # the gate, not silently fall out of the comparison
            if key not in f_row:
                mismatches.append(
                    f"{name}: deterministic field {key!r} present in the "
                    "committed row but missing from the fresh run")
            elif f_row[key] != c_row[key]:
                mismatches.append(
                    f"{name}: {key} = {f_row[key]!r} (fresh) vs "
                    f"{c_row[key]!r} (committed)")
    return mismatches, errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python tools/check_bench.py")
    ap.add_argument("fresh", help="BENCH json written by the fresh run")
    ap.add_argument("--bench-dir",
                    default=os.path.join(os.path.dirname(os.path.dirname(
                        os.path.abspath(__file__))), "benchmarks"),
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--require", nargs="*", default=list(DEFAULT_REQUIRED),
                    help="row names that must exist in both files")
    args = ap.parse_args(argv)

    committed_path = latest_committed(args.bench_dir)
    if committed_path is None:
        print(f"check_bench: no committed BENCH_*.json in "
              f"{args.bench_dir}", file=sys.stderr)
        return 2
    fresh = load_rows(args.fresh)
    committed = load_rows(committed_path)
    mismatches, errors = compare(fresh, committed,
                                 required=tuple(args.require))
    overlap = len(set(fresh) & set(committed))
    print(f"check_bench: {args.fresh} vs {committed_path} "
          f"({overlap} shared rows)")
    for e in errors:
        print(f"  INVALID: {e}", file=sys.stderr)
    for m in mismatches:
        print(f"  REGRESSION: {m}", file=sys.stderr)
    if errors:
        return 2
    if mismatches:
        return 1
    print("  deterministic counters match exactly -- gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
