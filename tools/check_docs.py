"""Docs link checker: every relative markdown link must resolve.

Scans the given markdown files (or every ``*.md`` under given
directories) for ``[text](target)`` links, skips absolute URLs and
anchors, and verifies each remaining target exists relative to the file
that references it.  CI runs this over README.md, docs/, tests/ and
benchmarks/ so documentation cannot point at files that moved or never
existed.

    python tools/check_docs.py README.md docs tests/README.md
"""
from __future__ import annotations

import pathlib
import re
import sys

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def collect(paths: list[str]) -> list[pathlib.Path]:
    files: list[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.md")))
        else:
            files.append(path)
    return files


def check(files: list[pathlib.Path]) -> list[str]:
    errors = []
    for md in files:
        if not md.exists():
            errors.append(f"{md}: file not found")
            continue
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for target in _LINK.findall(line):
                if target.startswith(_SKIP_PREFIXES):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                if not (md.parent / rel).exists():
                    errors.append(f"{md}:{lineno}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    files = collect(argv or ["README.md", "docs"])
    errors = check(files)
    for e in errors:
        print(e)
    print(f"checked {len(files)} file(s): "
          f"{'FAIL' if errors else 'all links resolve'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
