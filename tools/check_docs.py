"""Docs checker: links must resolve, and quickstart commands must run.

Two passes over the given markdown files (or every ``*.md`` under given
directories):

1. **Links** (always): every relative ``[text](target)`` link must point
   at a file that exists relative to the referencing document --
   absolute URLs and ``#`` anchors are skipped.
2. **Commands** (``--exec``): every fenced code block tagged ``sh`` is a
   quickstart the reader will paste, so each command in it must exit 0
   when run from the repo root.  Comment lines and blank lines are
   skipped, trailing-backslash continuations join, and each command gets
   its own subprocess (no state leaks between commands beyond the
   filesystem).  CI's docs job runs the exec pass over README.md and
   docs/, which is what keeps documented commands from rotting.

    python tools/check_docs.py README.md docs tests/README.md
    python tools/check_docs.py --exec README.md docs
"""
from __future__ import annotations

import argparse
import pathlib
import re
import subprocess
import sys

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")
#: only blocks explicitly tagged as shell are executable quickstarts;
#: untagged fences (ASCII diagrams, span trees) and other languages
#: (python, json) are prose
_SH_FENCE = re.compile(r"^```sh\s*$")
_FENCE_END = re.compile(r"^```\s*$")


def collect(paths: list[str]) -> list[pathlib.Path]:
    files: list[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.md")))
        else:
            files.append(path)
    return files


def check(files: list[pathlib.Path]) -> list[str]:
    errors = []
    for md in files:
        if not md.exists():
            errors.append(f"{md}: file not found")
            continue
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for target in _LINK.findall(line):
                if target.startswith(_SKIP_PREFIXES):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                if not (md.parent / rel).exists():
                    errors.append(f"{md}:{lineno}: broken link -> {target}")
    return errors


def sh_commands(md: pathlib.Path) -> list[tuple[int, str]]:
    """(lineno, command) pairs from every ```sh fenced block: comments
    and blanks dropped, backslash continuations joined into one
    command."""
    out: list[tuple[int, str]] = []
    in_sh = False
    pending: list[str] = []
    pending_line = 0
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        if not in_sh:
            in_sh = bool(_SH_FENCE.match(line))
            continue
        if _FENCE_END.match(line):
            in_sh = False
            pending = []
            continue
        stripped = line.strip()
        if not pending and (not stripped or stripped.startswith("#")):
            continue
        if not pending:
            pending_line = lineno
        if stripped.endswith("\\"):
            pending.append(stripped[:-1].strip())
            continue
        pending.append(stripped)
        out.append((pending_line, " ".join(pending)))
        pending = []
    return out


def run_commands(files: list[pathlib.Path], root: pathlib.Path) -> list[str]:
    errors = []
    total = 0
    for md in files:
        if not md.exists():
            continue
        for lineno, cmd in sh_commands(md):
            total += 1
            print(f"[exec] {md}:{lineno}: {cmd}", flush=True)
            proc = subprocess.run(cmd, shell=True, cwd=root,
                                  stdout=subprocess.PIPE,
                                  stderr=subprocess.STDOUT, text=True)
            if proc.returncode != 0:
                tail = "\n".join(proc.stdout.splitlines()[-15:])
                errors.append(f"{md}:{lineno}: exit {proc.returncode} "
                              f"from: {cmd}\n{tail}")
    print(f"executed {total} documented command(s)")
    return errors


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python tools/check_docs.py")
    ap.add_argument("paths", nargs="*", default=None,
                    help="markdown files or directories (default: "
                         "README.md docs)")
    ap.add_argument("--exec", dest="execute", action="store_true",
                    help="additionally run every ```sh fenced command "
                         "from the repo root; any nonzero exit fails")
    args = ap.parse_args(argv)

    files = collect(args.paths or ["README.md", "docs"])
    errors = check(files)
    root = pathlib.Path(__file__).resolve().parent.parent
    if args.execute and not errors:
        errors += run_commands(files, root)
    for e in errors:
        print(e)
    if errors:
        status = "FAIL"
    else:
        status = "all links resolve"
        if args.execute:
            status += " + all commands ran"
    print(f"checked {len(files)} file(s): {status}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
