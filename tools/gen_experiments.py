"""Regenerate the data-driven tables of EXPERIMENTS.md from
results/dryrun.jsonl.  Hand-written sections (Faithful, Perf) live in
EXPERIMENTS.md between markers and are preserved."""
from __future__ import annotations

import json
import sys

ADVICE = {
    "memory": "fuse/keep score+gate intermediates in VMEM (Pallas) or cut "
              "saved residual bytes (bf16 scores, recompute masks)",
    "collective": "reduce per-microbatch weight gathers (fewer accum steps, "
                  "quantized collectives) or switch the MoE to EP all-to-all",
    "compute": "already compute-bound: raise MXU utilisation via larger "
               "microbatch or fused kernels",
}


def load(path="results/dryrun.jsonl"):
    recs = {}
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def dryrun_table(recs, mesh):
    out = ["| arch | shape | kind | status | live GB/dev | compile s | "
           "accum | collective GB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        if r["status"] == "SKIP":
            out.append(f"| {a} | {s} | - | SKIP: {r['reason'][:60]} | | | | |")
            continue
        b = r["bytes_per_device"]
        out.append(
            f"| {a} | {s} | {r['kind']} | OK | "
            f"{b['total_live']/1e9:.1f} | {r['compile_s']} | "
            f"{r.get('accum_steps') or '-'} | "
            f"{r['collective_bytes_per_device']/1e9:.1f} |")
    return "\n".join(out)


def roofline_table(recs, mesh="16x16"):
    out = ["| arch | shape | t_compute s | t_memory s | t_collective s | "
           "bottleneck | MODEL/HLO flops | roofline frac | what would move it |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(recs.items()):
        if m != mesh or r["status"] != "OK":
            continue
        rf = r["roofline"]
        out.append(
            f"| {a} | {s} | {rf['t_compute']:.4f} | {rf['t_memory']:.4f} | "
            f"{rf['t_collective']:.4f} | {rf['bottleneck']} | "
            f"{rf['useful_flops_ratio']:.3f} | "
            f"{rf['roofline_fraction']*100:.2f}% | "
            f"{ADVICE[rf['bottleneck']]} |")
    return "\n".join(out)


def main():
    recs = load()
    text = open("EXPERIMENTS.md").read()
    for marker, table in [
        ("DRYRUN_16x16", dryrun_table(recs, "16x16")),
        ("DRYRUN_2x16x16", dryrun_table(recs, "2x16x16")),
        ("ROOFLINE_16x16", roofline_table(recs)),
    ]:
        begin, end = f"<!-- BEGIN {marker} -->", f"<!-- END {marker} -->"
        pre, rest = text.split(begin)
        _, post = rest.split(end)
        text = pre + begin + "\n" + table + "\n" + end + post
    open("EXPERIMENTS.md", "w").write(text)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    sys.exit(main())
