"""Autotune benchmark: tuned vs default launch parameters, same workload.

``benchmarks/run.py --autotune`` runs this module: one seeded mixed
workload (bit-identical request mix for both sides -- the SAME seeded
workloads ``repro.autotune.search`` tunes on, so the cache entry is a
grid tuned for exactly this traffic) is served through the GeometryServer
twice, once under the deterministic default size grid and once under the
tuned grid from the tuning cache (the committed ``default_cache.json``
winners at this workload's size class, or a fresh pruned search when the
cache has no such entry).  The rows record launches, padding, and
wall-clock for each side, so ``BENCH_<ts>.json`` captures
tuned-vs-default as data, not prose:

  * ``autotune_serving_default`` -- default grid (min_len=8, cap=0.5);
  * ``autotune_serving_tuned``   -- tuned grid, with ``launches_saved``
    and ``speedup_vs_default`` derived fields and the exact config used.

A third row, ``autotune_model_residual``, records the cost model's
predicted launch ratio next to the measured one -- the paper's
predict-then-validate loop applied to the tuner itself.
"""
from __future__ import annotations

from repro import serving
from repro.autotune import cache as tcache
from repro.autotune import costmodel, search
from repro.serving.workload import timed as _timed


def _serve_stats(reqs, backend: str, min_len: int, waste_cap: float,
                 iters: int):
    """Best-of-``iters`` wall-clock + per-flush launch/padding stats for
    one grid configuration (explicit knobs: the cache is bypassed)."""
    srv = serving.GeometryServer(backend=backend, min_len=min_len,
                                 waste_cap=waste_cap)
    srv.serve(reqs)                              # warm plans + jit shapes
    serving.reset_stats()
    best = min(_timed(lambda: srv.serve(reqs)) for _ in range(iters))
    st = serving.stats
    launches = st["launches"] // iters
    padded = st["padded_points"] // iters
    payload = st["payload_points"] // iters
    return best, launches, payload, padded


def run(smoke: bool = False) -> list[str]:
    tag = "_smoke" if smoke else ""
    iters = 2 if smoke else 5
    reqs = search.smoke_workload() if smoke else search.bench_workload()
    n_requests = len(reqs)
    backend = "ref"

    default = tcache.DEFAULTS["serving_grid"]
    # the committed winner for THIS workload's size class (grids are
    # tuned per traffic scale); tune fresh if the cache has none
    tuned = tcache.the_cache().get("serving_grid", backend, "float32",
                                   search.workload_size_class_n(reqs))
    if tuned is None:
        rep = search.tune_serving_grid(reqs, backend, iters=iters)
        tuned = rep.winner

    d_us, d_launch, payload, d_pad = _serve_stats(
        reqs, backend, default.grid_min_len, default.grid_waste_cap, iters)
    t_us, t_launch, _, t_pad = _serve_stats(
        reqs, backend, tuned.grid_min_len, tuned.grid_waste_cap, iters)

    # predicted launch economy from the cost model, for the residual row
    shape = costmodel.workload_shape(reqs)
    pred_d = costmodel.grid_cost(shape, default.grid_min_len,
                                 default.grid_waste_cap)
    pred_t = costmodel.grid_cost(shape, tuned.grid_min_len,
                                 tuned.grid_waste_cap)

    rows = [
        f"autotune_serving_default{tag},{d_us * 1e6:.1f},"
        f"requests={n_requests};launches={d_launch};"
        f"padded_points={d_pad};payload_points={payload};"
        f"config={default.describe()}",
        f"autotune_serving_tuned{tag},{t_us * 1e6:.1f},"
        f"requests={n_requests};launches={t_launch};"
        f"launches_saved={d_launch - t_launch};"
        f"padded_points={t_pad};"
        f"speedup_vs_default={d_us / t_us:.2f}x;"
        f"config={tuned.describe()}",
        f"autotune_model_residual{tag},{t_us * 1e6:.1f},"
        f"predicted_launches_default={pred_d.launches};"
        f"predicted_launches_tuned={pred_t.launches};"
        f"measured_launches_default={d_launch};"
        f"measured_launches_tuned={t_launch};"
        f"model_launches_exact={pred_d.launches == d_launch and pred_t.launches == t_launch}",
    ]
    print(f"[autotune] {n_requests} requests: default grid "
          f"{d_us * 1e3:.1f} ms / {d_launch} launches vs tuned "
          f"{t_us * 1e3:.1f} ms / {t_launch} launches "
          f"({tuned.describe()}) -> {d_us / t_us:.2f}x, "
          f"{d_launch - t_launch} launches saved")
    return rows
