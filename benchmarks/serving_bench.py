"""Serving-engine benchmark: plan-bucketed batched dispatch vs per-request.

The ``chain_serving_*`` rows time one 64-request mixed workload (bounded
structure pool, lognormal sizes -- see ``repro.serving.workload``) served
two ways on the CPU ref backend:

  * ``chain_serving_per_request`` -- every request through its own
    ``TransformChain.apply``: plan-cache hits, but one kernel launch (and
    one dispatch round-trip) per request;
  * ``chain_serving_batched``    -- the same requests through
    ``GeometryServer``: bucketed by structure + size class, one launch per
    bucket, staging double-buffered against compute.

Derived fields record the launch economy (launches, launches_saved,
padding waste) next to the wall-clock speedup, so the row shows WHY the
batched path wins, not just that it does.  See benchmarks/PERF.md.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro import serving
from repro.serving import workload
from repro.serving.workload import timed as _timed


def _build_workload(n_requests: int, max_points: int, n_templates: int,
                    seed: int = 7):
    # explicit end-to-end seed: the same (seed, args) always yields a
    # bit-identical request mix (see repro.serving.workload)
    return workload.random_workload(
        seed=seed, n_requests=n_requests, max_points=max_points,
        templates=workload.TEMPLATES[:n_templates])


def run(smoke: bool = False) -> list[str]:
    tag = "_smoke" if smoke else ""
    iters = 2 if smoke else 5
    n_requests = 24 if smoke else 64
    # smoke: fewer structures so the tiny request count still fills
    # buckets (the liveness check should exercise a batched win, not a
    # degenerate one-request-per-bucket schedule)
    reqs = _build_workload(n_requests, max_points=96 if smoke else 1024,
                           n_templates=4 if smoke else len(workload.TEMPLATES))

    # per-request dispatch baseline (warm plan cache, results to host)
    for chain, pts in reqs:
        chain.apply(jnp.asarray(pts), backend="ref")
    best_single = min(
        _timed(lambda: [np.asarray(chain.apply(jnp.asarray(pts),
                                               backend="ref"))
                        for chain, pts in reqs])
        for _ in range(iters))

    # batched bucket execution (warm batch plans, same workload)
    srv = serving.GeometryServer(backend="ref")
    srv.serve(reqs)
    serving.reset_stats()
    best_batched = min(_timed(lambda: srv.serve(reqs)) for _ in range(iters))
    st = serving.stats
    launches = st["launches"] // iters
    waste = 1 - st["payload_points"] / max(1, st["padded_points"])

    rows = [
        f"chain_serving_per_request{tag},{best_single * 1e6:.1f},"
        f"requests={n_requests};launches={n_requests}",
        f"chain_serving_batched{tag},{best_batched * 1e6:.1f},"
        f"requests={n_requests};launches={launches};"
        f"launches_saved={n_requests - launches};"
        f"padding_waste={waste:.2f};"
        f"speedup_vs_per_request={best_single / best_batched:.2f}x",
    ]
    print(f"[serving] {n_requests} requests: per-request "
          f"{best_single * 1e3:.1f} ms ({n_requests} launches) vs batched "
          f"{best_batched * 1e3:.1f} ms ({launches} launches) -> "
          f"{best_single / best_batched:.2f}x")
    return rows
