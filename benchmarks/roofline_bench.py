"""Roofline table from the dry-run sweep (results/dryrun.jsonl).

One row per (arch x shape x mesh) cell: the three terms in seconds, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline fraction.
This is the source table for EXPERIMENTS.md section Roofline.
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), os.pardir, "results",
                       "dryrun.jsonl")


def load(path: str = RESULTS) -> list[dict]:
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # last wins
    return list(recs.values())


def format_rows(recs: list[dict], mesh: str = "16x16") -> list[str]:
    rows = []
    hdr = (f"{'arch':<22}{'shape':<13}{'kind':<8}{'t_comp':>9}{'t_mem':>9}"
           f"{'t_coll':>9}{'bound':>11}{'useful':>8}{'roof%':>8}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "SKIP":
            print(f"{r['arch']:<22}{r['shape']:<13}SKIP     ({r['reason'][:48]})")
            rows.append(f"roofline_{r['arch']}_{r['shape']},0,SKIP")
            continue
        if r["status"] != "OK":
            print(f"{r['arch']:<22}{r['shape']:<13}FAIL     {r.get('error','')[:60]}")
            rows.append(f"roofline_{r['arch']}_{r['shape']},0,FAIL")
            continue
        rf = r["roofline"]
        print(f"{r['arch']:<22}{r['shape']:<13}{r['kind']:<8}"
              f"{rf['t_compute']:>9.4f}{rf['t_memory']:>9.4f}"
              f"{rf['t_collective']:>9.4f}{rf['bottleneck']:>11}"
              f"{rf['useful_flops_ratio']:>8.3f}"
              f"{rf['roofline_fraction']*100:>7.2f}%")
        rows.append(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
            f"{max(rf['t_compute'], rf['t_memory'], rf['t_collective'])*1e6:.0f},"
            f"bottleneck={rf['bottleneck']};roof_frac={rf['roofline_fraction']:.4f}")
    return rows


def run() -> list[str]:
    recs = load()
    if not recs:
        print("roofline: no results/dryrun.jsonl yet -- run "
              "PYTHONPATH=src python -m repro.launch.dryrun --all "
              "--out results/dryrun.jsonl")
        return ["roofline_table,0,missing_results"]
    out = []
    for mesh in ("16x16", "2x16x16"):
        if any(r["mesh"] == mesh for r in recs):
            print(f"\n== mesh {mesh} ==")
            out.extend(format_rows(recs, mesh))
    return out
