"""Kernel microbenchmarks: the paper's metrics applied to the TPU mapping.

The paper reports elements/cycle for its vector routines on the M1 at
100 MHz.  We benchmark the same primitive classes through the public kernel
API (ref backend -- the XLA path that the dry-run lowers; the Pallas bodies
are validated separately in interpret mode, which is a correctness
interpreter, not a performance path) and report us/call plus the derived
elements/us.  On-CPU numbers calibrate nothing about the TPU -- the TPU
projection column divides the memory-bound byte volume by v5e HBM bandwidth
(these ops are all memory-bound; see EXPERIMENTS.md section Perf).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels
from repro.roofline import HBM_BW


def _time(fn, *args, iters: int = 20) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(0)

    # vector-vector (translation) and vector-scalar (scaling), 1M elements
    m, n = 1024, 1024
    x = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    z = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    s = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((n,)), jnp.float32)

    vecadd = jax.jit(lambda a, b: kernels.vecadd(a, b))
    us = _time(vecadd, x, z)
    tpu_us = 3 * x.size * 4 / HBM_BW * 1e6
    rows.append(f"kernel_vecadd_translation_1M,{us:.1f},"
                f"elems_per_us={x.size/us:.0f};tpu_projection_us={tpu_us:.1f}")

    scale = jax.jit(lambda a, b: kernels.scale(a, b))
    us = _time(scale, x, s)
    rows.append(f"kernel_scale_scaling_1M,{us:.1f},"
                f"elems_per_us={x.size/us:.0f};tpu_projection_us={tpu_us:.1f}")

    affine = jax.jit(lambda a, b, c: kernels.affine(a, b, c))
    us = _time(affine, x, s, t)
    rows.append(f"kernel_affine_fused_1M,{us:.1f},"
                f"elems_per_us={x.size/us:.0f};fusion_saves=1x_hbm_pass")

    # rotation (rope) on a (8, 4096, 128) head block
    xr = jnp.asarray(rng.standard_normal((8, 4096, 128)), jnp.bfloat16)
    cos, sin = kernels.rope_tables(jnp.arange(4096), 128)
    rope = jax.jit(lambda a: kernels.rope(a, cos, sin))
    us = _time(rope, xr)
    rows.append(f"kernel_rope_rotation,{us:.1f},elems_per_us={xr.size/us:.0f}")

    # matmul (rotation/composite) 1024^3
    a = jnp.asarray(rng.standard_normal((1024, 1024)), jnp.bfloat16)
    b = jnp.asarray(rng.standard_normal((1024, 1024)), jnp.bfloat16)
    mm = jax.jit(lambda p, q: kernels.matmul(p, q))
    us = _time(mm, a, b)
    fl = 2 * 1024 ** 3
    rows.append(f"kernel_matmul_1k3,{us:.1f},"
                f"gflops_cpu={fl/us/1e3:.1f};tpu_projection_us={fl/197e12*1e6:.1f}")

    # rmsnorm fused (derived-scalar scaling)
    g = jnp.ones((n,), jnp.float32)
    rn = jax.jit(lambda p: kernels.rmsnorm(p, g))
    us = _time(rn, x)
    rows.append(f"kernel_rmsnorm_1M,{us:.1f},elems_per_us={x.size/us:.0f}")

    # blockwise attention (composite), 4k causal
    q = jnp.asarray(rng.standard_normal((1, 8, 4096, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 4096, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 4096, 64)), jnp.bfloat16)
    att = jax.jit(lambda a, b, c: kernels.attention(a, b, c))
    us = _time(att, q, k, v, iters=3)
    fl = 4 * 8 * 4096 * 4096 * 64 / 2
    rows.append(f"kernel_attention_4k,{us:.1f},gflops_cpu={fl/us/1e3:.1f}")
    return rows
