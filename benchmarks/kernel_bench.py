"""Kernel microbenchmarks: the paper's metrics applied to the TPU mapping.

The paper reports elements/cycle for its vector routines on the M1 at
100 MHz.  We benchmark the same primitive classes through the public kernel
API (ref backend -- the XLA path that the dry-run lowers; the Pallas bodies
are validated separately in interpret mode, which is a correctness
interpreter, not a performance path) and report us/call plus the derived
elements/us.  On-CPU numbers calibrate nothing about the TPU -- the TPU
projection column divides the memory-bound byte volume by v5e HBM bandwidth
(these ops are all memory-bound; see EXPERIMENTS.md section Perf).

The ``chain_*`` rows benchmark the paper's headline claim -- composite
transforms as ONE pass instead of one pass per primitive -- through the
fused transform-chain compiler; see ``benchmarks/PERF.md`` for what each
row means and the byte accounting behind the speedup.

``run(smoke=True)`` shrinks every shape and the iteration count so the
whole sweep finishes in seconds (the CI liveness pass); row names gain a
``_smoke`` suffix so small-shape numbers are never mistaken for the real
sweep.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels
from repro.autotune import cache as tuning
from repro.core import transform_chain as tc
from repro.core import transform_engine as te
from repro.kernels import dispatch
from repro.roofline import HBM_BW


def _cfg_tag(kernel: str, dtype: str, n: int) -> str:
    """Which launch config this row used: the same tuning-cache lookup the
    kernel itself performs (``default(...)`` when autotuning is off,
    ``cached(...)``/``tuned(...)`` winners otherwise)."""
    return tuning.config_for(kernel, dispatch.resolve(None), dtype,
                             n).describe()


def _time(fn, *args, iters: int = 20) -> float:
    out = fn(*args)               # one warmup call: compile + stage buffers
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def _chain_rows(rng, *, n_points: int, iters: int, tag: str) -> list[str]:
    """Fused one-pass chains vs sequential per-primitive dispatch (CPU ref)."""
    rows = []
    pts = jnp.asarray(rng.standard_normal((n_points, 2)), jnp.float32)
    sv = jnp.asarray([1.3, 0.8], jnp.float32)
    t1 = jnp.asarray([3.0, 2.0], jnp.float32)
    t2 = jnp.asarray([-1.0, 5.0], jnp.float32)
    theta = 0.3

    # length-4 general chain: translate . scale . rotate . translate
    def sequential(p):
        return te.translate(te.rotate(te.scale(te.translate(p, t2), sv),
                                      theta), t1)

    us_seq = _time(sequential, pts, iters=iters)
    rows.append(f"chain_sequential_len4{tag},{us_seq:.1f},"
                f"elems_per_us={pts.size / us_seq:.0f};hbm_passes=4")

    chain = (tc.TransformChain.identity(2)
             .translate(-1.0, 5.0).scale(1.3, 0.8).rotate(theta)
             .translate(3.0, 2.0))
    tc.clear_plan_cache()
    t0 = time.perf_counter()
    jax.block_until_ready(chain.apply(pts))
    cold_us = (time.perf_counter() - t0) * 1e6        # fold + trace + run
    us_fused = _time(chain.apply, pts, iters=iters)   # plan-cache hits
    rows.append(f"chain_fused_len4{tag},{us_fused:.1f},"
                f"elems_per_us={pts.size / us_fused:.0f};hbm_passes=1;"
                f"speedup_vs_sequential={us_seq / us_fused:.2f}x;"
                f"config={_cfg_tag('chain_apply', 'float32', n_points)}")
    rows.append(f"chain_plan_cache{tag},{us_fused:.1f},"
                f"cold_us={cold_us:.1f};"
                f"cachehit_speedup={cold_us / us_fused:.1f}x")

    # length-3 diagonal chain: folds to one affine, never touches the MXU
    def seq_diag(p):
        return te.translate(te.scale(te.translate(p, t2), sv), t1)

    us_seq_d = _time(seq_diag, pts, iters=iters)
    diag = (tc.TransformChain.identity(2)
            .translate(-1.0, 5.0).scale(1.3, 0.8).translate(3.0, 2.0))
    jax.block_until_ready(diag.apply(pts))
    us_diag = _time(diag.apply, pts, iters=iters)
    rows.append(f"chain_fused_diag_len3{tag},{us_diag:.1f},"
                f"elems_per_us={pts.size / us_diag:.0f};plan=diag_no_mxu;"
                f"sequential_us={us_seq_d:.1f};"
                f"speedup_vs_sequential={us_seq_d / us_diag:.2f}x;"
                f"config={_cfg_tag('chain_diag', 'float32', n_points)}")
    return rows


def run(smoke: bool = False) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    tag = "_smoke" if smoke else ""
    iters = 3 if smoke else 20

    # vector-vector (translation) and vector-scalar (scaling)
    m, n = (256, 256) if smoke else (1024, 1024)
    x = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    z = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    s = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((n,)), jnp.float32)

    vecadd = jax.jit(lambda a, b: kernels.vecadd(a, b))
    us = _time(vecadd, x, z, iters=iters)
    tpu_us = 3 * x.size * 4 / HBM_BW * 1e6
    rows.append(f"kernel_vecadd_translation{tag},{us:.1f},"
                f"elems_per_us={x.size/us:.0f};tpu_projection_us={tpu_us:.1f}")

    scale = jax.jit(lambda a, b: kernels.scale(a, b))
    us = _time(scale, x, s, iters=iters)
    rows.append(f"kernel_scale_scaling{tag},{us:.1f},"
                f"elems_per_us={x.size/us:.0f};tpu_projection_us={tpu_us:.1f}")

    affine = jax.jit(lambda a, b, c: kernels.affine(a, b, c))
    us = _time(affine, x, s, t, iters=iters)
    rows.append(f"kernel_affine_fused{tag},{us:.1f},"
                f"elems_per_us={x.size/us:.0f};fusion_saves=1x_hbm_pass")

    # composite transform chains (the paper's General Composite Algorithm)
    rows += _chain_rows(rng, n_points=1 << 12 if smoke else 1 << 19,
                        iters=iters, tag=tag)

    # rotation (rope) on a head block
    rope_shape = (2, 256, 128) if smoke else (8, 4096, 128)
    xr = jnp.asarray(rng.standard_normal(rope_shape), jnp.bfloat16)
    cos, sin = kernels.rope_tables(jnp.arange(rope_shape[1]), 128)
    rope = jax.jit(lambda a: kernels.rope(a, cos, sin))
    us = _time(rope, xr, iters=iters)
    rows.append(f"kernel_rope_rotation{tag},{us:.1f},elems_per_us={xr.size/us:.0f}")

    # matmul (rotation/composite)
    mm_n = 256 if smoke else 1024
    a = jnp.asarray(rng.standard_normal((mm_n, mm_n)), jnp.bfloat16)
    b = jnp.asarray(rng.standard_normal((mm_n, mm_n)), jnp.bfloat16)
    mm = jax.jit(lambda p, q: kernels.matmul(p, q))
    us = _time(mm, a, b, iters=iters)
    fl = 2 * mm_n ** 3
    rows.append(f"kernel_matmul{tag},{us:.1f},"
                f"gflops_cpu={fl/us/1e3:.1f};tpu_projection_us={fl/197e12*1e6:.1f};"
                f"config={_cfg_tag('matmul', 'bfloat16', mm_n * mm_n)}")

    # rmsnorm fused (derived-scalar scaling)
    g = jnp.ones((n,), jnp.float32)
    rn = jax.jit(lambda p: kernels.rmsnorm(p, g))
    us = _time(rn, x, iters=iters)
    rows.append(f"kernel_rmsnorm{tag},{us:.1f},elems_per_us={x.size/us:.0f};"
                f"config={_cfg_tag('rmsnorm', 'float32', x.size)}")

    # blockwise attention (composite), causal
    seq = 256 if smoke else 4096
    q = jnp.asarray(rng.standard_normal((1, 8, seq, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, seq, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, seq, 64)), jnp.bfloat16)
    att = jax.jit(lambda a_, b_, c_: kernels.attention(a_, b_, c_))
    us = _time(att, q, k, v, iters=3)
    fl = 4 * 8 * seq * seq * 64 / 2
    rows.append(f"kernel_attention{tag},{us:.1f},gflops_cpu={fl/us/1e3:.1f}")
    return rows
