"""Animated scene benchmark: per-frame fold cost is O(changed nodes).

``benchmarks/run.py --scene`` runs this module.  Three row groups over an
animated N-frame scene (a shared world -> camera prefix, B branches, L
leaves per branch, one branch re-posed per frame):

  * ``scene_anim`` -- the float32 lane on a DIAGONAL scene (the plan
    kind whose packed serving results are exactly equal to per-request
    ``apply``): every frame edits one branch (``set_local``), dirties
    exactly that subtree, and serves every leaf's points through
    ``GeometryServer.submit_scene``.  The gated counters are the
    tentpole claim: ``folds == dirtied`` (fold work per frame == changed
    nodes, NOT scene size), ``cse_hits`` (clean prefixes served from the
    shared ``FoldCache``), deterministic ``launches``, and ``equal`` --
    every scene-served result bitwise equal to the independent
    per-request ``TransformChain.apply`` oracle.
  * ``scene_anim_q8_7`` -- the same animation discipline on a 3D
    MATRIX-kind scene (camera rotation) through the int16 q8.7 lane,
    where packed-vs-apply equality is bitwise on every plan kind;
    additionally each leaf is submitted BOTH scene-cached and as its
    plain world chain in the same float32 flush and the two results
    compared bitwise (``scene_vs_chain_equal`` -- the cached fold is the
    same fold).
  * ``scene_fold_scratch`` -- the O(scene) baseline the scene graph
    replaces: folding every leaf's whole world chain from scratch each
    frame.  Its deterministic fold count is ``leaves`` per frame vs the
    scene's ``dirtied`` per frame; ``fold_ratio_vs_scene`` records the
    ratio (and each scratch fold walks the WHOLE path, so the real work
    ratio is larger still).

All counter fields are deterministic (fixed tree, fixed edit schedule,
frame-indexed float32 parameters), so ``tools/check_bench.py`` gates
them exactly in the scene-smoke CI lane.
"""
from __future__ import annotations

import time

import numpy as np

from repro import scene, serving
from repro.core import transform_chain as tc

SCENE_SEED = 3104


def _branch_pose(branch: int, frame: int) -> tc.TransformChain:
    """The animated branch-root local: frame-indexed float32 content so
    every edit is FRESH content (never a revert-to-cached hit) and every
    CI run folds bit-identical parameters."""
    return (tc.TransformChain.identity(2)
            .scale(np.float32(1.0 + 0.125 * branch))
            .translate(np.float32(0.25 * frame + branch),
                       np.float32(0.5 * branch)))


def _build_diag_scene(branches: int, leaves: int):
    """World -> camera -> B branch roots -> L leaves per branch, all
    translate/scale/affine locals (diagonal plans: the float32 packed
    lane is exactly equal to apply)."""
    g = scene.SceneGraph(2, cache=scene.FoldCache())
    g.add("world", tc.TransformChain.identity(2)
          .translate(np.float32(0.5), np.float32(-0.25)))
    g.add("camera", tc.TransformChain.identity(2)
          .affine((np.float32(0.5), np.float32(0.5)),
                  (np.float32(1.0), np.float32(2.0))), parent="world")
    names = []
    for b in range(branches):
        g.add(f"b{b}", _branch_pose(b, 0), parent="camera")
        for leaf in range(leaves):
            names.append(g.add(
                f"b{b}/l{leaf}",
                tc.TransformChain.identity(2)
                .affine(np.float32(1.0 + 0.0625 * leaf),
                        (np.float32(0.125 * leaf), np.float32(b))),
                parent=f"b{b}"))
    return g, names


def _pose3(branch: int, frame: int) -> tc.TransformChain:
    return (tc.TransformChain.identity(3)
            .scale(np.float32(1.0 + 0.0625 * branch))
            .translate(np.float32(0.0625 * frame),
                       np.float32(0.125 * branch), np.float32(0.0)))


def _build_matrix_scene(branches: int, leaves: int):
    """Same tree shape in 3D with a rotating camera: every leaf's world
    chain is matrix kind (the q8.7 lane is bitwise on it; the float lane
    carries the engine's documented last-ULP envelope)."""
    g = scene.SceneGraph(3, cache=scene.FoldCache())
    g.add("world", tc.TransformChain.identity(3)
          .translate(np.float32(0.25), np.float32(0.0), np.float32(0.5)))
    g.add("camera", tc.TransformChain.identity(3)
          .rotate(np.float32(0.4), axis=1)
          .translate(np.float32(0.0), np.float32(0.0), np.float32(-2.0)),
          parent="world")
    names = []
    for b in range(branches):
        g.add(f"b{b}", _pose3(b, 0), parent="camera")
        for leaf in range(leaves):
            names.append(g.add(
                f"b{b}/l{leaf}",
                tc.TransformChain.identity(3)
                .affine(np.float32(0.5),
                        (np.float32(0.125 * leaf), np.float32(0.0625 * b),
                         np.float32(0.0))),
                parent=f"b{b}"))
    return g, names


def _leaf_points(rng, n_leaves: int, n_points: int, dim: int):
    return [rng.uniform(-2, 2, (n_points, dim)).astype(np.float32)
            for _ in range(n_leaves)]


def _bytes_eq(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return a.dtype == b.dtype and a.shape == b.shape \
        and a.tobytes() == b.tobytes()


def _animate(g, leaf_names, pts, srv, frames, branches, pose,
             *, qformat=None, vs_chain=False):
    """Run the edit -> serve -> verify frame loop; returns the counter
    dict.  Serving wall-clock accumulates around submit+flush only (the
    oracle comparison is verification, not the thing being timed)."""
    serve_s = 0.0
    equal = True
    vs_equal = True
    folds = dirtied = 0
    for frame in range(1, frames + 1):
        edit = f"b{(frame - 1) % branches}"
        before = scene.stats["folds"]
        d = g.set_local(edit, pose((frame - 1) % branches, frame))
        t0 = time.perf_counter()
        tickets = [srv.submit_scene(g, n, p, qformat=qformat)
                   for n, p in zip(leaf_names, pts)]
        chain_tickets = [srv.submit(g.world_chain(n), p)
                         for n, p in zip(leaf_names, pts)] if vs_chain \
            else []
        scene_tickets = [srv.submit_scene(g, n, p)
                         for n, p in zip(leaf_names, pts)] if vs_chain \
            else []
        res = srv.flush()
        serve_s += time.perf_counter() - t0
        folds += scene.stats["folds"] - before
        dirtied += d
        base = tickets[0]       # flush() results are per-flush positional
        for n, p, t in zip(leaf_names, pts, tickets):
            oracle = g.world_chain(n).apply(p, backend=srv.backend,
                                            dtype=qformat)
            equal = equal and _bytes_eq(res[t - base], oracle)
        for tc_, ts_ in zip(chain_tickets, scene_tickets):
            vs_equal = vs_equal and _bytes_eq(res[tc_ - base],
                                              res[ts_ - base])
    return {"serve_us": serve_s * 1e6, "equal": equal,
            "vs_equal": vs_equal, "folds": folds, "dirtied": dirtied}


def _scene_rows(tag: str, frames: int, branches: int, leaves: int,
                n_points: int) -> list[str]:
    rng = np.random.default_rng(SCENE_SEED)

    # --- float32 lane, diagonal scene: bitwise equality gate ------------
    g, leaf_names = _build_diag_scene(branches, leaves)
    pts = _leaf_points(rng, len(leaf_names), n_points, 2)
    srv = serving.GeometryServer(backend="ref")
    for n, p in zip(leaf_names, pts):       # warm frame: plans + cold folds
        srv.submit_scene(g, n, p)
    srv.flush()
    scene.reset_stats()
    serving.reset_stats()
    r = _animate(g, leaf_names, pts, srv, frames, branches, _branch_pose)
    launches = serving.stats["launches"]
    n_nodes, n_leaves = len(g), len(leaf_names)
    assert r["folds"] == r["dirtied"], (r["folds"], r["dirtied"])
    print(f"[scene] {frames}-frame diag scene ({n_nodes} nodes, "
          f"{n_leaves} leaves): {r['folds']} folds for {r['dirtied']} "
          f"dirtied nodes ({r['folds'] // frames}/frame vs {n_nodes} "
          f"nodes), {launches} launches, equal={r['equal']}")
    rows = [
        f"scene_anim{tag},{r['serve_us'] / frames:.1f},"
        f"frames={frames};nodes={n_nodes};leaves={n_leaves};"
        f"requests={n_leaves * frames};dirtied={r['dirtied']};"
        f"folds={r['folds']};folds_per_frame={r['folds'] // frames};"
        f"cse_hits={scene.stats['cse_hits']};"
        f"refolds={scene.stats['refolds']};launches={launches};"
        f"equal={r['equal']}",
    ]

    # --- q8.7 lane, matrix scene (+ scene-vs-chain float check) ---------
    g3, leaf3 = _build_matrix_scene(branches, leaves)
    pts3 = _leaf_points(rng, len(leaf3), n_points, 3)
    srv3 = serving.GeometryServer(backend="ref")
    for n, p in zip(leaf3, pts3):
        srv3.submit_scene(g3, n, p, qformat="q8.7")
        srv3.submit_scene(g3, n, p)
        srv3.submit(g3.world_chain(n), p)
    srv3.flush()
    scene.reset_stats()
    serving.reset_stats()
    r3 = _animate(g3, leaf3, pts3, srv3, frames, branches, _pose3,
                  qformat="q8.7", vs_chain=True)
    launches3 = serving.stats["launches"]
    assert r3["folds"] == r3["dirtied"], (r3["folds"], r3["dirtied"])
    print(f"[scene] {frames}-frame matrix scene, q8.7 lane: "
          f"{r3['folds']} folds for {r3['dirtied']} dirtied nodes, "
          f"{launches3} launches, q_equal={r3['equal']}, "
          f"scene_vs_chain_equal={r3['vs_equal']}")
    rows.append(
        f"scene_anim_q8_7{tag},{r3['serve_us'] / frames:.1f},"
        f"frames={frames};nodes={len(g3)};leaves={len(leaf3)};"
        f"requests={len(leaf3) * frames * 3};dirtied={r3['dirtied']};"
        f"folds={r3['folds']};folds_per_frame={r3['folds'] // frames};"
        f"cse_hits={scene.stats['cse_hits']};launches={launches3};"
        f"equal={r3['equal']};scene_vs_chain_equal={r3['vs_equal']}")

    # --- the O(scene) baseline: every leaf refolds from scratch ---------
    t0 = time.perf_counter()
    scratch_folds = 0
    for frame in range(1, frames + 1):
        g.set_local(f"b{(frame - 1) % branches}",
                    _branch_pose((frame - 1) % branches, frames + frame))
        for n in leaf_names:
            c = g.world_chain(n)
            tc.fold_structure(c.structure, c.params)
            scratch_folds += 1
    scratch_us = (time.perf_counter() - t0) * 1e6
    per_frame_scene = r["folds"] // frames
    ratio = scratch_folds / max(1, r["folds"])
    print(f"[scene] scratch baseline: {scratch_folds} whole-path folds "
          f"vs {r['folds']} incremental ({ratio:.2f}x fold count; each "
          f"scratch fold also walks the full path)")
    rows.append(
        f"scene_fold_scratch{tag},{scratch_us / frames:.1f},"
        f"frames={frames};leaves={n_leaves};folds={scratch_folds};"
        f"folds_per_frame={scratch_folds // frames};"
        f"fold_ratio_vs_scene={ratio:.2f}x;"
        f"scene_folds_per_frame={per_frame_scene}")
    return rows


def run(smoke: bool = False) -> list[str]:
    """Entry point for ``benchmarks/run.py --scene``."""
    tag = "_smoke" if smoke else ""
    if smoke:
        return _scene_rows(tag, frames=6, branches=4, leaves=4,
                           n_points=64)
    return _scene_rows(tag, frames=30, branches=8, leaves=8,
                       n_points=512)
