"""Soak benchmark: the continuous-batching front-end under Poisson traffic.

A batched serving engine's benchmarks so far answered "how fast is one
flush"; the soak answers the question production actually asks: given
requests ARRIVING on a timeline at a sustained rate, does the flush
policy hold its batching economy, does admission control shed the right
load, and does the zero-lost-requests invariant survive hours of traffic
-- compressed into seconds by running the timeline on a ``VirtualClock``.

Two row families (see benchmarks/PERF.md):

  * ``soak_poisson{_smoke}`` -- a seeded Poisson arrival process (10^5
    requests smoke, 10^6 full) replaying the mixed affine + projective +
    fixed-point workload pool through ``AsyncGeometryServer`` with four
    tenants, per-tenant token buckets tuned so rate limiting MUST fire,
    and the deadline-times-fill flush policy deciding every launch.  The
    wall-clock column is the host cost of driving the whole soak; the
    derived fields are deterministic -- arrivals, tenants, admission
    decisions, bucket compositions, launch counts, and the VIRTUAL-time
    p50/p99 latency and sustained req/s are all pure functions of the
    seed, so the CI gate (tools/check_bench.py) compares them EXACTLY.
    ``lost=0`` (every admitted request resolved) is the headline.
  * ``soak_chaos{_smoke}`` -- the same driver with the PR 6
    ``FaultInjector`` wired into the inner engine: launches fail, degrade
    across backends, and bisect UNDER the async path, and the gate pins
    ``lost=0`` plus the exact recovery counters -- the proof that the
    recovery ladder composes with continuous batching.
  * ``soak_trace{_smoke}`` -- a small soak served under a ``repro.obs``
    tracer sharing the soak's ``VirtualClock``: every span timestamp is
    a pure function of the seed, so the exported Chrome-trace JSON is
    BYTE-identical across runs (the obs-smoke CI lane diffs two
    independent runs and the committed ``benchmarks/traces/`` snapshot)
    and the span/event counts sit in the exact-match gate.
  * ``soak_trace_overhead{_smoke}`` -- the same small soak twice, traced
    and untraced, gating ``counters_identical=1``: instrumentation
    observes the serving stack, it never steers it.
"""
from __future__ import annotations

import time

import numpy as np

from repro import obs
from repro.serving import admission as adm
from repro.serving import engine, faults, workload
from repro.serving.async_engine import AsyncGeometryServer, SLOConfig
from repro.serving.clock import VirtualClock

SEED = 17
SMOKE_REQUESTS = 100_000
FULL_REQUESTS = 1_000_000
#: arrivals in the traced soak (both lanes: the committed trace must
#: stay small enough to live in the repo)
TRACE_REQUESTS = 250
#: distinct requests in the replayed pool (cycled; pool generation is
#: seeded so the request mix is identical across runs and machines)
POOL = 384


def drive_soak(n_requests: int, *, backend: str = "ref",
               rate_rps: float = 150_000.0, n_tenants: int = 4,
               tenant_rate: float | None = 30_000.0,
               tenant_burst: float = 64.0,
               max_queue_depth: int = 1024,
               slo: SLOConfig | None = None,
               max_points: int = 48,
               injector: faults.FaultInjector | None = None,
               traced: bool = False,
               trace_path: str | None = None,
               prom_path: str | None = None) -> dict:
    """Drive one seeded Poisson soak; returns the deterministic counters.

    The timeline is virtual: the driver alternates between the next
    arrival and the engine's ``next_due_in`` deadline, advancing the
    clock to whichever comes first -- exactly the event loop a real
    deployment runs, minus the waiting.  Every random draw (arrival
    gaps, tenant assignment, workload pool) comes from seeded
    generators, so the returned counters are bit-stable.

    ``traced`` serves the soak under a tracer on the soak's OWN virtual
    clock (the counters gain exact-gateable ``trace_spans`` /
    ``trace_events``); ``trace_path`` additionally writes the stream as
    deterministic Chrome-trace JSON, and ``prom_path`` writes the
    engines' registries as Prometheus text.
    """
    pool = workload.mixed_lane_workload(SEED, POOL, max_points=max_points)
    # defaults tuned so BOTH flush triggers fire (most buckets fill to
    # target_rows inside the window; stragglers go out on the deadline)
    # and both admission gates reject a deterministic nonzero slice:
    # offered 150k req/s vs 4 x 30k token buckets -> rate limiting, and
    # ~admitted_rate * mean_wait queued rows vs depth 1024 -> queue-full
    slo = slo or SLOConfig(max_wait_s=0.02, target_rows=32)
    server_kw: dict = {}
    if injector is not None:
        server_kw.update(injector=injector,
                         fault_config=engine.FaultConfig(backoff_base_s=0.0))
    clock = VirtualClock()
    eng = AsyncGeometryServer(
        backend=backend, clock=clock, slo=slo,
        admission=adm.AdmissionConfig(max_queue_depth=max_queue_depth,
                                      tenant_share=0.5,
                                      tenant_rate=tenant_rate,
                                      tenant_burst=tenant_burst),
        **server_kw)
    rng = np.random.default_rng([0x50AF, SEED])
    base = {k: engine.stats[k] for k in engine.stats}
    tracer = obs.Tracer(clock=clock) if traced or trace_path is not None \
        else obs.NullTracer()

    next_arrival = 0.0
    polls = 0
    i = 0
    wall0 = time.perf_counter()
    with obs.installed(tracer):
        while i < n_requests:
            nd = eng.next_due_in()
            if nd is not None and clock.now() + nd < next_arrival:
                clock.advance(nd)
                eng.poll()
                polls += 1
                continue
            clock.advance_to(next_arrival)
            tenant = f"t{int(rng.integers(n_tenants))}"
            chain, pts, qname = pool[i % POOL]
            try:
                # tickets are deliberately dropped: resolution is counted
                # in the engine telemetry, and lost-request accounting
                # below is what proves none fell through
                eng.submit_async(chain, pts, tenant=tenant, qformat=qname)
            except (adm.QueueFullError, adm.RateLimitError):
                pass                  # counted by the admission controller
            i += 1
            next_arrival += float(rng.exponential(1.0 / rate_rps))
        # let the flush policy retire the tail on its own deadlines (a
        # drain would skew the latency telemetry)
        while True:
            nd = eng.next_due_in()
            if nd is None:
                break
            clock.advance(nd)
            eng.poll()
            polls += 1
    wall_s = time.perf_counter() - wall0

    st = eng.stats
    delta = {k: engine.stats[k] - base[k] for k in base}
    assert st["queue_depth"] == 0, "soak ended with requests still queued"
    trace_fields = {}
    if tracer.enabled:
        trace_fields = {"trace_spans": tracer.n_spans,
                        "trace_events": tracer.n_events}
    if trace_path is not None:
        obs.dump_chrome_trace(tracer, trace_path)
    if prom_path is not None:
        with open(prom_path, "w") as f:
            f.write(obs.prometheus_text(eng.metrics, eng.server.metrics,
                                        eng._admission.metrics))
    return {
        **trace_fields,
        "requests": n_requests,
        "admitted": st["admitted"],
        "rate_limited": st["rate_limit_rejections"],
        "queue_full": st["queue_full_rejections"],
        "resolved": st["resolved"],
        "failed": st["failed"],
        "lost": st["admitted"] - st["resolved"] - st["failed"],
        "launches": delta["launches"],
        "buckets": delta["buckets"],
        "payload_points": delta["payload_points"],
        "padded_points": delta["padded_points"],
        "retries": delta["retries"],
        "backend_fallbacks": delta["backend_fallbacks"],
        "bisections": delta["bisections"],
        "polls": polls,
        "p50_virtual_us": round(st["p50_latency_s"] * 1e6, 2),
        "p99_virtual_us": round(st["p99_latency_s"] * 1e6, 2),
        "virtual_rps": round(st["sustained_rps"], 1),
        "virtual_span_s": round(clock.now(), 6),
        "wall_s": wall_s,
    }


_GATED = ("requests", "admitted", "rate_limited", "queue_full", "resolved",
          "failed", "lost", "launches", "buckets", "payload_points",
          "padded_points", "retries", "backend_fallbacks", "bisections",
          "polls", "p50_virtual_us", "p99_virtual_us", "virtual_rps")

#: the traced row additionally pins the span stream's exact size
_GATED_TRACE = _GATED + ("trace_spans", "trace_events")


def _row(name: str, counters: dict, gated: tuple = _GATED) -> str:
    derived = ";".join(f"{k}={counters[k]}" for k in gated)
    return f"{name},{counters['wall_s'] * 1e6:.1f},{derived}"


def _cold_caches() -> None:
    """Drop both plan caches.  Plan compiles/hits and jit re-traces are
    TRACED events, so the traced soak is only byte-reproducible if it
    always starts cold -- independent of whatever ran earlier in the
    process."""
    from repro.core import transform_chain as tc
    engine.clear_plan_cache()
    tc.clear_plan_cache()


def run_traced(trace_path: str | None, prom_path: str | None) -> list[dict]:
    """The traced-soak pair (untraced, traced), both from cold caches;
    writes the Chrome/Prometheus artifacts when paths are given."""
    _cold_caches()
    cu = drive_soak(TRACE_REQUESTS)
    _cold_caches()
    ct = drive_soak(TRACE_REQUESTS, traced=True, trace_path=trace_path,
                    prom_path=prom_path)
    return [cu, ct]


def run(smoke: bool = False, trace_path: str | None = None,
        prom_path: str | None = None) -> list[str]:
    tag = "_smoke" if smoke else ""
    n = SMOKE_REQUESTS if smoke else FULL_REQUESTS

    c = drive_soak(n)
    rows = [_row(f"soak_poisson{tag}", c)]
    print(f"[soak] poisson: {c['requests']} arrivals -> {c['admitted']} "
          f"admitted ({c['rate_limited']} rate-limited, {c['queue_full']} "
          f"queue-full), {c['resolved']} resolved + {c['failed']} failed, "
          f"lost={c['lost']}; {c['launches']} launches over "
          f"{c['virtual_span_s']:.2f} virtual s "
          f"({c['virtual_rps']:.0f} req/s, p50 {c['p50_virtual_us']:.0f} us "
          f"/ p99 {c['p99_virtual_us']:.0f} us) in {c['wall_s']:.1f} wall s")

    # chaos variant: the PR 6 injector under the async path, smaller n
    # (the interpret-lane recovery ladder is the expensive part)
    n_chaos = 1_500 if smoke else 12_000
    inj = faults.FaultInjector(seed=SEED, flaky_rate=0.06, backend_rate=0.05,
                               corrupt_rate=0.05, poison_rate=0.03)
    cc = drive_soak(n_chaos, backend="interpret", injector=inj)
    rows.append(_row(f"soak_chaos{tag}", cc))
    print(f"[soak] chaos: {cc['requests']} arrivals under injection -> "
          f"{cc['resolved']} resolved + {cc['failed']} typed failures, "
          f"lost={cc['lost']} ({cc['retries']} retries, "
          f"{cc['backend_fallbacks']} fallbacks, {cc['bisections']} "
          f"bisections) in {cc['wall_s']:.1f} wall s")

    # traced + overhead rows: one small soak untraced, the SAME soak
    # traced (and exported), gating that the counters cannot tell the
    # difference -- instrumentation observes, it never steers
    cu, ct = run_traced(trace_path, prom_path)
    rows.append(_row(f"soak_trace{tag}", ct, _GATED_TRACE))
    identical = all(cu[k] == ct[k] for k in _GATED)
    overhead = (ct["wall_s"] - cu["wall_s"]) / cu["wall_s"] * 100.0
    rows.append(_row(f"soak_trace_overhead{tag}",
                     {**ct, "counters_identical": int(identical),
                      "overhead_pct": round(overhead, 1)},
                     _GATED + ("counters_identical", "overhead_pct")))
    print(f"[soak] trace: {ct['requests']} arrivals traced -> "
          f"{ct['trace_spans']} spans / {ct['trace_events']} events "
          f"(untraced {cu['wall_s'] * 1e3:.0f} ms vs traced "
          f"{ct['wall_s'] * 1e3:.0f} ms; counters identical: {identical})")
    return rows


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(
        description="seeded soak benchmark (see module docstring)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="where the traced soak writes its Chrome-trace "
                         "JSON (byte-identical across runs)")
    ap.add_argument("--prom", default=None, metavar="OUT.prom",
                    help="where the traced soak writes its Prometheus "
                         "text snapshot")
    ap.add_argument("--trace-only", action="store_true",
                    help="run just the traced soak pair (the obs-smoke "
                         "CI lane byte-diffs two runs of this)")
    ap.add_argument("--out", default=None,
                    help="append benchmark rows to this CSV")
    args = ap.parse_args(argv)
    if args.trace_only:
        cu, ct = run_traced(args.trace, args.prom)
        identical = all(cu[k] == ct[k] for k in _GATED)
        print(f"[soak] trace-only: {ct['trace_spans']} spans / "
              f"{ct['trace_events']} events; counters identical: "
              f"{identical}")
        if not identical:
            raise SystemExit("traced counters diverged from untraced")
        return
    rows = run(smoke=args.smoke, trace_path=args.trace, prom_path=args.prom)
    if args.out:
        with open(args.out, "a") as f:
            f.writelines(r + "\n" for r in rows)


if __name__ == "__main__":
    main()
