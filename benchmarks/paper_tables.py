"""Reproduction of the paper's Tables 3, 4 and 5.

For every (algorithm, system, n) cell we print three sources side by side:
  paper    -- the published number (intel.PAPER_TABLE5),
  model    -- our instruction-level Intel cycle model (Tables 3-4),
  emulator -- the M1 emulator executing the reconstructed TinyRISC program
              (functionally validated against int16 oracles).

Known deltas (analysed in EXPERIMENTS.md section Faithful):
  * Table 3's 64-element totals are arithmetic slips in the paper (769/1723
    published vs 706/1732 from its own per-instruction clocks);
  * the matrix routines (rotation 256c, composite II 70c) have no published
    listing; our reconstruction is faster (90c / 25c) because it overlaps
    context loads -- both numbers are reported.
"""
from __future__ import annotations

import numpy as np

from repro.core import analysis
from repro.core.morphosys import intel, programs


def _emulator_cycles() -> dict:
    rng = np.random.default_rng(0)
    out = {}
    for n in (8, 64):
        u = rng.integers(-1000, 1000, n)
        v = rng.integers(-1000, 1000, n)
        rt = programs.run_translation(u, v)
        assert np.array_equal(rt.values, programs.oracle_translation(u, v))
        out[("translation", n)] = rt.cycles
        rs = programs.run_scaling(u, 5)
        assert np.array_equal(rs.values, programs.oracle_scaling(u, 5))
        out[("scaling", n)] = rs.cycles
    a = rng.integers(-100, 100, (8, 8))
    b = rng.integers(-1000, 1000, (8, 8))
    rm = programs.run_matmul(a, b)
    assert np.array_equal(rm.values, programs.oracle_matmul(a, b))
    out[("rotation_matmul", 64)] = rm.cycles
    pts = rng.integers(-100, 100, (2, 8))
    rr = programs.run_rotation_points((3, 4), pts)
    out[("composite_ii", 16)] = rr.cycles
    return out


def table3() -> list[str]:
    """Vector-vector translation: Intel cycle models vs paper."""
    rows = []
    for n in (8, 64):
        for cpu in ("80486", "80386"):
            model = intel.translation_cycles(cpu, n)
            paper = intel.paper_row("translation", cpu, n).cycles
            rows.append(f"table3_translation_{cpu}_n{n},"
                        f"{intel.time_us(cpu, model):.3f},"
                        f"model={model};paper={paper};match={model == paper}")
    return rows


def table4() -> list[str]:
    """Vector-scalar scaling: Intel cycle models vs paper."""
    rows = []
    for n in (8, 64):
        for cpu in ("80486", "80386"):
            model = intel.scaling_cycles(cpu, n)
            paper = intel.paper_row("scaling", cpu, n).cycles
            rows.append(f"table4_scaling_{cpu}_n{n},"
                        f"{intel.time_us(cpu, model):.3f},"
                        f"model={model};paper={paper};match={model == paper}")
    return rows


def table5() -> list[str]:
    """Full comparison incl. speedups; emulator validates the M1 rows."""
    emu = _emulator_cycles()
    rows = []
    perf_rows = []
    for row in intel.PAPER_TABLE5:
        if row.system == "m1":
            got = emu.get((row.algorithm, row.n_elements))
            perf_rows.append(analysis.derive(row.algorithm, "m1",
                                             row.n_elements, got,
                                             source="emulator"))
            rows.append(f"table5_{row.algorithm}_m1_n{row.n_elements},"
                        f"{got / intel.CLOCK_MHZ['m1']:.3f},"
                        f"emulator={got};paper={row.cycles};"
                        f"match={got == row.cycles}")
        else:
            m1_paper = intel.paper_row(row.algorithm, "m1",
                                       row.n_elements).cycles
            speedup = row.cycles / m1_paper
            perf_rows.append(analysis.derive(row.algorithm, row.system,
                                             row.n_elements, row.cycles,
                                             ref_cycles=m1_paper,
                                             source="paper"))
            rows.append(f"table5_{row.algorithm}_{row.system}_n{row.n_elements},"
                        f"{intel.time_us(row.system, row.cycles):.3f},"
                        f"speedup_vs_m1={speedup:.2f};paper_speedup={row.speedup}")
    print(analysis.format_table(perf_rows))
    return rows


def run() -> list[str]:
    out = []
    for fn in (table3, table4, table5):
        out.extend(fn())
    return out
