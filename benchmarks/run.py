"""Benchmark harness: one module per paper table + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV (plus human-readable tables on the
way).  Invoke:  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys


def main() -> None:
    # keep repo-root execution working (src layout)
    sys.path.insert(0, "src")
    from benchmarks import kernel_bench, paper_tables, roofline_bench

    rows: list[str] = []
    print("== paper tables (3/4/5): M1 emulator + Intel cycle models ==")
    rows += paper_tables.run()
    print("\n== kernel microbenchmarks (paper primitives on the TPU mapping) ==")
    rows += kernel_bench.run()
    print("\n== roofline (from multi-pod dry-run) ==")
    rows += roofline_bench.run()

    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
