"""Benchmark harness: one module per paper table + kernels + serving + roofline.

Prints ``name,us_per_call,derived`` CSV (plus human-readable tables on the
way) and records the same rows to ``benchmarks/BENCH_<timestamp>.json`` so
the perf trajectory across PRs is preserved, not just printed.  Invoke:

    PYTHONPATH=src python -m benchmarks.run

``--smoke`` runs a seconds-long liveness subset (paper tables + tiny-shape
kernel + serving rows, roofline skipped) -- the CI pass; see
benchmarks/PERF.md.  ``--autotune`` additionally records tuned-vs-default
rows (``autotune_serving_*``: same seeded workload served under the
default size grid and under the tuning-cache winner, with launch counts
and speedup as derived fields).  ``--graphics`` records the projective
viewing-pipeline rows (``graphics_*``: fused vs staged dispatch, and the
mixed affine+projective 64-request serving economy).  ``--fixedpoint``
records the int16 Qm.n lane rows (``fixedpoint_*``: fused-q vs fused-f32
bytes and launches -- half the HBM traffic at the 64-request serving
workload -- plus the M1 emulator-cycle parity flags).  ``--chaos``
records the fault-tolerance rows (``chaos_*``: a seeded fault-injection
soak whose recovery counters are exact-gated by the chaos CI lane, plus
the recovery machinery's wall-clock overhead under faults).  ``--soak``
records the continuous-batching rows (``soak_*``: a seeded Poisson
arrival stream driven through the async front-end on a virtual clock --
admission, launch, and latency counters are all deterministic and
exact-gated by the soak CI lane).  ``--profile`` records the analysis
layer's rows (``profile_attrib``: span-stream attribution counters with
the ``attribution_exact``/``byte_ratio_exact`` flags; ``slo_burn``:
pinned virtual-clock alert instants), gated by the profile-smoke CI
lane.  ``--scene`` records the animated scene-graph rows (``scene_*``:
an N-frame edit/serve loop through the fold CSE cache whose fold counts
equal the dirtied-subtree sizes, with bitwise scene-vs-apply equality
flags on the float32 diagonal lane and the q8.7 lane, plus the
fold-everything-from-scratch baseline), gated by the scene-smoke CI
lane.  ``--out``
overrides the JSON path (``--out ''`` disables the record; CI instead
writes to a scratch path, gates on it with ``tools/check_bench.py``, and
uploads it as a workflow artifact); the default path is collision-proof
-- two runs in the same second get distinct files, never a silent
overwrite.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _coerce(v: str):
    """Derived-field values as real JSON types so BENCH records compare
    without re-parsing: ints, floats, bools, '3.97x'-style ratios."""
    if v in ("True", "False"):
        return v == "True"
    for conv in (int, float):
        try:
            return conv(v)
        except ValueError:
            pass
    if v.endswith("x"):
        try:
            return float(v[:-1])
        except ValueError:
            pass
    return v


def _parse_rows(rows: list[str]) -> list[dict]:
    out = []
    for r in rows:
        name, us, derived = r.split(",", 2)
        entry: dict = {"name": name, "us_per_call": float(us)}
        for field in derived.split(";"):
            if "=" in field:
                k, v = field.split("=", 1)
                entry[k] = _coerce(v)
            elif field:
                entry["note"] = field
        out.append(entry)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few iters; CI liveness check")
    ap.add_argument("--autotune", action="store_true",
                    help="record tuned-vs-default serving rows "
                         "(tuning-cache winners vs the deterministic "
                         "default grid, same seeded workload)")
    ap.add_argument("--graphics", action="store_true",
                    help="record projective viewing-pipeline rows (fused "
                         "vs staged dispatch + mixed affine+projective "
                         "serving)")
    ap.add_argument("--fixedpoint", action="store_true",
                    help="record fixed-point lane rows (fused-q vs "
                         "fused-f32 bytes/launches at the 64-request "
                         "serving workload + M1 emulator-cycle parity)")
    ap.add_argument("--chaos", action="store_true",
                    help="record fault-tolerance rows (seeded chaos soak "
                         "with exact recovery counters + the recovery "
                         "machinery's wall-clock overhead under faults)")
    ap.add_argument("--soak", action="store_true",
                    help="record continuous-batching soak rows (seeded "
                         "Poisson arrivals through the async front-end "
                         "on a virtual clock; deterministic admission/"
                         "latency counters, exact-gated)")
    ap.add_argument("--profile", action="store_true",
                    help="record profiler + SLO rows (span-stream "
                         "attribution counters with exactness flags, and "
                         "pinned virtual-clock alert instants)")
    ap.add_argument("--scene", action="store_true",
                    help="record animated scene-graph rows (incremental "
                         "refold counters == dirtied-subtree sizes, "
                         "bitwise equality flags, scratch-fold baseline)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="with --soak: write the traced soak's span "
                         "stream as byte-deterministic Chrome-trace JSON")
    ap.add_argument("--prom", default=None, metavar="OUT.prom",
                    help="with --soak: write the traced soak's registry "
                         "state as Prometheus text")
    ap.add_argument("--out", default=None,
                    help="JSON record path (default benchmarks/"
                         "BENCH_<timestamp>.json; '' disables)")
    args = ap.parse_args(argv)

    # keep both `python -m benchmarks.run` and `python benchmarks/run.py`
    # working from the repo root (src layout)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(root, "src"))
    sys.path.insert(0, root)
    from benchmarks import (autotune_bench, chaos_bench, fixedpoint_bench,
                            graphics_bench, kernel_bench, paper_tables,
                            profile_bench, roofline_bench, scene_bench,
                            serving_bench, soak_bench)

    rows: list[str] = []
    print("== paper tables (3/4/5): M1 emulator + Intel cycle models ==")
    rows += paper_tables.run()
    print("\n== kernel microbenchmarks (paper primitives on the TPU mapping) ==")
    rows += kernel_bench.run(smoke=args.smoke)
    print("\n== transform serving (batched buckets vs per-request dispatch) ==")
    rows += serving_bench.run(smoke=args.smoke)
    if args.autotune:
        print("\n== autotune (tuned vs default launch parameters) ==")
        rows += autotune_bench.run(smoke=args.smoke)
    if args.graphics:
        print("\n== graphics (projective viewing chains, fused + served) ==")
        rows += graphics_bench.run(smoke=args.smoke)
    if args.fixedpoint:
        print("\n== fixed point (int16 Qm.n lane vs float32) ==")
        rows += fixedpoint_bench.run(smoke=args.smoke)
    if args.chaos:
        print("\n== chaos (seeded fault injection: recovery + overhead) ==")
        rows += chaos_bench.run(smoke=args.smoke)
    if args.soak:
        print("\n== soak (Poisson arrivals through the async front-end) ==")
        rows += soak_bench.run(smoke=args.smoke, trace_path=args.trace,
                               prom_path=args.prom)
    if args.profile:
        print("\n== profile (span-stream attribution + SLO burn rate) ==")
        rows += profile_bench.run(smoke=args.smoke)
    if args.scene:
        print("\n== scene (animated scene graph: fold CSE + incremental "
              "refold) ==")
        rows += scene_bench.run(smoke=args.smoke)
    if not args.smoke:
        print("\n== roofline (from multi-pod dry-run) ==")
        rows += roofline_bench.run()

    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)

    stamp = time.strftime("%Y%m%d_%H%M%S")
    out = args.out
    if out is None:
        # collision-proof default path: second-granularity timestamps let
        # two same-second runs silently overwrite each other, so suffix
        # until the name is fresh
        base = os.path.join(root, "benchmarks", f"BENCH_{stamp}")
        out, k = f"{base}.json", 1
        while os.path.exists(out):
            out = f"{base}_{k}.json"
            k += 1
    if out:
        # CI points --out into a not-yet-existing scratch dir (ci-bench/);
        # the record must not crash after minutes of benchmark work
        parent = os.path.dirname(os.path.abspath(out))
        os.makedirs(parent, exist_ok=True)
        with open(out, "w") as f:
            json.dump({"timestamp": stamp, "smoke": args.smoke,
                       "rows": _parse_rows(rows)}, f, indent=1)
        print(f"\nrecorded {len(rows)} rows -> {out}")


if __name__ == "__main__":
    main()
