"""Benchmark harness: one module per paper table + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV (plus human-readable tables on the
way).  Invoke:  PYTHONPATH=src python -m benchmarks.run

``--smoke`` runs a seconds-long liveness subset (paper tables + tiny-shape
kernel rows, roofline skipped) -- the CI pass; see benchmarks/PERF.md.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few iters; CI liveness check")
    args = ap.parse_args(argv)

    # keep both `python -m benchmarks.run` and `python benchmarks/run.py`
    # working from the repo root (src layout)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(root, "src"))
    sys.path.insert(0, root)
    from benchmarks import kernel_bench, paper_tables, roofline_bench

    rows: list[str] = []
    print("== paper tables (3/4/5): M1 emulator + Intel cycle models ==")
    rows += paper_tables.run()
    print("\n== kernel microbenchmarks (paper primitives on the TPU mapping) ==")
    rows += kernel_bench.run(smoke=args.smoke)
    if not args.smoke:
        print("\n== roofline (from multi-pod dry-run) ==")
        rows += roofline_bench.run()

    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
