"""Fixed-point lane benchmark: fused-q vs fused-f32 bytes and launches,
plus M1-emulator parity rows.

``benchmarks/run.py --fixedpoint`` runs this module.  Three row groups:

  * ``fixedpoint_fused_*`` -- ONE fused composite chain (the paper's
    translate/scale/rotate pipeline) applied to the same point set on the
    float32 lane and the int16 q8.7 lane; the byte fields come from
    ``kernels.opcount`` (the accounting the tests pin), so the 0.5x HBM
    ratio is recorded as data, not arithmetic in prose.
  * ``fixedpoint_serving_*`` -- the 64-request affine serving workload
    (the scale the acceptance gate names) served through the
    GeometryServer twice: float32 buckets vs q8.7 buckets.  Same
    structures, same size grid -> identical launch schedules; the q
    lane's packed batches move 2-byte words, so its HBM total is half.
    ``byte_ratio_vs_f32`` is the committed proof of the <= 0.55x claim.
  * ``fixedpoint_emulator_*`` -- the Composite I/II parity rows: cycle
    counts from the M1 emulator programs next to ``parity`` flags
    recomputed HERE (the lane's output equals the emulator's, exactly --
    Q15.0 bit-for-bit, q8.7 through the shift identity), so the BENCH
    record carries the paper-fidelity check, not just the test suite.

All counter fields are deterministic (seeded workload, analytic bytes,
emulator cycles), which is what lets ``tools/check_bench.py`` gate CI on
them exactly.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro import serving
from repro.core import transform_chain as tc
from repro.core.morphosys import programs
from repro.kernels import opcount
from repro.serving import workload
from repro.serving.workload import timed as _timed

#: the 64-request serving scale the acceptance criterion names; seeded so
#: the f32 and q sides (and every CI re-run) serve a bit-identical mix
FP_SEED = 2203
FP_REQUESTS = 64
FP_MAX_POINTS = 1024


def _fp_workload():
    return workload.random_workload(seed=FP_SEED, n_requests=FP_REQUESTS,
                                    max_points=FP_MAX_POINTS,
                                    templates=workload.AFFINE_TEMPLATES)


def _fused_rows(tag: str, iters: int, n_points: int) -> list[str]:
    chain = (tc.TransformChain.identity(2)
             .translate(1.0, -2.0).scale(1.5, 0.5).rotate(0.3)
             .translate(-0.5, 0.25))
    from repro.quantize import Q8_7
    rng = np.random.default_rng(0)
    pts = rng.uniform(-3, 3, (n_points, 2)).astype(np.float32)
    pts_j = jnp.asarray(pts)
    words_j = jnp.asarray(Q8_7.quantize(pts))
    chain.apply(words_j, backend="ref", dtype="q8.7")      # warm q plan
    chain.apply(pts_j, backend="ref")                      # warm f32 plan
    with opcount.counting() as rec_f:
        chain.apply(pts_j, backend="ref")
    with opcount.counting() as rec_q:
        chain.apply(words_j, backend="ref", dtype="q8.7")
    bytes_f = opcount.total_bytes(rec_f)
    bytes_q = opcount.total_bytes(rec_q)

    us_f = min(_timed(lambda: chain.apply(pts_j, backend="ref"))
               for _ in range(iters)) * 1e6
    us_q = min(_timed(lambda: chain.apply(words_j, backend="ref",
                                          dtype="q8.7"))
               for _ in range(iters)) * 1e6
    print(f"[fixedpoint] fused len-4 chain over {n_points} pts: "
          f"f32 {bytes_f} B vs q8.7 {bytes_q} B "
          f"({bytes_q / bytes_f:.3f}x), {us_f:.0f} us vs {us_q:.0f} us")
    return [
        f"fixedpoint_fused_f32{tag},{us_f:.1f},"
        f"points={n_points};launches=1;hbm_bytes={bytes_f}",
        f"fixedpoint_fused_q8_7{tag},{us_q:.1f},"
        f"points={n_points};launches=1;hbm_bytes={bytes_q};"
        f"byte_ratio_vs_f32={bytes_q / bytes_f:.4f}",
    ]


def _serving_rows(tag: str, iters: int) -> list[str]:
    reqs = _fp_workload()

    def measure(qformat):
        srv = serving.GeometryServer(backend="ref")
        srv.serve(reqs, qformat=qformat)       # warm plans + jit shapes
        serving.reset_stats()
        with opcount.counting() as rec:
            best = min(_timed(lambda: srv.serve(reqs, qformat=qformat))
                       for _ in range(iters))
        launches = serving.stats["launches"] // iters
        nbytes = opcount.total_bytes(
            [r for r in rec if r[0].startswith("serve_bucket")]) // iters
        return best * 1e6, launches, nbytes

    us_f, launches_f, bytes_f = measure(None)
    us_q, launches_q, bytes_q = measure("q8.7")
    ratio = bytes_q / bytes_f
    print(f"[fixedpoint] {FP_REQUESTS}-request serving: f32 {launches_f} "
          f"launches / {bytes_f} B vs q8.7 {launches_q} launches / "
          f"{bytes_q} B -> {ratio:.3f}x bytes, "
          f"{us_f / us_q:.2f}x wall-clock")
    return [
        f"fixedpoint_serving_f32{tag},{us_f:.1f},"
        f"requests={FP_REQUESTS};launches={launches_f};"
        f"hbm_bytes={bytes_f}",
        f"fixedpoint_serving_q8_7{tag},{us_q:.1f},"
        f"requests={FP_REQUESTS};launches={launches_q};"
        f"hbm_bytes={bytes_q};byte_ratio_vs_f32={ratio:.4f};"
        f"speedup_vs_f32={us_f / us_q:.2f}x",
    ]


def _emulator_rows(tag: str) -> list[str]:
    # Composite I: scaling then translation on one 64-vector, Q15.0
    rng = np.random.default_rng(41)
    u = rng.integers(-30000, 30000, 64).astype(np.int16)
    v2 = rng.integers(-30000, 30000, 2).astype(np.int16)
    scaled = programs.run_scaling(u, 5)
    translated = programs.run_translation(scaled.values, np.tile(v2, 32))
    chain1 = (tc.TransformChain.identity(2)
              .scale(5.0).translate(float(v2[0]), float(v2[1])))
    ours1 = np.asarray(chain1.apply(
        jnp.asarray(u.reshape(32, 2).astype(np.float32)),
        backend="ref", dtype="q15.0")).reshape(-1)
    parity1 = bool((ours1 == translated.values).all())
    cycles1 = scaled.cycles + translated.cycles

    # Composite II: Q7 rotation of 8 points; Q15.0 exact + q8.7 shift
    theta = 0.35
    c = int(np.round(np.cos(theta) * 127))
    s = int(np.round(np.sin(theta) * 127))
    pts = rng.integers(-90, 91, (2, 8)).astype(np.int16)
    emu2 = programs.run_rotation_points((c, s), pts)
    chain2 = tc.TransformChain.identity(2).matrix(
        np.array([[c, s], [-s, c]], np.float32))
    ours2 = np.asarray(chain2.apply(jnp.asarray(pts.T.astype(np.float32)),
                                    backend="ref", dtype="q15.0")).T
    cq = int(np.round(np.cos(theta) * 128))
    sq = int(np.round(np.sin(theta) * 128))
    words = rng.integers(-127, 128, (2, 8)).astype(np.int16)
    emu3 = programs.run_rotation_points((cq, sq), words).values
    chain3 = tc.TransformChain.identity(2).matrix(
        np.array([[cq, sq], [-sq, cq]], np.float32) / 128.0)
    ours3 = np.asarray(chain3.apply(jnp.asarray(words.T), backend="ref",
                                    dtype="q8.7")).T
    parity2 = bool((ours2 == emu2.values).all()
                   and (ours3.astype(np.int32)
                        == (emu3.astype(np.int32) + 64) >> 7).all())

    print(f"[fixedpoint] emulator parity: composite I {cycles1} cycles "
          f"({'OK' if parity1 else 'MISMATCH'}), composite II "
          f"{emu2.cycles} cycles ({'OK' if parity2 else 'MISMATCH'})")
    return [
        f"fixedpoint_emulator_composite_i{tag},{cycles1 / 100:.2f},"
        f"cycles={cycles1};parity={parity1}",
        f"fixedpoint_emulator_composite_ii{tag},{emu2.cycles / 100:.2f},"
        f"cycles={emu2.cycles};parity={parity2}",
    ]


def run(smoke: bool = False) -> list[str]:
    tag = "_smoke" if smoke else ""
    iters = 2 if smoke else 5
    rows = _fused_rows(tag, iters, n_points=20_000 if smoke else 200_000)
    rows += _serving_rows(tag, iters)
    rows += _emulator_rows(tag)
    return rows
