"""Profiler + SLO benchmark rows: the analysis layer's own determinism,
gated.

Two row families (see benchmarks/PERF.md):

  * ``profile_attrib{_smoke}`` -- the seeded 64-request mixed-lane smoke
    workload served under a traced virtual clock and folded by
    ``repro.obs.profile``.  Every derived field is a bit-deterministic
    counter: span/event totals, launch counts by all three aggregation
    axes, observed and predicted HBM bytes, predicted FLOPs and M1
    cycles, and the two exactness flags the PR's acceptance rests on --
    ``attribution_exact=1`` (the attribution tree's launch count equals
    ``serving.stats["launches"]``) and ``byte_ratio_exact=1`` (every
    launch's observed/predicted byte ratio is exactly 1.0, the shared
    opcount/costmodel formula).  The wall-clock column is the host cost
    of serving + folding; never gated.
  * ``slo_burn{_smoke}`` -- the canonical scripted error-budget train
    (good@1s, bad@2s, good@3..5s on a virtual clock, one second-scale
    burn rule) plus a monitored async serving drive.  Gated fields pin
    the alert count AND the exact virtual firing/resolution instants in
    microseconds -- the monitor evaluates synchronously on every
    observation, so the instants are pure functions of the script.
"""
from __future__ import annotations

import time

from repro import serving
from repro.obs.profile import Profile, profile_smoke_workload
from repro.obs.slo import BurnRule, SLOMonitor
from repro.serving import engine, workload
from repro.serving.async_engine import AsyncGeometryServer, SLOConfig
from repro.serving.clock import VirtualClock

SEED = 17
REQUESTS = 64


def _attrib_row(tag: str) -> tuple[str, dict]:
    engine.reset_stats()
    t0 = time.perf_counter()
    tracer, _server = profile_smoke_workload(REQUESTS, seed=SEED)
    prof = Profile.from_tracer(tracer)
    wall = time.perf_counter() - t0
    c = prof.counters()
    c["attribution_exact"] = int(
        prof.launches == serving.stats["launches"] > 0)
    gated = ("events", "spans", "launches", "kernels", "launch_buckets",
             "hbm_bytes", "pred_hbm_bytes", "pred_flops",
             "pred_m1_cycles", "byte_ratio_exact", "attribution_exact")
    derived = ";".join(f"{k}={c[k]}" for k in gated)
    return f"profile_attrib{tag},{wall * 1e6:.1f},{derived}", c


def _burn_row(tag: str) -> tuple[str, dict]:
    t0 = time.perf_counter()
    # the scripted train: deterministic fire at 2.0 s, resolve at 5.0 s
    clock = VirtualClock()
    mon = SLOMonitor(clock, latency_slo_s=0.05, latency_target=0.9,
                     rejection_target=0.9,
                     rules=(BurnRule(long_s=10.0, short_s=2.0,
                                     threshold=2.0),))
    for t, latency in ((1.0, 0.01), (2.0, 0.10), (3.0, 0.01),
                       (4.0, 0.01), (5.0, 0.01)):
        clock.advance_to(t)
        mon.observe_latency(latency)
    c = mon.counters()
    # the wired path: a monitored async drive over the same seeded pool
    # (generous SLO: events flow, no alert) -- proves the three feed
    # points move the monitor without steering the engine
    serving.reset_stats()
    serving.clear_plan_cache()
    aclock = VirtualClock()
    amon = SLOMonitor(aclock, latency_slo_s=10.0, latency_target=0.9,
                      rules=(BurnRule(long_s=10.0, short_s=2.0,
                                      threshold=2.0),))
    eng = AsyncGeometryServer(
        backend="ref", clock=aclock, slo_monitor=amon,
        slo=SLOConfig(max_wait_s=0.01, target_rows=8))
    for chain, pts, qname in workload.mixed_lane_workload(
            SEED, REQUESTS, max_points=48):
        eng.submit_async(chain, pts, qformat=qname)
        aclock.advance(0.001)
        eng.poll()
    eng.drain()
    ac = amon.counters()
    wall = time.perf_counter() - t0
    out = {
        "latency_alerts_fired": c["latency_alerts_fired"],
        "latency_first_fire_us": c["latency_first_fire_us"],
        "latency_first_resolve_us": c["latency_first_resolve_us"],
        "latency_bad_events": c["latency_bad_events"],
        "served_latency_events": ac["latency_events"],
        "served_rejections_events": ac["rejections_events"],
        "served_alerts_fired": ac["latency_alerts_fired"]
        + ac["rejections_alerts_fired"],
    }
    derived = ";".join(f"{k}={v}" for k, v in out.items())
    return f"slo_burn{tag},{wall * 1e6:.1f},{derived}", out


def run(smoke: bool = False) -> list[str]:
    tag = "_smoke" if smoke else ""
    rows = []
    row, c = _attrib_row(tag)
    rows.append(row)
    print(f"profile_attrib: {c['launches']} launches over "
          f"{c['launch_buckets']} buckets / {c['kernels']} kernels, "
          f"{c['events']} trace events; attribution exact: "
          f"{bool(c['attribution_exact'])}, byte ratio exact: "
          f"{bool(c['byte_ratio_exact'])}")
    row, s = _burn_row(tag)
    rows.append(row)
    print(f"slo_burn: scripted alert fired {s['latency_alerts_fired']}x "
          f"(fire @ {s['latency_first_fire_us'] / 1e6:.1f} virtual s, "
          f"resolve @ {s['latency_first_resolve_us'] / 1e6:.1f}); "
          f"monitored drive saw {s['served_latency_events']} resolutions"
          f", {s['served_alerts_fired']} alerts")
    return rows
