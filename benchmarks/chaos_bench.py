"""Chaos benchmark: the fault-tolerant serving path under seeded injection.

Two row families (see benchmarks/PERF.md):

  * ``chaos_soak{_smoke}`` -- one seeded ``serving.run_chaos_soak``: a
    mixed affine + projective + fixed-point workload served with faults
    injected into roughly a fifth of the buckets.  The wall-clock column
    is the full soak (including per-request oracle verification); the
    derived fields are the deterministic recovery counters the chaos CI
    lane gates EXACTLY (tools/check_bench.py) -- ``lost=0`` and
    ``mismatches=0`` are the headline invariants, and
    ``recovered_rps`` reports recovered requests per second.
  * ``chaos_fallback_overhead{_smoke}`` -- the same workload served
    clean (no injector) vs under injection, timing the serving path
    alone (verification off): ``overhead`` is the wall-clock multiple
    the recovery machinery costs when faults DO occur, and
    ``extra_launches`` counts the retry/bisection launches that paid
    for containment.
"""
from __future__ import annotations

from repro import serving
from repro.serving import engine, faults, workload
from repro.serving.workload import timed as _timed

SEED = 11


def _soak(n_requests: int, verify: bool = True) -> serving.ChaosReport:
    return faults.run_chaos_soak(seed=SEED, n_requests=n_requests,
                                 backend="interpret", verify=verify)


def _serve_once(n_requests: int, injector) -> int:
    """Serve the soak's workload once; returns launches dispatched."""
    srv = engine.GeometryServer(backend="interpret",
                                injector=injector,
                                fault_config=engine.FaultConfig(
                                    backoff_base_s=0.0))
    base = serving.stats["launches"]
    for chain, pts, qname in workload.mixed_lane_workload(SEED, n_requests):
        srv.submit(chain, pts, qformat=qname)
    srv.flush()
    return serving.stats["launches"] - base


def run(smoke: bool = False) -> list[str]:
    tag = "_smoke" if smoke else ""
    n_requests = 64
    iters = 2 if smoke else 4

    rep = _soak(n_requests)
    counters = rep.counters()
    derived = ";".join(f"{k}={v}" for k, v in counters.items()
                       if k != "seed")
    rows = [
        f"chaos_soak{tag},{rep.elapsed_s * 1e6:.1f},"
        f"{derived};recovered_rps={rep.recovered_rps:.1f}",
    ]
    print(f"[chaos] soak: {rep.requests} requests, "
          f"{rep.launch_failures} launch failures -> {rep.resolved} "
          f"resolved + {rep.failed_requests} typed failures, "
          f"lost={rep.lost}, mismatches={rep.mismatches} "
          f"({rep.retries} retries, {rep.bisections} bisections, "
          f"{rep.backend_fallbacks} backend fallbacks)")

    # fallback overhead: identical workload, clean vs injected, no oracle
    inj = lambda: faults.FaultInjector(     # noqa: E731 -- fresh per serve
        seed=SEED, flaky_rate=0.06, backend_rate=0.05,
        corrupt_rate=0.05, poison_rate=0.03)
    _serve_once(n_requests, None)           # warm plans
    launches_clean = _serve_once(n_requests, None)
    best_clean = min(_timed(lambda: _serve_once(n_requests, None))
                     for _ in range(iters))
    launches_chaos = _serve_once(n_requests, inj())
    best_chaos = min(_timed(lambda: _serve_once(n_requests, inj()))
                     for _ in range(iters))
    rows.append(
        f"chaos_fallback_overhead{tag},{best_chaos * 1e6:.1f},"
        f"requests={n_requests};launches_clean={launches_clean};"
        f"launches_chaos={launches_chaos};"
        f"extra_launches={launches_chaos - launches_clean};"
        f"overhead={best_chaos / best_clean:.2f}x")
    print(f"[chaos] fallback overhead: clean {best_clean * 1e3:.1f} ms "
          f"({launches_clean} launches) vs injected "
          f"{best_chaos * 1e3:.1f} ms ({launches_chaos} launches) -> "
          f"{best_chaos / best_clean:.2f}x")
    return rows
