"""Graphics-pipeline benchmark: projective viewing chains, fused vs staged.

``benchmarks/run.py --graphics`` runs this module.  Two claims, as rows:

  * ``graphics_fused_pipeline`` -- a full 3D viewing chain (model affines
    -> camera -> perspective -> NDC cull -> viewport) executed as ONE
    fused kernel launch through the chain compiler, against the same
    chain dispatched one primitive at a time (one launch + one full HBM
    round-trip per stage).  Launch counts and HBM bytes come from
    ``repro.kernels.opcount`` -- the byte economy is recorded, not
    implied.
  * ``graphics_serving_mixed`` -- a seeded 64-request mixed affine +
    projective workload (the full ``repro.serving.workload`` template
    pool, which includes the viewing-pipeline templates) served through
    ``GeometryServer`` vs per-request dispatch: the launch-count
    reduction extends to projective plan buckets unchanged.  This row
    always runs at 64 requests -- smoke mode only trims iterations -- so
    every recorded BENCH json carries the mixed-workload launch economy.

See benchmarks/PERF.md for the row definitions.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro import graphics, serving
from repro.core.transform_chain import TransformChain
from repro.kernels import opcount
from repro.serving import workload
from repro.serving.workload import timed as _timed

#: seed for the mixed affine+projective serving row (fixed so BENCH
#: records across PRs compare the same request mix)
MIXED_SEED = 2207
MIXED_REQUESTS = 64


def _pipeline_chain() -> TransformChain:
    """Model spin/scale + camera + perspective + cull + viewport: 7
    primitives folding to one projective (H, lo, hi) plan."""
    model = (TransformChain.identity(3)
             .rotate(0.5, axis="y").scale(1.4).translate(0.2, -0.1, 0.0))
    cam = graphics.Camera(eye=(2.5, 1.8, 4.0), target=(0.0, 0.0, 0.0),
                          fov_y=np.pi / 3, near=0.5, far=40.0)
    return graphics.viewing_chain(
        model=model, camera=cam,
        viewport=graphics.Viewport(0.0, 0.0, 640.0, 480.0))


def _singles(chain: TransformChain) -> list[TransformChain]:
    """The same chain as one-primitive chains -- the staged dispatch
    baseline (one launch and one full HBM round-trip per stage)."""
    return [TransformChain(chain.dim, (ka,), (p,))
            for ka, p in zip(chain.kinds, chain.params)]


def _fused_rows(rng, *, n_points: int, iters: int, tag: str) -> list[str]:
    chain = _pipeline_chain()
    pts = jnp.asarray(rng.standard_normal((n_points, 3)) * 0.8, jnp.float32)
    singles = _singles(chain)

    def staged(p):
        for single in singles:
            p = single.apply(p, backend="ref")
        return p

    staged(pts)                                     # warm plans
    chain.project(pts, backend="ref")
    with opcount.counting() as seq_rec:
        staged(pts)
    with opcount.counting() as fused_rec:
        out, mask = chain.project(pts, backend="ref")
    us_seq = min(_timed(lambda: staged(pts)) for _ in range(iters)) * 1e6
    us_fused = min(_timed(lambda: chain.project(pts, backend="ref"))
                   for _ in range(iters)) * 1e6
    inside = int(np.sum(np.asarray(mask)))
    return [
        f"graphics_staged_pipeline{tag},{us_seq:.1f},"
        f"launches={len(seq_rec)};"
        f"hbm_bytes={opcount.total_bytes(seq_rec)}",
        f"graphics_fused_pipeline{tag},{us_fused:.1f},"
        f"launches={len(fused_rec)};"
        f"hbm_bytes={opcount.total_bytes(fused_rec)};"
        f"primitives_folded={len(chain)};"
        f"points_inside={inside};"
        f"byte_ratio_vs_staged="
        f"{opcount.total_bytes(seq_rec) / opcount.total_bytes(fused_rec):.2f}x;"
        f"speedup_vs_staged={us_seq / us_fused:.2f}x",
    ]


def _serving_rows(*, iters: int, tag: str) -> list[str]:
    reqs = workload.random_workload(seed=MIXED_SEED,
                                    n_requests=MIXED_REQUESTS,
                                    max_points=512)
    n_proj = sum(1 for c, _ in reqs if c.is_projective)

    for chain, pts in reqs:                          # warm per-request plans
        chain.apply(jnp.asarray(pts), backend="ref")
    best_single = min(
        _timed(lambda: [np.asarray(chain.apply(jnp.asarray(pts),
                                               backend="ref"))
                        for chain, pts in reqs])
        for _ in range(iters))

    srv = serving.GeometryServer(backend="ref")
    srv.serve(reqs)                                  # warm batch plans
    serving.reset_stats()
    best_batched = min(_timed(lambda: srv.serve(reqs)) for _ in range(iters))
    st = serving.stats
    launches = st["launches"] // iters
    proj_buckets = sum(1 for r in srv.last_report if r.kind == "projective")
    print(f"[graphics] {MIXED_REQUESTS} requests ({n_proj} projective): "
          f"per-request {best_single * 1e3:.1f} ms ({MIXED_REQUESTS} "
          f"launches) vs batched {best_batched * 1e3:.1f} ms "
          f"({launches} launches, {proj_buckets} projective buckets) -> "
          f"{best_single / best_batched:.2f}x")
    return [
        f"graphics_serving_mixed{tag},{best_batched * 1e6:.1f},"
        f"requests={MIXED_REQUESTS};projective_requests={n_proj};"
        f"launches={launches};"
        f"launches_saved={MIXED_REQUESTS - launches};"
        f"projective_buckets={proj_buckets};"
        f"per_request_us={best_single * 1e6:.1f};"
        f"speedup_vs_per_request={best_single / best_batched:.2f}x",
    ]


def run(smoke: bool = False) -> list[str]:
    tag = "_smoke" if smoke else ""
    iters = 2 if smoke else 5
    rng = np.random.default_rng(0)
    rows = _fused_rows(rng, n_points=1 << 12 if smoke else 1 << 18,
                       iters=iters, tag=tag)
    rows += _serving_rows(iters=iters, tag=tag)
    return rows
