"""AdamW with fp32 master weights + moments over (possibly bf16) params.

Large-scale layout: params live in model dtype (bf16 on TPU) and are what
the forward reads; the optimizer carries fp32 master/m/v, all sharded like
the params (ZeRO-style via the sharding rules in repro.distributed).
Weight decay applies to rank>=2 tensors only (norm gains / biases exempt,
the usual convention).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.optim.schedule import warmup_cosine


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params):
    # master must be a DISTINCT buffer even for fp32 params: params and
    # opt_state are both donated to the train step, and aliased leaves
    # would be donated twice.
    f32 = lambda t: jax.tree.map(
        lambda a: jnp.array(a, jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), t)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": f32(params),
        "m": zeros(params),
        "v": zeros(params),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(a.astype(jnp.float32)))
              for a in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = warmup_cosine(step, peak_lr=cfg.peak_lr,
                       warmup_steps=cfg.warmup_steps,
                       total_steps=cfg.total_steps)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if master.ndim >= 2:
            update = update + cfg.weight_decay * master
        return m, v, master - lr * update

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_w = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])

    flat_p = treedef.flatten_up_to(params)

    def cast(w, p):
        c = w.astype(p.dtype)
        if c.dtype == w.dtype:
            # keep the params output a distinct XLA value from master, or
            # CSE would alias the two donated-next-step output buffers
            c = jax.lax.optimization_barrier(c)
        return c

    new_params = treedef.unflatten(
        [cast(w, p) for w, p in zip([o[2] for o in out], flat_p)])
    new_state = {"step": step, "master": new_master, "m": new_m, "v": new_v}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
