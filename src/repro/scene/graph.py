"""The scene-graph IR: named nodes over ``TransformChain`` with dirty bits.

A ``SceneGraph`` is a forest of named nodes, each owning a LOCAL
``TransformChain`` and a parent link.  A node's WORLD chain is the
concatenation of the local chains along the root -> node path, applied in
path order: the root's primitives first, the node's own last.  That is
the shared-prefix shape of real transform traffic (the companion
graphics paper's world -> camera -> projection pipelines): every
descendant of a node shares the node's whole prefix, so the fold of that
prefix is computed ONCE and extended per child -- never recomputed per
request.

Two mechanisms make that sound:

  * **Content-hash fold CSE** (``scene.cache``): each node's world prefix
    is named by a content digest, and its fold carry is cached in a
    ``FoldCache`` shared across nodes, scenes and requests under
    (digest, fold kind).  Extending a parent's cached carry re-enters the
    SAME fold loop ``fold_structure`` runs (``fold_carry_extend``), so a
    cached world fold is bit-identical to folding the node's whole world
    chain from scratch -- the equality contract ``tests/test_scene.py``
    asserts and the serving integration relies on.

  * **Dirty propagation**: editing one node's local chain
    (``set_local``) invalidates exactly that node's subtree (per-node
    dirty bit = an invalidated world digest).  The next resolution
    recomputes digests down the dirty path and folds ONLY nodes whose
    content digest is new to the cache: cost O(changed subtree), not
    O(scene).  ``benchmarks/scene_bench.py`` gates "folds per frame ==
    dirtied nodes" exactly.

Serving: ``GeometryServer.submit_scene(scene, node, points)`` submits a
node's points through the cached world fold -- same buckets, same packed
kernels, bitwise-equal results to submitting ``scene.world_chain(node)``
(float32 and Qm.n lanes both; see ``docs/scene_graph.md``).
"""
from __future__ import annotations

import dataclasses

from repro.core import transform_chain as tc
from repro.obs import trace as obst
from repro.scene import cache as scache


@dataclasses.dataclass
class SceneNode:
    """One scene node: a named local chain + its place in the tree.

    ``world_key`` is the content digest of the node's whole root -> node
    prefix; ``None`` IS the dirty bit (an edit anywhere above invalidated
    it).  ``folded_kinds`` remembers the fold kinds this node has ever
    folded under, so a recomputation counts as a *refold* rather than
    first contact."""

    name: str
    parent: str | None
    local: tc.TransformChain
    children: list[str] = dataclasses.field(default_factory=list)
    local_key: bytes = b""
    world_key: bytes | None = None
    folded_kinds: set = dataclasses.field(default_factory=set)


class SceneGraph:
    """Named transform hierarchy with cached, incrementally-refolded
    world folds (see the module docstring for the contract)."""

    def __init__(self, dim: int = 2, *, cache: scache.FoldCache | None = None):
        """A scene of ``dim``-dimensional chains.  ``cache`` is the
        ``FoldCache`` to share; default is the process-wide
        ``scene.shared_cache()`` so independent scenes still CSE each
        other's subchains."""
        if dim not in (2, 3):
            raise ValueError(f"dim must be 2 or 3, got {dim}")
        self.dim = dim
        self.cache = cache if cache is not None else scache.shared_cache()
        self._nodes: dict[str, SceneNode] = {}
        self._roots: list[str] = []

    # -- structure -----------------------------------------------------------

    def __len__(self) -> int:
        """Number of nodes in the scene."""
        return len(self._nodes)

    def __contains__(self, name: str) -> bool:
        """True if ``name`` is a node of this scene."""
        return name in self._nodes

    def names(self) -> list[str]:
        """Every node name, in insertion order."""
        return list(self._nodes)

    def add(self, name: str, local: tc.TransformChain | None = None, *,
            parent: str | None = None) -> str:
        """Add a node under ``parent`` (None = a root) with ``local`` as
        its local chain (None = the identity chain).  Names are unique;
        the parent must already exist -- parents are fixed at add time,
        so the graph is a forest by construction (no cycles to check
        for).  Returns the name for chaining."""
        if not isinstance(name, str) or not name:
            raise ValueError(f"node name must be a non-empty str: {name!r}")
        if name in self._nodes:
            raise ValueError(f"duplicate scene node {name!r}")
        local = tc.TransformChain.identity(self.dim) if local is None \
            else self._check_local(local)
        if parent is not None and parent not in self._nodes:
            raise KeyError(f"unknown parent node {parent!r}")
        node = SceneNode(name, parent, local,
                         local_key=scache.chain_digest(
                             self.dim, local.kinds, local.params))
        self._nodes[name] = node
        if parent is None:
            self._roots.append(name)
        else:
            self._nodes[parent].children.append(name)
        return name

    def _check_local(self, local: tc.TransformChain) -> tc.TransformChain:
        if not isinstance(local, tc.TransformChain):
            raise TypeError(f"local must be a TransformChain, "
                            f"got {type(local).__name__}")
        if local.dim != self.dim:
            raise ValueError(f"local chain dim {local.dim} != scene "
                             f"dim {self.dim}")
        return local

    def _node(self, name: str) -> SceneNode:
        try:
            return self._nodes[name]
        except KeyError:
            raise KeyError(f"unknown scene node {name!r}") from None

    def parent_of(self, name: str) -> str | None:
        """The node's parent name (None for a root)."""
        return self._node(name).parent

    def children_of(self, name: str) -> list[str]:
        """The node's direct children, in add order."""
        return list(self._node(name).children)

    def local(self, name: str) -> tc.TransformChain:
        """The node's LOCAL chain (its own primitives only)."""
        return self._node(name).local

    def leaves(self) -> list[str]:
        """Every childless node, in insertion order (where point payloads
        naturally attach)."""
        return [n for n, nd in self._nodes.items() if not nd.children]

    def subtree(self, name: str) -> list[str]:
        """``name`` plus every descendant, preorder -- the set an edit of
        ``name`` dirties."""
        out, stack = [], [name]
        self._node(name)
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(reversed(self._nodes[n].children))
        return out

    def dirty(self, name: str) -> bool:
        """True if the node's world digest is invalidated (an edit at or
        above it has not been resolved yet)."""
        return self._node(name).world_key is None

    # -- editing -------------------------------------------------------------

    def set_local(self, name: str, local: tc.TransformChain) -> int:
        """Replace the node's local chain and dirty its subtree: every
        descendant's world digest is invalidated, nothing else is
        touched.  Returns the number of nodes NEWLY dirtied (already
        dirty nodes don't recount -- they still only cost one refold),
        which the ``dirtied`` counter accumulates: the next resolution of
        the whole scene performs at most that many folds, and exactly
        that many when the new parameters are fresh content (a revert to
        previously-folded content is a cache hit instead)."""
        node = self._node(name)
        node.local = self._check_local(local)
        node.local_key = scache.chain_digest(
            self.dim, local.kinds, local.params)
        dirtied = 0
        for n in self.subtree(name):
            nd = self._nodes[n]
            if nd.world_key is not None:
                nd.world_key = None
                dirtied += 1
        scache.stats["dirtied"] += dirtied
        return dirtied

    # -- world resolution ----------------------------------------------------

    def _path(self, name: str) -> list[SceneNode]:
        """root -> node chain of SceneNodes."""
        path = []
        cur: str | None = name
        while cur is not None:
            node = self._node(cur)
            path.append(node)
            cur = node.parent
        path.reverse()
        return path

    def world_structure(self, name: str) -> tuple:
        """The ``TransformChain.structure`` of the node's world chain
        (concatenated kinds along the root -> node path)."""
        kinds: tuple = ()
        for node in self._path(name):
            kinds = kinds + node.local.kinds
        return (self.dim, kinds)

    def world_kind(self, name: str) -> str:
        """Plan kind of the node's world chain (diag|matrix|projective);
        the fold-kind half of the node's cache key."""
        return tc.plan_kind_of(self.world_structure(name))

    def world_chain(self, name: str) -> tc.TransformChain:
        """The node's world chain as a plain ``TransformChain`` -- the
        independent per-request oracle: applying/folding it from scratch
        is bit-identical to the scene's cached ``world_fold`` (the
        equality the tests assert)."""
        kinds: tuple = ()
        params: tuple = ()
        for node in self._path(name):
            kinds = kinds + node.local.kinds
            params = params + node.local.params
        return tc.TransformChain(self.dim, kinds, params)

    def world_digest(self, name: str) -> str:
        """Hex content digest naming the node's world prefix -- a pure
        function of chain content, stable across processes and hash
        seeds (what the FoldCache keys on)."""
        path = self._path(name)
        self._ensure_keys(path)
        key = path[-1].world_key
        assert key is not None
        return key.hex()

    def _ensure_keys(self, path: list[SceneNode]) -> None:
        """Recompute invalidated world digests down a root -> node path
        (consuming the dirty bits on it)."""
        parent_key: bytes | None = None
        for node in path:
            if node.world_key is None:
                node.world_key = scache.path_digest(parent_key,
                                                    node.local_key)
            parent_key = node.world_key

    def _carry(self, name: str, kind: str) -> tuple:
        """Resolve the node's fold carry under ``kind``: walk up to the
        nearest cached prefix, then extend downward, caching and
        counting each fold.  Fold work == nodes on the path whose
        content digest is new to the cache under this kind."""
        path = self._path(name)
        self._ensure_keys(path)
        trc = obst.active()
        carry = None
        start = 0
        for i in range(len(path) - 1, -1, -1):
            node = path[i]
            cached = self.cache.lookup((node.world_key, kind))
            if cached is not None:
                if trc.enabled:
                    trc.instant("scene.cse_hit", node=node.name, kind=kind)
                carry, start = cached, i + 1
                break
        if carry is None:
            carry = tc.fold_carry_identity(kind, self.dim)
        for node in path[start:]:
            carry = tc.fold_carry_extend(kind, self.dim, carry,
                                         node.local.kinds,
                                         node.local.params)
            self.cache.store((node.world_key, kind), carry)
            refold = kind in node.folded_kinds
            node.folded_kinds.add(kind)
            scache.stats["folds"] += 1
            if refold:
                scache.stats["refolds"] += 1
            if trc.enabled:
                trc.instant("scene.refold" if refold else "scene.fold",
                            node=node.name, kind=kind,
                            length=len(node.local.kinds))
        return carry

    def world_fold(self, name: str) -> tuple:
        """The node's folded world parameters -- float32 (s, t) / (A, t)
        / (H, lo, hi) by world plan kind -- resolved through the shared
        ``FoldCache``.  Bit-identical to
        ``fold_structure(*world chain*)`` from scratch, because a cache
        extension re-runs the very same fold loop from the parent's
        saved state (``transform_chain.fold_carry_extend``); cost is
        O(nodes whose content is new) thanks to dirty propagation."""
        kind = self.world_kind(name)
        return tc.fold_carry_finish(kind, self._carry(name, kind))
