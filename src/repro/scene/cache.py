"""Content-hash fold CSE: digests of chain content + the shared FoldCache.

The scene graph never keys a fold on an object identity or an insertion
order -- it keys on WHAT is being folded: a ``blake2b`` digest over the
chain structure (dim + primitive kinds) and the float32-canonical bytes
of every parameter leaf.  Two subchains with equal content digest fold to
bit-identical carries (the fold casts parameters to float32 first, so
float32-canonical bytes are exactly the fold's input domain), which is
what makes a cache entry reusable across nodes, scenes, requests and
processes: the digest is a pure function of content, never of
``PYTHONHASHSEED``, id(), or construction history.

A node's WORLD digest chains its parent's world digest with its local
digest, so it names the whole root->node prefix; the cache key adds the
fold kind (``plan_kind_of`` of the full chain being resolved -- the same
prefix folds to a different carry under a diag vs a matrix loop, see
``transform_chain.fold_carry_extend``).

Counters (module ``stats``, a ``StatsView`` over the ``scene`` registry,
exported by Prometheus/profiler like the serving counters):

  folds        -- ``fold_carry_extend`` executions (cache-miss work; the
                  bench gate's "folds per frame == changed nodes" counts
                  exactly this)
  cache_misses -- lookups that missed; every miss is followed by exactly
                  one fold + store, so ``cache_misses == folds`` always
  cse_hits     -- lookups served from the cache: a subchain folded for
                  one node/request reused by another
  refolds      -- folds for a (node, kind) that had folded before, i.e.
                  dirty-driven recomputation rather than first contact
  dirtied      -- nodes invalidated by ``SceneGraph.set_local``
"""
from __future__ import annotations

import hashlib

import numpy as np

from repro.obs import metrics as obsm

_STAT_KEYS = ("folds", "cache_misses", "cse_hits", "refolds", "dirtied")

#: the scene registry behind the module ``stats`` view
#: (``obs.export.prometheus_text(REGISTRY)`` exposes it)
REGISTRY = obsm.MetricsRegistry("scene")

#: dict-facade over the counters above, same discipline as
#: ``serving.stats``
stats = obsm.StatsView(REGISTRY, _STAT_KEYS)


def reset_stats() -> None:
    """Zero the module counters (cache CONTENTS are separate state --
    ``FoldCache.clear`` / ``shared_cache().clear`` for those)."""
    for k in stats:
        stats[k] = 0


def _leaf_bytes(x, h) -> None:
    """Feed one parameter leaf (or nested tuple of leaves) to the digest
    in float32-canonical form -- the exact value domain the host fold
    reads -- with shape framing so (2,) and (1, 2) never collide."""
    if isinstance(x, (tuple, list)):
        h.update(b"(%d" % len(x))
        for e in x:
            _leaf_bytes(e, h)
        h.update(b")")
        return
    a = np.asarray(x, np.float32)
    h.update(b"[%d" % a.ndim)
    h.update(np.asarray(a.shape, np.int64).tobytes())
    h.update(a.tobytes())
    h.update(b"]")


def chain_digest(dim: int, kinds: tuple, params: tuple) -> bytes:
    """Content digest of one (sub)chain: a pure function of dim, the
    primitive kind/axis sequence, and float32-canonical parameter bytes.
    Equal digests imply bit-identical folds; stable across processes and
    hash seeds (``blake2b``, not built-in ``hash``)."""
    h = hashlib.blake2b(digest_size=16)
    h.update(b"chain:%d:" % dim)
    for k, axis in kinds:
        h.update(b"%s%d;" % (k.encode(), axis))
    _leaf_bytes(params, h)
    return h.digest()


def path_digest(parent_world: bytes | None, local: bytes) -> bytes:
    """World digest of a node: chain the parent's world digest with the
    node's local digest, naming the whole root->node prefix by content.
    ``None`` parent marks a root (an explicit tag, so a root chain and a
    child of an empty-digest parent cannot collide)."""
    h = hashlib.blake2b(digest_size=16)
    h.update(b"root:" if parent_world is None else b"path:" + parent_world)
    h.update(local)
    return h.digest()


class FoldCache:
    """The shared fold store: (world digest, fold kind) -> fold carry.

    Deliberately dumb -- lookup, store, clear -- so the CSE policy lives
    in one place (``SceneGraph``) and a cache object can be shared by any
    number of scenes: a subchain folded while resolving one scene's node
    is served to every other scene that names the same content.  Folded
    carries are immutable by convention (the fold constructs fresh
    arrays; nothing mutates them after store)."""

    def __init__(self):
        """Start empty; share one instance across scenes for CSE (the
        module's ``shared_cache()`` is the default everyone gets)."""
        self._carries: dict[tuple[bytes, str], tuple] = {}

    def __len__(self) -> int:
        """Number of cached (subchain, kind) fold entries."""
        return len(self._carries)

    def lookup(self, key: tuple[bytes, str]):
        """Return the cached carry for ``key`` or None; counts the
        module ``cse_hits`` / ``cache_misses`` counters."""
        c = self._carries.get(key)
        if c is None:
            stats["cache_misses"] += 1
        else:
            stats["cse_hits"] += 1
        return c

    def store(self, key: tuple[bytes, str], carry: tuple) -> None:
        """Save a freshly folded carry under its content key."""
        self._carries[key] = carry

    def clear(self) -> None:
        """Drop every entry (counters are ``reset_stats``'s job)."""
        self._carries.clear()


_SHARED = FoldCache()


def shared_cache() -> FoldCache:
    """The process-wide default ``FoldCache`` -- every ``SceneGraph``
    built without an explicit cache shares it, which is what makes the
    CSE *cross-request*: request handlers building scenes independently
    still fold each shared subchain once per process."""
    return _SHARED
