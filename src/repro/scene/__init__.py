"""Scene graph over the chain compiler: shared prefixes fold once.

Real transform traffic is a hierarchy, not independent chains -- the
companion graphics paper's pipelines (world -> camera -> projection ->
viewport) hang thousands of leaf payloads off a handful of shared
stages.  This package is the IR for that shape:

  * ``SceneGraph`` / ``SceneNode`` (``graph.py``) -- named nodes with
    local ``TransformChain``s, parent links and per-node dirty bits; a
    node's world chain is the root -> node concatenation.
  * ``FoldCache`` + content digests (``cache.py``) -- world folds are
    cached under (content digest of the prefix, fold kind) in a cache
    shared across scenes and requests, so a subchain folded for one
    node is never refolded for another; editing a node dirties exactly
    its subtree and the next resolution folds O(changed nodes).

The bitwise contract: a cached world fold extends the parent's saved
fold state through the SAME loop ``fold_structure`` runs
(``transform_chain.fold_carry_extend``), so it is bit-identical to
folding the node's whole world chain from scratch -- which is why
``GeometryServer.submit_scene`` can hand the cached fold straight to the
packed serving lane (float32 and Qm.n both) without weakening the
engine's packed-vs-apply equality.  Counters (``scene.stats``: folds,
cse_hits, cache_misses, refolds, dirtied) and trace instants
(``scene.fold`` / ``scene.cse_hit`` / ``scene.refold``) make the CSE
exactly gateable; see ``docs/scene_graph.md`` and
``benchmarks/scene_bench.py``.
"""
from repro.scene.cache import (FoldCache, REGISTRY, chain_digest,
                               path_digest, reset_stats, shared_cache,
                               stats)
from repro.scene.graph import SceneGraph, SceneNode

__all__ = [
    "FoldCache", "REGISTRY", "SceneGraph", "SceneNode", "chain_digest",
    "path_digest", "reset_stats", "shared_cache", "stats",
]
