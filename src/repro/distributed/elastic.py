"""Elastic re-meshing: resume a checkpoint on a different device count.

The checkpoint stores full (unsharded) host arrays; resuming onto a
smaller/larger mesh is therefore just re-placement under the new mesh's
sharding rules.  The data pipeline is stateless-seekable, so the resumed
job replays from the exact step with the new data-parallel width -- the
global batch is preserved (accumulation steps scale inversely with the
data-axis size).  See tests/test_elastic.py for the shrink-and-resume
drill and launch/train.py for the entry point.
"""
from __future__ import annotations

import jax

from repro.distributed import sharding


def place(tree, mesh, spec_tree):
    """Device_put a host pytree onto ``mesh`` under ``spec_tree``."""
    shardings = sharding.to_shardings(spec_tree, mesh)
    return jax.tree.map(jax.device_put, tree, shardings)


def replan_accum(global_batch: int, micro_per_shard: int, mesh) -> int:
    """Recompute gradient-accumulation steps for the current mesh so the
    global batch is invariant under elastic resizes."""
    fsdp, _ = sharding.axis_names(mesh)
    data_width = 1
    for a in fsdp:
        data_width *= mesh.shape[a]
    micro = micro_per_shard * data_width
    if global_batch % micro:
        raise ValueError(
            f"global batch {global_batch} not divisible by microbatch {micro}")
    return global_batch // micro
