"""Train / serve step builders (pure functions to be pjit'd by the launcher).

Training: microbatch gradient accumulation via lax.scan over the leading
``accum`` dim of the batch.  Per-microbatch backward reduces grads over the
fsdp axes in bf16 (implicit compression, see distributed/compression.py);
accumulation and the optimizer run in fp32.  Params/opt-state are donated by
the launcher so per-device memory stays flat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.optim import AdamWConfig, adamw_update


def make_train_step(model: Model, opt_cfg: AdamWConfig, accum_steps: int):
    def loss_fn(params, microbatch):
        return model.loss(params, microbatch)

    def train_step(params, opt_state, batch):
        """batch leaves: (accum, micro, ...)."""
        if accum_steps == 1:
            mb = jax.tree.map(lambda a: a[0], batch)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            def body(carry, mb):
                gacc, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                gacc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), gacc, g)
                return (gacc, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(body, (zeros, 0.0), batch)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = lsum / accum_steps
        new_params, new_opt, om = adamw_update(grads, opt_state, params, opt_cfg)
        return new_params, new_opt, {"loss": loss, **om}

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, tokens, pos, cache):
        return model.decode(params, tokens, pos, cache)
    return decode_step
