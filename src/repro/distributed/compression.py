"""Gradient compression for cross-pod (DCN) synchronisation.

Two mechanisms, both with error feedback so compression noise does not
accumulate:

  * implicit bf16: backward reduces gradients in the params' bf16 dtype
    (half the collective bytes of fp32) while the accumulation across
    microbatches and the optimizer run in fp32 -- on by default;
  * explicit int8: per-tensor-scaled int8 quantisation applied around the
    pod-axis psum (4x fewer DCN bytes), used via shard_map when
    ``--grad-compression int8`` is set on the launcher.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map


def quantize_int8(g: jnp.ndarray, err: jnp.ndarray):
    """(g + err) -> (int8 q, fp32 scale, new_err)."""
    target = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(target)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, target - deq


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(
        lambda a: jnp.zeros(a.shape, jnp.float32), params)


def pod_sync_int8(grads, err_state, mesh, pspecs):
    """All-reduce grads over the 'pod' axis with int8 + error feedback.

    Call with grads already reduced over the in-pod 'data' axis (which SPMD
    does during backward); only the slow DCN hop is compressed."""
    if "pod" not in mesh.axis_names:
        return grads, err_state

    def sync_leaf(g, err, spec):
        def inner(g_blk, err_blk):
            q, scale, new_err = quantize_int8(g_blk, err_blk)
            total = jax.lax.psum(q.astype(jnp.int32), "pod")
            scale_max = jax.lax.pmax(scale, "pod")
            g_out = (total.astype(jnp.float32) * scale_max /
                     mesh.shape["pod"]).astype(g_blk.dtype)
            return g_out, new_err

        inner_spec = P(*(s if s != "pod" else None for s in
                         (spec or P(*(None,) * g.ndim))))
        fn = shard_map(inner, mesh=mesh,
                       in_specs=(inner_spec, inner_spec),
                       out_specs=(inner_spec, inner_spec))
        return fn(g, err)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    flat_s = treedef.flatten_up_to(pspecs)
    out = [sync_leaf(g, e, s) for g, e, s in zip(flat_g, flat_e, flat_s)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
