"""Sharding rules: parameter/optimizer/activation/cache PartitionSpecs.

Layout (DESIGN.md section 4): mesh axes (pod, data, model) or (data, model).

  * ``fsdp``  = ("pod", "data")  -- ZeRO-3 weight shard + batch shard,
  * ``tp``    = "model"          -- Megatron-style tensor parallel.

Every rank>=2 weight shards its TP-natural dim over ``model`` and its other
major dim over the fsdp axes, so params AND optimizer state are fully
sharded; XLA SPMD inserts the per-layer all-gathers which, under the layer
scan, overlap with the previous layer's compute (the paper's frame-buffer
set-0/set-1 discipline, one level up).

KV caches: heads shard over ``model`` when divisible; otherwise the cache
*length* dim shards over ``model`` (sequence-sharded decode: scores stay
sharded over T and only the small PV partial-sums all-reduce).
"""
from __future__ import annotations

import re
from typing import TYPE_CHECKING

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

if TYPE_CHECKING:  # avoid repro.models import cycle (models use constrain())
    from repro.models.config import ModelConfig


def ambient_mesh():
    """Version-portable ``jax.sharding.get_abstract_mesh()``: older jax
    exposes the ambient mesh only as the thread-local physical mesh set by
    the ``with mesh:`` context.  Returns None when no mesh is active."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:                      # pragma: no cover - jax internals
        return None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-portable ``jax.shard_map``: older jax ships it under
    ``jax.experimental.shard_map`` with ``check_rep`` instead of
    ``check_vma`` (same replication-checking knob, renamed)."""
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm
    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def axis_names(mesh: Mesh) -> tuple[tuple[str, ...], str]:
    names = mesh.axis_names
    tp = "model"
    fsdp = tuple(n for n in names if n != tp)
    return fsdp, tp


# rule: path-regex -> (spec for last two dims);  extra leading dims (layer
# stack, expert dim) are replicated.
_COL = "col"   # (.., d_in, d_out_tp):  P(fsdp, tp)
_ROW = "row"   # (.., d_in_tp, d_out):  P(tp, fsdp)
_PARAM_RULES: list[tuple[str, str]] = [
    (r"\['(embed|unembed)'\]$", "embed"),          # (V, d): P(tp, fsdp)
    (r"\['(wq|wk|wv)'\]$", _COL),
    (r"\['(w_gate|w_up)'\]$", _COL),
    (r"\['in_proj'\]$", _COL),
    (r"\['router'\]$", "router"),                  # (d, E): P(fsdp, None)
    (r"\['(wo|w_down|out_proj)'\]$", _ROW),
    # conv_w stays replicated: its channel layout is (heads x headdim)
    # interleaved, which a model-axis shard cannot re-express after the
    # (B,S,di)->(B,S,h,p) reshape (forces mesh-transpose permutes).
    (r"\['conv_w'\]$", "replicate"),
]


def param_spec(path_str: str, ndim: int, fsdp, tp) -> P:
    if ndim <= 1:
        return P()
    lead = (None,) * (ndim - 2)
    for pattern, kind in _PARAM_RULES:
        if re.search(pattern, path_str):
            if kind == "embed":
                return P(*lead, tp, fsdp)
            if kind == _COL:
                return P(*lead, fsdp, tp)
            if kind == _ROW:
                return P(*lead, tp, fsdp)
            if kind == "router":
                return P(*lead, fsdp, None)
            if kind == "replicate":
                return P(*lead, None, None)
    return P(*lead, None, None)                    # unknown 2D+: replicate


def params_specs(params_shape, mesh: Mesh):
    """PartitionSpec pytree for a params (or shapes) pytree."""
    fsdp, tp = axis_names(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [param_spec(jax.tree_util.keystr(path), leaf.ndim, fsdp, tp)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_specs(opt_shape, pspecs):
    """Optimizer state mirrors the params' specs (fully sharded fp32)."""
    return {
        "step": P(),
        "master": pspecs,
        "m": pspecs,
        "v": pspecs,
    }


def batch_specs(batch_shape, mesh: Mesh, *, accum_dim: bool):
    """Training batch (accum, micro, ...) or serving batch (B, ...):
    the batch dim shards over all fsdp axes."""
    fsdp, _ = axis_names(mesh)

    def spec(leaf):
        if accum_dim:
            return P(None, fsdp, *(None,) * (leaf.ndim - 2))
        return P(fsdp, *(None,) * (leaf.ndim - 1))

    return jax.tree.map(spec, batch_shape)


def _attn_cache_spec(shape_tree, cfg: "ModelConfig", mesh: Mesh):
    fsdp, tp = axis_names(mesh)
    tp_size = mesh.shape[tp]
    heads_shardable = cfg.n_kv_heads % tp_size == 0 if cfg.n_kv_heads else False

    def spec(path, leaf):
        name = jax.tree_util.keystr(path)
        if name.endswith("['kpos']"):
            return P(*(None,) * leaf.ndim)
        # (L, B, Hkv, T, D)
        if heads_shardable:
            return P(None, fsdp, tp, None, None)
        return P(None, fsdp, None, tp, None)       # sequence-sharded cache

    flat, treedef = jax.tree_util.tree_flatten_with_path(shape_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def cache_specs(cache_shape, cfg: "ModelConfig", mesh: Mesh):
    """Specs for the serve cache pytree (attention / ssm / hybrid / encdec)."""
    fsdp, tp = axis_names(mesh)

    def spec(path, leaf):
        name = jax.tree_util.keystr(path)
        if "kpos" in name:
            return P(*(None,) * leaf.ndim)
        if "'state'" in name:                      # (L, B, h, p, n)
            return P(None, fsdp, None, tp, None)
        if "'conv'" in name:                       # (L, B, w-1, ch)
            return P(None, fsdp, None, tp)
        # attention k/v (self or cross): (L, B, Hkv, T, D)
        tp_size = mesh.shape[tp]
        if cfg.n_kv_heads and cfg.n_kv_heads % tp_size == 0:
            return P(None, fsdp, tp, None, None)
        return P(None, fsdp, None, tp, None)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def constrain(x, *axes):
    """Best-effort activation sharding constraint under the ambient mesh.

    ``axes`` name mesh axes per dim ("batch" expands to all fsdp axes);
    axes missing from the mesh or not dividing the dim are dropped, and the
    call is a no-op outside jit/mesh contexts -- so model code can pin its
    activation layouts without caring whether it runs on 1 CPU device or
    the 512-chip production mesh."""
    mesh = ambient_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    names = set(mesh.axis_names)
    fsdp = tuple(n for n in ("pod", "data") if n in names)
    spec = []
    for i, ax in enumerate(axes):
        if ax is None:
            spec.append(None)
            continue
        group = fsdp if ax == "batch" else (ax,) if isinstance(ax, str) else ax
        group = tuple(a for a in group if a in names)
        size = 1
        for a in group:
            size *= mesh.shape[a]
        if not group or size == 0 or x.shape[i] % size:
            spec.append(None)
        else:
            spec.append(group if len(group) > 1 else group[0])
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def sanitize_specs(shape_tree, spec_tree, mesh: Mesh):
    """Drop spec axes whose mesh size does not divide the tensor dim.

    pjit *arguments* require exact divisibility; odd vocab sizes (50280,
    49155, 32001, 51865) or batch=1 long-context cells fall back to
    replication on that dim.  The downgrades are deliberate production
    behaviour and are surfaced in the dry-run record."""
    def fix(shape_leaf, spec):
        dims = shape_leaf.shape
        new = []
        for i, axis in enumerate(spec):
            if axis is None or i >= len(dims):
                new.append(axis)
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            new.append(axis if dims[i] % size == 0 else None)
        return P(*new)

    return jax.tree.map(fix, shape_tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def to_shardings(spec_tree, mesh: Mesh, shape_tree=None):
    if shape_tree is not None:
        spec_tree = sanitize_specs(shape_tree, spec_tree, mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
