from repro.distributed import compression, elastic, sharding

# repro.distributed.steps imports the model layer; import it directly to
# keep this package importable from inside model code (sharding constraints).
__all__ = ["sharding", "compression", "elastic"]
