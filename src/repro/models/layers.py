"""Shared layers.  Every residual add routes through the paper's
vector-vector primitive (``kernels.vecadd``) and every norm through the
derived-scalar scaling kernel -- the model stack is built *out of* the
paper's three linear-algebra classes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import rmsnorm as k_rmsnorm
from repro.kernels import vecadd as k_vecadd


def residual_add(x: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    """Paper section 5.1 vector-vector op as the residual connection."""
    return k_vecadd(x, delta)


def rmsnorm(x: jnp.ndarray, gain: jnp.ndarray, eps: float) -> jnp.ndarray:
    return k_rmsnorm(x, gain, eps=eps)


# -- dense / embedding --------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32)).astype(dtype)


def embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def logits_head(x: jnp.ndarray, table: jnp.ndarray,
                softcap: float = 0.0) -> jnp.ndarray:
    """x (..., d) @ table.T (V, d) -> (..., V); fp32 accumulation."""
    out = jax.lax.dot_general(x, table, (((x.ndim - 1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if softcap:
        out = jnp.tanh(out / softcap) * softcap
    return out


# -- SwiGLU MLP ---------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype),
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype, scale=d_ff ** -0.5),
    }


def mlp(params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


# -- positions ---------------------------------------------------------------

def sinusoidal_positions(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """Classic transformer sinusoids (whisper's position encoding)."""
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -- losses --------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 1e-4):
    """Token-mean CE in fp32 with optional z-loss; labels < 0 are masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse ** 2
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / denom
