"""Model assembly: decoder-only LMs (dense/MoE/SSM/hybrid/VLM) and the
whisper-style encoder-decoder, all with scan-over-layers (stacked params)
so 80-95 layer configs lower to compact HLO.

Batch dict convention:
  LM      : {"tokens": (B,S) int32, "labels": (B,S) int32}
  VLM     : + {"patches": (B,P,d) precomputed patch embeddings (stub)}
  enc-dec : {"frames": (B,S_enc,d) precomputed frame embeddings (stub),
             "tokens"/"labels": decoder side}
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import blocks
from repro.models.config import ModelConfig
from repro.models.layers import (
    cross_entropy, embed, embed_init, logits_head, rmsnorm,
    sinusoidal_positions,
)

_REMAT_POLICIES = {
    "dots": lambda: jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    "full": lambda: jax.checkpoint_policies.nothing_saveable,
}


class Model:
    """Functional model: params are plain pytrees, methods are pure."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = cfg.activation_dtype
        k_emb, k_head, k_layers, k_enc = jax.random.split(key, 4)
        params = {
            "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = embed_init(k_head, cfg.vocab_size,
                                           cfg.d_model, dtype)
        role = "encdec_decoder" if cfg.is_encdec else "decoder"
        params["layers"] = jax.vmap(
            lambda k: blocks.init(k, cfg, dtype, role))(
                jax.random.split(k_layers, cfg.n_layers))
        if cfg.is_encdec:
            params["enc_layers"] = jax.vmap(
                lambda k: blocks.init(k, cfg, dtype, "encoder"))(
                    jax.random.split(k_enc, cfg.encoder_layers))
            params["enc_final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        return params

    # ------------------------------------------------------------- embeddings
    def _embed_inputs(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"])
        if cfg.frontend == "vision" and "patches" in batch:
            p = batch["patches"].astype(x.dtype)
            x = jnp.concatenate([p, x[:, p.shape[1]:]], axis=1)
        if cfg.pos_embed == "sinusoidal":
            pos = sinusoidal_positions(jnp.arange(x.shape[1]), cfg.d_model)
            x = x + pos[None].astype(x.dtype)
        return x

    def _scan(self, layers, x, body):
        cfg = self.cfg
        if cfg.remat in _REMAT_POLICIES:
            body = jax.checkpoint(body, policy=_REMAT_POLICIES[cfg.remat]())
        elif cfg.remat != "none":
            raise ValueError(f"unknown remat policy {cfg.remat!r}")
        return jax.lax.scan(body, x, layers)

    def _encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """Encoder stack over precomputed frame embeddings (audio stub)."""
        cfg = self.cfg
        x = frames.astype(cfg.activation_dtype)
        pos = sinusoidal_positions(jnp.arange(x.shape[1]), cfg.d_model)
        x = x + pos[None].astype(x.dtype)

        def body(carry, lp):
            h, aux = carry
            h, aux_l = blocks.apply(lp, h, cfg, causal=False)
            return (h, aux + aux_l), None

        (x, _), _ = self._scan(params["enc_layers"], (x, 0.0), body)
        return rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)

    # ---------------------------------------------------------------- training
    def forward(self, params, batch):
        """Full-sequence logits.  Returns (logits (B,S,V) fp32, aux)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        enc_out = self._encode(params, batch["frames"]) if cfg.is_encdec else None

        def body(carry, lp):
            h, aux = carry
            ckv = (attn_mod.encode_kv(lp["cross"], enc_out, cfg)
                   if cfg.is_encdec else None)
            h, aux_l = blocks.apply(lp, h, cfg, causal=True, cross_kv=ckv)
            return (h, aux + aux_l), None

        (x, aux), _ = self._scan(params["layers"], (x, 0.0), body)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        return logits_head(x, table, cfg.logit_softcap), aux

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        ce = cross_entropy(logits, batch["labels"])
        total = ce + self.cfg.router_aux_weight * aux
        return total, {"ce": ce, "aux": aux}

    # ---------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_len: int, enc_len: int = 0):
        cfg = self.cfg
        role = "encdec_decoder" if cfg.is_encdec else "decoder"
        one = blocks.init_cache(cfg, batch, max_len, role, enc_len)
        return jax.tree.map(
            lambda a: jnp.tile(a[None], (cfg.n_layers,) + (1,) * a.ndim), one)

    def cache_struct(self, batch: int, max_len: int, enc_len: int = 0):
        return jax.eval_shape(
            functools.partial(self.init_cache, batch, max_len, enc_len))

    def prefill(self, params, batch, cache):
        """Prompt pass.  Returns (last-position logits (B, V), cache)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        enc_out = self._encode(params, batch["frames"]) if cfg.is_encdec else None

        def body(h, xs):
            lp, c = xs
            h, c2 = blocks.prefill(lp, h, cfg, c, start=0, enc_out=enc_out)
            return h, c2

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        return logits_head(x, table, cfg.logit_softcap)[:, 0], new_cache

    def decode(self, params, tokens, pos, cache):
        """One step: tokens (B,) int32 at absolute position ``pos``.
        Returns (logits (B, V), cache)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens[:, None])
        if cfg.pos_embed == "sinusoidal":
            p = sinusoidal_positions(jnp.asarray(pos).reshape(1), cfg.d_model)
            x = x + p[None].astype(x.dtype)

        def body(h, xs):
            lp, c = xs
            h, c2 = blocks.decode(lp, h, cfg, c, pos)
            return h, c2

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        return logits_head(x, table, cfg.logit_softcap)[:, 0], new_cache


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
