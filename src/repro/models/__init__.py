from repro.models.config import ModelConfig, attention_flops, flops_per_token
from repro.models.transformer import Model, build

__all__ = ["ModelConfig", "Model", "build", "flops_per_token",
           "attention_flops"]
