"""GQA attention with RoPE, sliding windows, and ring-buffer KV caches.

Three entry modes share one parameter set:
  * ``attend``      -- full-sequence training/encoding (no cache),
  * ``prefill``     -- fills a cache (linear for full attention, ring buffer
                       for SWA) and returns outputs for every position,
  * ``decode_step`` -- one new token against the cache.

The QK^T / PV products are the paper's matmul primitive, RoPE its rotation
primitive, and the KV stream through the blockwise kernel its frame-buffer
discipline; see repro.kernels.flash_attention.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import attention as k_attention
from repro.kernels import rope as k_rope
from repro.kernels.flash_attention import ref as attn_ref
from repro.kernels.rope import ref as rope_ref
from repro.models.config import ModelConfig


def init(key, cfg: ModelConfig, dtype, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, hq * hd), jnp.float32) * scale).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, hkv * hd), jnp.float32) * scale).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, hkv * hd), jnp.float32) * scale).astype(dtype),
        "wo": (jax.random.normal(ks[3], (hq * hd, d), jnp.float32)
               * (hq * hd) ** -0.5).astype(dtype),
    }


def _split_heads(x, n_heads, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)   # (B, H, S, D)


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def _qkv(params, x, cfg: ModelConfig, positions: Optional[jnp.ndarray],
         use_rope: bool):
    q = _split_heads(x @ params["wq"], cfg.n_heads, cfg.head_dim)
    k = _split_heads(x @ params["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(x @ params["wv"], cfg.n_kv_heads, cfg.head_dim)
    if use_rope:
        cos, sin = rope_ref.rope_tables(positions, cfg.head_dim,
                                        cfg.rope_theta, jnp.float32)
        q = k_rope(q, cos, sin)
        k = k_rope(k, cos, sin)
    return q, k, v


# ---------------------------------------------------------------------------
# full-sequence attention (training / encoder)
# ---------------------------------------------------------------------------

def attend(params, x: jnp.ndarray, cfg: ModelConfig, *, causal: bool = True,
           block_kv: int = 4096) -> jnp.ndarray:
    b, s, _ = x.shape
    use_rope = cfg.pos_embed == "rope"
    q, k, v = _qkv(params, x, cfg, jnp.arange(s), use_rope)
    out = k_attention(q, k, v, causal=causal, window=cfg.window,
                      block_kv=block_kv)
    return _merge_heads(out) @ params["wo"]


def cross_attend(params, x: jnp.ndarray, kv_cache: dict,
                 cfg: ModelConfig) -> jnp.ndarray:
    """Decoder cross-attention against precomputed encoder K/V."""
    q = _split_heads(x @ params["wq"], cfg.n_heads, cfg.head_dim)
    out = k_attention(q, kv_cache["k"], kv_cache["v"], causal=False)
    return _merge_heads(out) @ params["wo"]


def encode_kv(params, enc_out: jnp.ndarray, cfg: ModelConfig) -> dict:
    """Precompute cross-attention K/V from encoder output (prefill)."""
    k = _split_heads(enc_out @ params["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(enc_out @ params["wv"], cfg.n_kv_heads, cfg.head_dim)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# KV cache (linear for full attention, ring buffer for SWA)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CacheSpec:
    batch: int
    n_kv_heads: int
    length: int          # cache slots: T_max (full) or window (SWA)
    head_dim: int
    ring: bool           # True for SWA ring buffer
    dtype: str = "bfloat16"   # bfloat16 | int8 (per-slot-scaled KV quant)


def cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> CacheSpec:
    ring = cfg.window is not None and cfg.window < max_len
    dtype = "int8" if cfg.kv_cache_dtype == "int8" else cfg.dtype
    return CacheSpec(batch, cfg.n_kv_heads, cfg.window if ring else max_len,
                     cfg.head_dim, ring, dtype)


def init_cache(spec: CacheSpec):
    shape = (spec.batch, spec.n_kv_heads, spec.length, spec.head_dim)
    cache = {
        # absolute position held in each slot (-1 = empty)
        "kpos": jnp.full((spec.length,), -1, jnp.int32),
    }
    if spec.dtype == "int8":
        # beyond-paper: per-(slot, head) scaled int8 KV -- halves the cache
        # of the over-HBM 32k decode cells (EXPERIMENTS section Dry-run)
        cache.update(
            k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
            kscale=jnp.zeros(shape[:3] + (1,), jnp.float32),
            vscale=jnp.zeros(shape[:3] + (1,), jnp.float32))
    else:
        dt = jnp.dtype(spec.dtype)
        cache.update(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt))
    return cache


def _quantize(x):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    q = jnp.round(x.astype(jnp.float32) /
                  jnp.maximum(scale, 1e-9)).astype(jnp.int8)
    return q, scale


def _cache_kv(cache, which: str):
    """Read k or v from the cache, dequantizing if int8."""
    x = cache[which]
    if x.dtype == jnp.int8:
        return x.astype(jnp.float32) * cache[which[0] + "scale"]
    return x


def _write_linear(cache, k_new, v_new, start):
    s = k_new.shape[2]
    out = dict(cache)
    if cache["k"].dtype == jnp.int8:
        for name, val in (("k", k_new), ("v", v_new)):
            q, scale = _quantize(val)
            out[name] = jax.lax.dynamic_update_slice(
                cache[name], q, (0, 0, start, 0))
            out[name + "scale"] = jax.lax.dynamic_update_slice(
                cache[name + "scale"], scale, (0, 0, start, 0))
    else:
        out["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, 0, start, 0))
        out["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, 0, start, 0))
    out["kpos"] = jax.lax.dynamic_update_slice(
        cache["kpos"], start + jnp.arange(s, dtype=jnp.int32), (start,))
    return out


def _write_ring(cache, k_new, v_new, start, window):
    s = k_new.shape[2]
    positions = start + jnp.arange(s, dtype=jnp.int32)
    slots = positions % window
    if s >= window:      # only the last `window` entries survive
        k_new = k_new[:, :, -window:]
        v_new = v_new[:, :, -window:]
        positions = positions[-window:]
        slots = slots[-window:]
    k = cache["k"].at[:, :, slots].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[:, :, slots].set(v_new.astype(cache["v"].dtype))
    kpos = cache["kpos"].at[slots].set(positions)
    return {"k": k, "v": v, "kpos": kpos}


def _cached_attention(q, cache, qpos, window):
    """Attend q (B, Hq, S, D) over cache slots with per-slot absolute
    positions (handles linear, ring, and int8-quantized layouts)."""
    kpos = cache["kpos"]
    group = q.shape[1] // cache["k"].shape[1]
    valid = kpos >= 0
    mask = valid[None, :] & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    k = attn_ref._expand_kv(_cache_kv(cache, "k"), group)
    v = attn_ref._expand_kv(_cache_kv(cache, "v"), group)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    logits = logits * (q.shape[-1] ** -0.5)
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def prefill(params, x: jnp.ndarray, cfg: ModelConfig, cache: dict,
            start: int = 0, block_kv: int = 1024):
    """Process a prompt, fill the cache, return per-position outputs."""
    b, s, _ = x.shape
    use_rope = cfg.pos_embed == "rope"
    positions = start + jnp.arange(s)
    q, k, v = _qkv(params, x, cfg, positions, use_rope)
    out = k_attention(q, k, v, causal=True, window=cfg.window,
                      q_offset=0, block_kv=block_kv)
    ring = cfg.window is not None and cache["kpos"].shape[0] == cfg.window
    if ring:
        cache = _write_ring(cache, k, v, start, cfg.window)
    else:
        cache = _write_linear(cache, k.astype(cfg.activation_dtype),
                              v.astype(cfg.activation_dtype), start)
    return _merge_heads(out) @ params["wo"], cache


def decode_step(params, x: jnp.ndarray, cfg: ModelConfig, cache: dict,
                pos) -> tuple[jnp.ndarray, dict]:
    """One token x (B, 1, d) at absolute position ``pos`` (traced ok)."""
    use_rope = cfg.pos_embed == "rope"
    positions = jnp.asarray(pos).reshape(1)
    q, k, v = _qkv(params, x, cfg, positions, use_rope)
    window = cfg.window
    ring = window is not None and cache["kpos"].shape[0] == window
    if ring:
        slot = jnp.asarray(pos) % window
        knew = cache["k"].at[:, :, slot].set(k[:, :, 0].astype(cache["k"].dtype))
        vnew = cache["v"].at[:, :, slot].set(v[:, :, 0].astype(cache["v"].dtype))
        kpos = cache["kpos"].at[slot].set(jnp.asarray(pos, jnp.int32))
        cache = {"k": knew, "v": vnew, "kpos": kpos}
    else:
        cache = _write_linear(cache, k.astype(cfg.activation_dtype),
                              v.astype(cfg.activation_dtype), pos)
    out = _cached_attention(q, cache, positions, window)
    return _merge_heads(out) @ params["wo"], cache
