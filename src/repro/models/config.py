"""Unified model configuration covering all assigned architecture families.

One frozen dataclass drives dense / MoE / SSM / hybrid / enc-dec / VLM
builds; ``src/repro/configs/<arch>.py`` instantiates the exact assigned
configs and ``reduced()`` derives the CPU smoke-test variants.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    # -- attention ----------------------------------------------------------
    window: Optional[int] = None     # sliding-window size (SWA)
    rope_theta: float = 10000.0
    pos_embed: str = "rope"          # rope | sinusoidal (whisper)
    # -- MoE ------------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # -- SSM (mamba-2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # -- enc-dec / frontend stubs ----------------------------------------------
    encoder_layers: int = 0          # > 0 -> encoder-decoder
    frontend: Optional[str] = None   # audio | vision (stub: precomputed embeds)
    n_frontend_tokens: int = 0       # vision: patch tokens replacing prefix
    # -- numerics / training ----------------------------------------------------
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8 (serving)
    tie_embeddings: bool = False
    remat: str = "dots"              # none | dots | full
    logit_softcap: float = 0.0
    # -- source note -------------------------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------ derived
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_headdim

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    # -------------------------------------------------------------- param count
    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, v = self.d_model, self.vocab_size
        hd = self.head_dim
        per_layer = 0
        if not self.attn_free:
            per_layer += d * (self.n_heads * hd)              # wq
            per_layer += 2 * d * (self.n_kv_heads * hd)       # wk, wv
            per_layer += (self.n_heads * hd) * d              # wo
            per_layer += d                                    # attn norm gain
        if self.family == "ssm" or self.family == "hybrid":
            di, ns, gh = self.ssm_inner, self.ssm_state, self.ssm_heads
            per_layer += d * (2 * di + 2 * ns + gh)           # in_proj (z,x,B,C,dt)
            per_layer += self.ssm_conv_width * (di + 2 * ns)  # conv
            per_layer += di * d                               # out_proj
            per_layer += 2 * gh + di                          # A_log, D, dt_bias... norm
            per_layer += d                                    # ssm norm gain
        if self.d_ff > 0:
            ffn = 3 * d * self.d_ff                           # SwiGLU: gate, up, down
            if self.n_experts:
                per_layer += self.n_experts * ffn + d * self.n_experts  # + router
            else:
                per_layer += ffn
            per_layer += d                                    # mlp norm gain
        total_layers = self.n_layers + self.encoder_layers
        cross = 0
        if self.is_encdec:   # decoder cross-attention per decoder layer
            cross = self.n_layers * (2 * d * (self.n_kv_heads * hd)
                                     + d * (self.n_heads * hd)
                                     + (self.n_heads * hd) * d + d)
        embed = v * d * (1 if self.tie_embeddings else 2)
        return per_layer * total_layers + cross + embed + d   # final norm

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        ffn = 3 * d * self.d_ff
        dead = (self.n_experts - self.experts_per_token) * ffn * self.n_layers
        return self.param_count() - dead

    # ---------------------------------------------------------------- reduction
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=2,
            encoder_layers=2 if self.is_encdec else 0,
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_head=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            window=min(self.window, 32) if self.window else None,
            n_frontend_tokens=min(self.n_frontend_tokens, 8),
            dtype="float32",
        )


def flops_per_token(cfg: ModelConfig) -> float:
    """MODEL_FLOPS/token = 6 * N_active (the roofline's 'useful' compute)."""
    return 6.0 * cfg.active_param_count()


def attention_flops(cfg: ModelConfig, batch: int, seq: int,
                    kv_len: int | None = None, causal: bool = True) -> float:
    """Extra attention score/value FLOPs not counted in 6N (for roofline)."""
    if cfg.attn_free:
        return 0.0
    kv_len = kv_len or seq
    if cfg.window:
        kv_len = min(kv_len, cfg.window)
    pairs = batch * cfg.n_heads * seq * kv_len
    if causal and kv_len == seq:
        pairs /= 2
    layers = cfg.n_layers + cfg.encoder_layers
    return 12.0 * pairs * cfg.head_dim * layers  # 2 matmuls * 2 ops * 3 (fwd+bwd)


def ssd_flops(cfg: ModelConfig, batch: int, seq: int) -> float:
    """SSD chunked-scan FLOPs beyond 6N (intra-chunk quadratic + states).

    Per chunk of length Lc: G = C B^T (2 Lc^2 n), y_intra = att @ xdt
    (2 Lc^2 h p), chunk state S_c and y_inter (2 Lc h p n each).  x3 for
    fwd+bwd in training (callers divide for inference)."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    lc = cfg.ssm_chunk
    n, h, p = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    tokens = batch * seq
    per_token = 2 * lc * (n + h * p) + 4 * h * p * n
    return 3.0 * per_token * tokens * cfg.n_layers
