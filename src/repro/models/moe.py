"""Top-k mixture-of-experts FFN -- explicit shard_map distribution.

Distribution history (EXPERIMENTS.md section Perf, dbrx cell): two
global-view (pjit-propagated) dispatch layouts measured 6.1-7.2 TB/device
of collectives on dbrx train_4k -- the SPMD partitioner conservatively
replicates + all-reduces the dispatch scatters.  The production layout is
therefore EXPLICIT:

  * ``moe_ffn`` shard_maps over the whole mesh: tokens local to their data
    shard (one group = one sequence), expert weights' d_ff dim local to
    the "model" shard (expert tensor parallelism -- fine-grained MoE never
    needs an all-to-all);
  * inside, dispatch is plain local jnp: sort-based (argsort by expert id
    + running starts), capacity C = ceil(cf*S*k/E) per sequence, dropped
    tokens write to a sentinel row;
  * the ONE collective is an explicit bf16 psum of the combined (B,S,d)
    output over "model" (combine is linear, so reducing after combine
    moves S rows instead of E*C capacity slots -- 5x fewer bytes at
    top-4 x 1.25 capacity);
  * router fp32; Switch aux loss pmean'd over the data axes.

Without a mesh (single-device tests) the same local function runs
directly.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.distributed.sharding import ambient_mesh, shard_map
from repro.models.config import ModelConfig


def init(key, cfg: ModelConfig, dtype):
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, ff ** -0.5
    return {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, ff), jnp.float32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, ff), jnp.float32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, ff, d), jnp.float32) * s_out).astype(dtype),
    }


def capacity(cfg: ModelConfig, group_tokens: int) -> int:
    c = int(cfg.capacity_factor * group_tokens * cfg.experts_per_token
            / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to sublane multiple


def _moe_local(router, w_gate, w_up, w_down, x, cfg: ModelConfig,
               tp_axis: str | None):
    """Per-shard MoE; x (B_local, S, d); w_* carry a LOCAL d_ff slice."""
    orig_b = x.shape[0]
    if x.shape[1] == 1 and orig_b > 1:
        # decode: one token per sequence -- dispatch the local batch as a
        # single group, or per-sequence capacity pads every token to 8
        # expert slots (measured 20x useful-flops loss on dbrx decode)
        x = x.reshape(1, orig_b, -1)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    c = capacity(cfg, s)
    sk = s * k

    # routing (fp32, replicated across the model axis)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)
    frac = jnp.mean(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32),
                    axis=(0, 1, 2))
    aux = e * jnp.sum(frac * probs.mean(axis=(0, 1)))

    # group-local sort-based dispatch (one group per sequence)
    ids = expert_ids.reshape(b, sk)
    gates = gate_vals.reshape(b, sk)
    order = jnp.argsort(ids, axis=-1, stable=True)
    sid = jnp.take_along_axis(ids, order, -1)
    stok = order // k
    sgate = jnp.take_along_axis(gates, order, -1)
    counts = jax.nn.one_hot(ids, e, dtype=jnp.int32).sum(axis=1)
    starts = jnp.cumsum(counts, axis=-1) - counts
    pos = jnp.arange(sk)[None] - jnp.take_along_axis(starts, sid, -1)
    keep = pos < c
    slot = jnp.where(keep, sid * c + pos, e * c)

    brow = jnp.arange(b)[:, None]
    rows = e * c + 1
    flat_slot = (brow * rows + slot).reshape(-1)
    flat_tok = (brow * s + stok).reshape(-1)
    xg = jnp.take(x.reshape(b * s, d), flat_tok, axis=0)
    buf = jnp.zeros((b * rows, d), x.dtype).at[flat_slot].set(xg)
    xe = buf.reshape(b, rows, d)[:, :e * c].reshape(b, e, c, d)

    # expert SwiGLU on the local d_ff slice (bf16 in, fp32 accumulate)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, w_gate,
                               preferred_element_type=jnp.float32)) * \
        jnp.einsum("becd,edf->becf", xe, w_up,
                   preferred_element_type=jnp.float32)
    h = h.astype(x.dtype)
    ye = jnp.einsum("becf,efd->becd", h, w_down).astype(x.dtype)

    # combine locally (linear in ye), then ONE bf16 psum over the TP axis
    yflat = jnp.concatenate(
        [ye.reshape(b, e * c, d), jnp.zeros((b, 1, d), ye.dtype)],
        axis=1).reshape(b * rows, d)
    contrib = jnp.take(yflat, flat_slot, axis=0).reshape(b, sk, d) * \
        (sgate * keep).astype(ye.dtype)[..., None]
    y = jnp.zeros((b * s, d), x.dtype).at[flat_tok].add(
        contrib.reshape(-1, d).astype(x.dtype)).reshape(b, s, d)
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)
    if orig_b != b:
        y = y.reshape(orig_b, 1, d)
    return y, aux


def moe_ffn(params, x: jnp.ndarray, cfg: ModelConfig):
    """x (B, S, d) -> (y (B, S, d), aux scalar); shard_mapped under a mesh."""
    mesh = ambient_mesh()
    if mesh is None or not mesh.axis_names:
        return _moe_local(params["router"], params["w_gate"], params["w_up"],
                          params["w_down"], x, cfg, tp_axis=None)

    from jax.sharding import PartitionSpec as P
    names = set(mesh.axis_names)
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    tp = "model" if "model" in names else None
    ff_spec = P(None, None, tp) if tp and cfg.d_ff % mesh.shape[tp] == 0 \
        else P(None, None, None)
    ff_spec_down = P(None, ff_spec[2], None)
    batch_spec = P(fsdp if x.shape[0] % _width(mesh, fsdp) == 0 else None,
                   None, None)

    def local_fn(router, w_gate, w_up, w_down, xl):
        y, aux = _moe_local(router, w_gate, w_up, w_down, xl, cfg,
                            tp_axis=ff_spec[2])
        if fsdp:
            aux = jax.lax.pmean(aux, fsdp)
        if tp:
            aux = jax.lax.pmean(aux, tp)  # identical, but align replication
        return y, aux

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, None), ff_spec, ff_spec, ff_spec_down, batch_spec),
        out_specs=(batch_spec, P()), check_vma=False)
    return fn(params["router"], params["w_gate"], params["w_up"],
              params["w_down"], x)


def _width(mesh, axes) -> int:
    w = 1
    for a in axes:
        w *= mesh.shape[a]
    return max(w, 1)
