"""Residual blocks for every assigned family, scan-compatible.

``apply`` is the single entry used inside the layer scan; its cache pytree
structure is fixed per family so prefill/decode scans stay uniform:

  dense/moe : cache = attention cache dict
  ssm       : cache = {state, conv}
  hybrid    : cache = {"attn": ..., "ssm": ...}
  encdec dec: cache = {"self": ..., "cross": {k, v}}
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import mlp, mlp_init, residual_add, rmsnorm


def init(key, cfg: ModelConfig, dtype, role: str = "decoder"):
    """One layer's params.  role: decoder | encoder | encdec_decoder."""
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p = {}
    if cfg.family == "ssm":
        p["ssm_norm"] = jnp.ones((d,), jnp.float32)
        p["ssm"] = ssm_mod.init(ks[0], cfg, dtype)
        return p
    p["attn_norm"] = jnp.ones((d,), jnp.float32)
    p["attn"] = attn.init(ks[0], cfg, dtype)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_mod.init(ks[1], cfg, dtype)
        p["attn_gain"] = jnp.ones((d,), jnp.float32)
        p["ssm_gain"] = jnp.ones((d,), jnp.float32)
    if role == "encdec_decoder":
        p["cross_norm"] = jnp.ones((d,), jnp.float32)
        p["cross"] = attn.init(ks[2], cfg, dtype)
    if cfg.d_ff:
        p["mlp_norm"] = jnp.ones((d,), jnp.float32)
        if cfg.n_experts:
            p["moe"] = moe_mod.init(ks[3], cfg, dtype)
        else:
            p["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff, dtype)
    return p


def _mixer_full(p, x, cfg: ModelConfig, causal: bool):
    """Token mixer, full-sequence (train/encode).  Returns (delta, aux)."""
    if cfg.family == "ssm":
        return ssm_mod.forward(p["ssm"], rmsnorm(x, p["ssm_norm"], cfg.norm_eps), cfg), 0.0
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    a = attn.attend(p["attn"], h, cfg, causal=causal)
    if cfg.family == "hybrid":
        s = ssm_mod.forward(p["ssm"], h, cfg)
        a = 0.5 * (rmsnorm(a, p["attn_gain"], cfg.norm_eps)
                   + rmsnorm(s, p["ssm_gain"], cfg.norm_eps))
    return a, 0.0


def _ffn(p, x, cfg: ModelConfig):
    """Channel mixer.  Returns (delta, aux)."""
    if not cfg.d_ff:
        return None, 0.0
    h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    if cfg.n_experts:
        y, aux = moe_mod.moe_ffn(p["moe"], h, cfg)
        return y, aux
    return mlp(p["mlp"], h), 0.0


def apply(p, x: jnp.ndarray, cfg: ModelConfig, *, causal: bool = True,
          cross_kv: Optional[dict] = None):
    """Full-sequence block (training / encoding).  (x, aux) out."""
    delta, _ = _mixer_full(p, x, cfg, causal)
    x = constrain(residual_add(x, delta.astype(x.dtype)), "batch", None, None)
    if cross_kv is not None:
        h = rmsnorm(x, p["cross_norm"], cfg.norm_eps)
        x = residual_add(x, attn.cross_attend(p["cross"], h, cross_kv, cfg).astype(x.dtype))
    delta, aux = _ffn(p, x, cfg)
    if delta is not None:
        x = constrain(residual_add(x, delta.astype(x.dtype)),
                      "batch", None, None)
    return x, aux


# ---------------------------------------------------------------------------
# cached paths
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, role: str,
               enc_len: int = 0):
    if cfg.family == "ssm":
        return ssm_mod.init_cache(cfg, batch)
    spec = attn.cache_spec(cfg, batch, max_len)
    c = attn.init_cache(spec)
    if cfg.family == "hybrid":
        return {"attn": c, "ssm": ssm_mod.init_cache(cfg, batch)}
    if role == "encdec_decoder":
        hd = cfg.head_dim
        z = jnp.zeros((batch, cfg.n_kv_heads, enc_len, hd), cfg.activation_dtype)
        return {"self": c, "cross": {"k": z, "v": z}}
    return c


def prefill(p, x: jnp.ndarray, cfg: ModelConfig, cache, *, start: int = 0,
            enc_out: Optional[jnp.ndarray] = None):
    """Prompt pass filling the cache.  Returns (x, new_cache)."""
    if cfg.family == "ssm":
        h = rmsnorm(x, p["ssm_norm"], cfg.norm_eps)
        delta, new_cache = ssm_mod.forward(p["ssm"], h, cfg,
                                           conv_tail=cache["conv"],
                                           return_state=True)
        # accumulate prior state: forward starts from zeros, so fold in decay?
        # prefill is always from start=0 for SSM cells; assert for clarity.
        x = residual_add(x, delta.astype(x.dtype))
        return x, new_cache
    if cfg.family == "hybrid":
        h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        a, attn_cache = attn.prefill(p["attn"], h, cfg, cache["attn"], start=start)
        s, ssm_cache = ssm_mod.forward(p["ssm"], h, cfg,
                                       conv_tail=cache["ssm"]["conv"],
                                       return_state=True)
        delta = 0.5 * (rmsnorm(a, p["attn_gain"], cfg.norm_eps)
                       + rmsnorm(s, p["ssm_gain"], cfg.norm_eps))
        x = residual_add(x, delta.astype(x.dtype))
        new_cache = {"attn": attn_cache, "ssm": ssm_cache}
    else:
        h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        self_cache = cache["self"] if "cross" in p else cache
        a, self_cache = attn.prefill(p["attn"], h, cfg, self_cache, start=start)
        x = residual_add(x, a.astype(x.dtype))
        if "cross" in p:
            assert enc_out is not None
            cross_kv = attn.encode_kv(p["cross"], enc_out, cfg)
            h = rmsnorm(x, p["cross_norm"], cfg.norm_eps)
            x = residual_add(x, attn.cross_attend(p["cross"], h, cross_kv, cfg).astype(x.dtype))
            new_cache = {"self": self_cache,
                         "cross": {k: v.astype(cfg.activation_dtype)
                                   for k, v in cross_kv.items()}}
        else:
            new_cache = self_cache
    delta, _ = _ffn(p, x, cfg)
    if delta is not None:
        x = residual_add(x, delta.astype(x.dtype))
    return x, new_cache


def decode(p, x: jnp.ndarray, cfg: ModelConfig, cache, pos):
    """One-token step.  Returns (x, new_cache)."""
    if cfg.family == "ssm":
        h = rmsnorm(x, p["ssm_norm"], cfg.norm_eps)
        delta, new_cache = ssm_mod.decode_step(p["ssm"], h, cfg, cache)
        return residual_add(x, delta.astype(x.dtype)), new_cache
    if cfg.family == "hybrid":
        h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        a, attn_cache = attn.decode_step(p["attn"], h, cfg, cache["attn"], pos)
        s, ssm_cache = ssm_mod.decode_step(p["ssm"], h, cfg, cache["ssm"])
        delta = 0.5 * (rmsnorm(a, p["attn_gain"], cfg.norm_eps)
                       + rmsnorm(s, p["ssm_gain"], cfg.norm_eps))
        x = residual_add(x, delta.astype(x.dtype))
        new_cache = {"attn": attn_cache, "ssm": ssm_cache}
    else:
        h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        self_cache = cache["self"] if "cross" in p else cache
        a, self_cache = attn.decode_step(p["attn"], h, cfg, self_cache, pos)
        x = residual_add(x, a.astype(x.dtype))
        if "cross" in p:
            h = rmsnorm(x, p["cross_norm"], cfg.norm_eps)
            x = residual_add(x, attn.cross_attend(p["cross"], h, cache["cross"], cfg).astype(x.dtype))
            new_cache = {"self": self_cache, "cross": cache["cross"]}
        else:
            new_cache = self_cache
    delta, _ = _ffn(p, x, cfg)
    if delta is not None:
        x = residual_add(x, delta.astype(x.dtype))
    return x, new_cache
