"""Mamba-2 (SSD, state-space duality) block -- arXiv:2405.21060.

The SSD chunked algorithm is itself a statement of the paper's thesis: the
recurrence is evaluated as *blocked matrix algebra* (intra-chunk quadratic
attention-like matmuls + an inter-chunk recurrence on compressed states),
so the hot loop is again the paper's matmul primitive streaming through
VMEM-sized tiles.

Layout: d_inner = expand * d_model, heads h = d_inner / headdim, single
B/C group (G=1), state size n = cfg.ssm_state.

Cache (decode): per layer
    state (B, h, p, n)  -- the SSM state
    conv  (B, w-1, di+2n) -- causal-conv tail
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.kernels import dispatch
from repro.kernels.rmsnorm import ref as rmsnorm_ref
from repro.kernels.ssd import ssd_intra
from repro.models.config import ModelConfig


def init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di, n, h, w = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv_width
    ks = jax.random.split(key, 5)
    proj_out = 2 * di + 2 * n + h          # z, xBC, dt
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_out), jnp.float32)
                    * d ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (w, di + 2 * n), jnp.float32)
                   * w ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * n,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_gain": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (di, d), jnp.float32)
                     * di ** -0.5).astype(dtype),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: jnp.ndarray | None = None):
    """Depthwise causal conv over (B, S, Ch); tail (B, w-1, Ch) prepends
    history for prefill continuation.  Returns (out, new_tail)."""
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    xfull = jnp.concatenate([tail.astype(xbc.dtype), xbc], axis=1)
    out = sum(xfull[:, i:i + xbc.shape[1]] * w[i][None, None]
              for i in range(width))
    new_tail = xfull[:, -(width - 1):] if width > 1 else tail
    return jax.nn.silu(out + b[None, None].astype(out.dtype)), new_tail


def _split(proj, cfg: ModelConfig):
    di, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n:]
    return z, xbc, dt


def _ssd_chunked(x, dt, a_coef, b_in, c_in, chunk: int):
    """SSD as chunk-parallel matrix algebra + associative scan over chunks.

    x (B,S,h,p), dt (B,S,h), a_coef = dt*A (B,S,h) negative, b_in/c_in
    (B,S,n).  Returns y (B,S,h,p) and final state (B,h,p,n).

    Layout (beyond-paper, EXPERIMENTS.md section Perf): every per-chunk
    quantity carries an explicit (B, nc, ...) layout with the CHUNK dim
    sharded over "model" (sequence parallelism for the SSM branch -- heads
    often do not divide the model axis); the only sequential piece is a
    log-depth associative scan over the tiny per-chunk states.  Big dot
    inputs are bf16 with fp32 accumulation.
    """
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    cdtype = x.dtype if x.dtype == jnp.bfloat16 else jnp.float32
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a_coef = jnp.pad(a_coef, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc, lc = sp // chunk, chunk

    def r(t, trailing):  # (B, S, ...) -> (B, nc, lc, ...), nc sharded
        out = t.reshape(bsz, nc, lc, *trailing)
        return constrain(out, "batch", "model", *(None,) * (out.ndim - 2))

    xc = r(x, (h, p))
    dtc = r(dt.astype(jnp.float32), (h,))
    ac = r(a_coef.astype(jnp.float32), (h,))
    bc = r(b_in, (n,))
    cc = r(c_in, (n,))
    cum = jnp.cumsum(ac, axis=2)                           # (B, nc, lc, h)
    xdt = (xc.astype(jnp.float32) * dtc[..., None]).astype(cdtype)

    if dispatch.resolve() in ("pallas", "interpret"):
        # fused VMEM-resident intra-chunk kernel (kernels/ssd): the
        # (lc x lc x h) att/decay tensors never touch HBM
        y_flat, s_flat = ssd_intra(
            xdt.reshape(bsz * nc, lc, h, p), bc.reshape(bsz * nc, lc, n),
            cc.reshape(bsz * nc, lc, n), cum.reshape(bsz * nc, lc, h))
        y_intra = y_flat.reshape(bsz, nc, lc, h, p)
        s_c = s_flat.reshape(bsz, nc, h, p, n)
        last = cum[:, :, -1:, :]
    else:
        # -- intra-chunk (parallel over chunks), XLA path --------------------
        # decay exponent masked BEFORE exp: for j > i it is positive and can
        # overflow; a post-hoc where() would leak inf*0 = NaN into backward.
        gbc = jax.lax.dot_general(
            cc.astype(cdtype), bc.astype(cdtype),
            (((3,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)            # (B, nc, i, j)
        diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]
        ii = jnp.arange(lc)
        mask = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
        decay = jnp.exp(jnp.where(mask, diff, -jnp.inf))
        att = (gbc[..., None] * decay).astype(cdtype)      # (B, nc, i, j, h)
        y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xdt,
                             preferred_element_type=jnp.float32)

        # per-chunk compressed state contribution
        last = cum[:, :, -1:, :]                           # (B, nc, 1, h)
        sdecay = jnp.exp(last - cum)                       # (B, nc, lc, h)
        w = (xdt.astype(jnp.float32) * sdecay[..., None]).astype(cdtype)
        s_c = jnp.einsum("bcjhp,bcjn->bchpn", w, bc.astype(cdtype),
                         preferred_element_type=jnp.float32)

    # -- inter-chunk: log-depth associative scan over chunk states -----------
    decays = jnp.exp(last[:, :, 0])[..., None, None]       # (B, nc, h, 1, 1)

    def comb(l, rgt):
        dl, sl = l
        dr, sr = rgt
        return dl * dr, sl * dr + sr

    dacc, states = jax.lax.associative_scan(comb, (decays, s_c), axis=1)
    del dacc
    state_prev = jnp.concatenate(
        [jnp.zeros_like(states[:, :1]), states[:, :-1]], axis=1)

    y_inter = jnp.einsum("bcin,bchpn->bcihp", cc.astype(jnp.float32),
                         state_prev) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(bsz, sp, h, p)[:, :s]
    return y, states[:, -1]


def forward(params, x: jnp.ndarray, cfg: ModelConfig, *,
            conv_tail=None, return_state: bool = False):
    """Full-sequence SSD pass; x (B, S, d) -> y (B, S, d)."""
    # SSD channels cannot shard over "model" (the (heads x headdim)
    # interleaved layout breaks after the (B,S,di)->(B,S,h,p) reshape), so
    # the SSM branch shards the SEQUENCE dim instead: in_proj/conv compute
    # S/16 per device (conv gets its 3-token halo from XLA), matching the
    # chunk-parallel SSD core below.
    x = constrain(x, "batch", "model", None)
    proj = constrain(x @ params["in_proj"], "batch", "model", None)
    z, xbc, dt_raw = _split(proj, cfg)
    xbc, new_tail = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_tail)
    xbc = constrain(xbc, "batch", "model", None)
    di, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_headdim
    xs = xbc[..., :di].reshape(*xbc.shape[:2], h, p)
    b_in = xbc[..., di:di + n]
    c_in = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"][None, None])
    a = -jnp.exp(params["A_log"])[None, None]              # (1,1,h)
    y, state = _ssd_chunked(xs, dt, dt * a, b_in, c_in, cfg.ssm_chunk)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], di)
    y = rmsnorm_ref.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)),
                            params["norm_gain"], cfg.norm_eps)
    out = y.astype(x.dtype) @ params["out_proj"]
    if return_state:
        return out, {"state": state, "conv": new_tail}
    return out


def init_cache(cfg: ModelConfig, batch: int):
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim,
                            cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1,
                           cfg.ssm_inner + 2 * cfg.ssm_state),
                          cfg.activation_dtype),
    }


def decode_step(params, x: jnp.ndarray, cfg: ModelConfig, cache: dict):
    """One token x (B, 1, d) against recurrent state."""
    proj = x @ params["in_proj"]
    z, xbc, dt_raw = _split(proj, cfg)
    # conv via explicit tail
    w = params["conv_w"]
    xfull = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", xfull[:, -w.shape[0]:], w)
    xbc1 = jax.nn.silu(conv_out + params["conv_b"][None].astype(conv_out.dtype))
    new_conv = xfull[:, 1:] if w.shape[0] > 1 else cache["conv"]

    di, n, h, p = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    xs = xbc1[..., :di].reshape(-1, h, p).astype(jnp.float32)
    b_in = xbc1[..., di:di + n].astype(jnp.float32)
    c_in = xbc1[..., di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) +
                         params["dt_bias"][None])           # (B, h)
    a = -jnp.exp(params["A_log"])[None]                     # (1, h)
    decay = jnp.exp(dt * a)                                 # (B, h)
    state = cache["state"] * decay[:, :, None, None] + \
        jnp.einsum("bhp,bn,bh->bhpn", xs, b_in, dt)
    y = jnp.einsum("bn,bhpn->bhp", c_in, state) + \
        params["D"][None, :, None] * xs
    y = y.reshape(-1, 1, di)
    y = rmsnorm_ref.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)),
                            params["norm_gain"], cfg.norm_eps)
    out = y.astype(x.dtype) @ params["out_proj"]
    return out, {"state": state, "conv": new_conv}
