"""Deterministic span-tree tracing for the serving stack.

The paper is a *performance analysis*: its contribution is stage-by-stage
accounting of where cycles and bytes go.  This module gives the
reproduction the same discipline at serving scale -- every request
lifecycle stage (validate -> admission -> queue wait -> bucket assembly
-> pack -> launch attempts -> recovery rungs -> unpack -> resolution)
emits a span into one flat, append-only event stream from which
per-request trees, per-bucket timelines, and exact CI-gateable counts
are all reconstructable.

Design rules (each one is load-bearing):

  * **Injectable clock.**  A ``Tracer`` reads time only through the
    object passed as ``clock=`` -- any ``serving.clock.Clock`` duck
    (``.now() -> float``).  Under a ``serving.clock.VirtualClock`` every
    timestamp, duration, and therefore the entire exported Chrome trace
    is a bit-deterministic function of the seeded workload: two runs
    produce byte-identical JSON, which is what lets CI gate span counts
    EXACTLY (the obs-smoke lane does).  The default is the process
    monotonic clock for real traffic.
  * **Flat stream, reconstructable trees.**  Spans append to one list in
    deterministic id order; parentage comes from a begin/end stack.
    ``span_tree(ticket)`` rebuilds a request's tree after the fact by
    collecting every span tagged with its ticket (``ticket=`` for
    request-scoped spans, ``tickets=`` for bucket-scoped ones whose
    launch covers many requests) and re-nesting by the nearest collected
    ancestor.  Nothing is indexed eagerly -- tracing cost on the hot
    path is one append.
  * **Near-zero cost when off.**  The module-level active tracer
    defaults to a ``NullTracer`` whose ``enabled`` is False; every
    instrumentation hook in the engine guards with a single
    ``if trc.enabled:`` branch, so a disabled build pays one attribute
    load + one branch per hook and allocates nothing.  The acceptance
    contract (pinned by ``tests/test_obs.py`` and the soak benchmark's
    overhead row) is that counters with tracing disabled are
    bit-identical to a build that never imported this module.
  * **Flight recording.**  A tracer may carry a ``recorder`` sink
    (``obs.recorder.FlightRecorder``); every finished span is offered to
    it, so the last-N-events window is always current when a
    ``LaunchError`` post-mortem wants a snapshot.

This module deliberately imports nothing from ``repro.serving`` (the
engine imports *us*; a clock import back into the package would cycle).
Clock compatibility is duck-typed on ``.now()``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import typing


@dataclasses.dataclass
class Span:
    """One event in the flat stream.  ``t1 is None`` while open;
    ``instant`` marks zero-extent events (``ph: "i"`` in the Chrome
    export).  ``ticket`` tags request-scoped spans; ``tickets`` tags
    bucket/launch-scoped spans covering many requests; ``track`` names
    the export timeline (one per plan bucket, one per recovery ladder)."""
    __slots__ = ("sid", "parent", "name", "t0", "t1", "ticket", "tickets",
                 "track", "instant", "attrs")
    sid: int
    parent: int | None
    name: str
    t0: float
    t1: float | None
    ticket: int | None
    tickets: tuple
    track: str | None
    instant: bool
    attrs: dict

    @property
    def duration(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0

    def as_dict(self) -> dict:
        """A plain-JSON event record (deterministic key order)."""
        d = {"sid": self.sid, "parent": self.parent, "name": self.name,
             "t0": self.t0, "t1": self.t1}
        if self.ticket is not None:
            d["ticket"] = self.ticket
        if self.tickets:
            d["tickets"] = list(self.tickets)
        if self.track is not None:
            d["track"] = self.track
        if self.instant:
            d["instant"] = True
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        return d


@dataclasses.dataclass
class SpanNode:
    """One node of a reconstructed per-request tree."""
    span: Span
    children: list["SpanNode"] = dataclasses.field(default_factory=list)

    @property
    def name(self) -> str:
        return self.span.name

    def walk(self) -> typing.Iterator[Span]:
        yield self.span
        for c in self.children:
            yield from c.walk()


class NullTracer:
    """The disabled default: every hook sees ``enabled == False`` and
    skips its span emission behind one branch.  The methods still exist
    (as no-ops) so non-hot-path call sites may skip the guard."""

    enabled = False
    recorder = None
    spans: tuple = ()

    def begin(self, name: str, **kw) -> int:
        return -1

    def end(self, sid: int, **kw) -> None:
        pass

    def instant(self, name: str, **kw) -> None:
        pass

    def complete(self, name: str, t0: float, t1: float, **kw) -> None:
        pass

    @contextlib.contextmanager
    def span(self, name: str, **kw):
        yield -1


class Tracer:
    """The live tracer: a flat append-only span stream with stack-based
    parenting and sequential ids.

        trc = Tracer(clock=VirtualClock())
        sid = trc.begin("flush")
        trc.instant("launch", tickets=(0, 1), backend="ref")
        trc.end(sid, buckets=2)
        trc.span_tree(0)     # -> [SpanNode, ...] roots for ticket 0

    ``begin``/``end`` nest via an explicit stack (the engine's phases are
    strictly nested, so a stack is sufficient and allocation-free);
    ``complete`` records a retroactive span (queue-wait spans are known
    only once the wait is over); ``instant`` records a zero-extent event.
    Keyword arguments become span attributes except the reserved
    ``ticket`` / ``tickets`` / ``track`` tags."""

    enabled = True

    def __init__(self, clock=None, recorder=None):
        #: any ``.now() -> float`` duck; serving.clock.Clock instances
        #: qualify, and a VirtualClock makes the stream deterministic
        self.clock = clock
        self._now = clock.now if clock is not None else time.monotonic
        #: optional FlightRecorder sink offered every finished span
        self.recorder = recorder
        self.spans: list[Span] = []
        self._stack: list[int] = []

    # -- emission ------------------------------------------------------------

    def _push(self, name: str, t0: float, t1: float | None, instant: bool,
              ticket, tickets, track, attrs: dict) -> Span:
        s = Span(sid=len(self.spans),
                 parent=self._stack[-1] if self._stack else None,
                 name=name, t0=t0, t1=t1, ticket=ticket,
                 tickets=tuple(tickets) if tickets else (),
                 track=track, instant=instant, attrs=attrs)
        self.spans.append(s)
        if t1 is not None and self.recorder is not None:
            self.recorder.record(s)
        return s

    def begin(self, name: str, *, ticket=None, tickets=(), track=None,
              **attrs) -> int:
        """Open a span; returns its id for the matching ``end``."""
        s = self._push(name, self._now(), None, False,
                       ticket, tickets, track, attrs)
        self._stack.append(s.sid)
        return s.sid

    def end(self, sid: int, *, ticket=None, **attrs) -> None:
        """Close span ``sid``; late keyword arguments merge into its
        attributes (outcomes are usually known only at the end), and a
        late ``ticket=`` tags a span whose request id was assigned after
        it opened (the async submit span)."""
        s = self.spans[sid]
        s.t1 = self._now()
        if attrs:
            s.attrs.update(attrs)
        if ticket is not None:
            s.ticket = ticket
        # the engine's phases close in strict LIFO order; tolerate an
        # out-of-order close (exception unwind paths) by popping through
        while self._stack and self._stack[-1] != sid:
            self._stack.pop()
        if self._stack:
            self._stack.pop()
        if self.recorder is not None:
            self.recorder.record(s)

    def instant(self, name: str, *, ticket=None, tickets=(), track=None,
                **attrs) -> None:
        """A zero-extent event at now (launch dispatches, policy
        decisions, resolutions)."""
        t = self._now()
        self._push(name, t, t, True, ticket, tickets, track, attrs)

    def complete(self, name: str, t0: float, t1: float, *, ticket=None,
                 tickets=(), track=None, **attrs) -> None:
        """A retroactive span over ``[t0, t1]`` (queue waits: the span is
        only known once the wait ends)."""
        self._push(name, t0, t1, False, ticket, tickets, track, attrs)

    @contextlib.contextmanager
    def span(self, name: str, **kw):
        """``with trc.span("flush"):`` -- begin/end with unwind safety."""
        sid = self.begin(name, **kw)
        try:
            yield sid
        finally:
            if self.spans[sid].t1 is None:
                self.end(sid)

    # -- derived views -------------------------------------------------------

    @property
    def n_events(self) -> int:
        """Every emitted record, instants included."""
        return len(self.spans)

    @property
    def n_spans(self) -> int:
        """Extent-carrying spans only (instants excluded)."""
        return sum(1 for s in self.spans if not s.instant)

    def count(self, name: str) -> int:
        return sum(1 for s in self.spans if s.name == name)

    def tickets_seen(self) -> list[int]:
        seen: set[int] = set()
        for s in self.spans:
            if s.ticket is not None:
                seen.add(s.ticket)
            seen.update(s.tickets)
        return sorted(seen)

    def spans_for(self, ticket: int) -> list[Span]:
        """Every span touching this ticket, in stream (= time) order."""
        return [s for s in self.spans
                if s.ticket == ticket or ticket in s.tickets]

    def span_tree(self, ticket: int) -> list[SpanNode]:
        """Reconstruct the request's tree from the flat stream: collect
        its spans, then nest each under its nearest collected ancestor
        (spans of OTHER requests in between -- a shared flush span's
        other buckets -- drop out, so the tree is this request's view).
        Returns the roots (submission and flush epochs are disjoint, so
        one request usually has 2-3 roots: validate, queue wait, and its
        flush-side spans)."""
        mine = self.spans_for(ticket)
        by_sid = {s.sid: s for s in mine}
        nodes = {s.sid: SpanNode(s) for s in mine}
        roots: list[SpanNode] = []
        for s in mine:
            p = s.parent
            while p is not None and p not in by_sid:
                p = self.spans[p].parent
            if p is None:
                roots.append(nodes[s.sid])
            else:
                nodes[p].children.append(nodes[s.sid])
        return roots


# -- the ambient tracer -------------------------------------------------------

_NULL = NullTracer()
_ACTIVE: NullTracer | Tracer = _NULL


def active() -> NullTracer | Tracer:
    """The ambient tracer every instrumentation hook consults.  Defaults
    to the shared ``NullTracer`` (one branch per hook, zero allocation)."""
    return _ACTIVE


def install(tracer: Tracer | None) -> None:
    """Install (or, with ``None``, uninstall) the ambient tracer."""
    global _ACTIVE
    _ACTIVE = tracer if tracer is not None else _NULL


@contextlib.contextmanager
def installed(tracer: Tracer | None):
    """Scoped install: the previous ambient tracer is restored on exit
    (benchmarks trace one soak without leaking into the next)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer if tracer is not None else _NULL
    try:
        yield tracer
    finally:
        _ACTIVE = prev
