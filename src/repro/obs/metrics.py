"""Typed metrics registry: counters, gauges, histograms, and the
back-compat dict views that replace the serving stack's ad-hoc ``stats``
dicts.

Before this module the repo's runtime telemetry was four disjoint
conventions: a module-level dict in ``serving.engine``, plain int
attributes on ``AdmissionController``, private lists on
``AsyncGeometryServer``, and ``BucketReport`` dataclasses.  The registry
unifies them behind three typed instrument kinds:

  * ``Counter`` -- monotone event counts (launches, retries, rejections).
  * ``Gauge``   -- point-in-time levels (queue depth, high-water marks).
  * ``Histogram`` -- sample distributions (request latency) whose
    quantiles come from the repo's ONE nearest-rank ``percentile``
    definition (defined here; ``serving.clock`` re-exports it), so
    hand-pinned test values, engine telemetry, benchmark rows, and the
    Prometheus exposition cannot disagree about what "p99" means.

Instruments live in families keyed by name; a family declared with
``labels=(...)`` fans out into children per label-value combination
(tenant, plan kind, backend, dtype/qformat, size class -- the serving
dimensions), reachable via ``family.labels(tenant="render")``.  Every
value is readable back (``registry.value(name, **labels)``), dumpable
(``as_dict``) and resettable -- determinism under seeded workloads is
preserved because instruments hold plain Python numbers, never wall
time.

``StatsView`` is the compatibility shim: a ``MutableMapping`` facade
over a fixed key set of counters so the module-level ``serving.stats``
dict -- read, iterated, compared, ``+=``-incremented and zeroed by
every existing test, benchmark, and example -- keeps its exact dict
semantics while the storage moves into the registry.
"""
from __future__ import annotations

import math
from collections.abc import MutableMapping


def percentile(values, q: float) -> float:
    """Nearest-rank percentile: the smallest element with at least
    ``q``% of the sample at or below it (``sorted[ceil(q/100 * n)]``,
    1-indexed).  Exact set membership -- p50 of [1, 2, 3, 4] is 2, p99
    is 4 -- which is what makes hand-pinned telemetry tests possible;
    interpolating estimators would make every pinned value a float
    artifact of the interpolation rule.  Returns ``nan`` on an empty
    sample."""
    if not 0 <= q <= 100:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    xs = sorted(values)
    if not xs:
        return math.nan
    if q == 0:
        return xs[0]
    rank = math.ceil(q / 100.0 * len(xs))
    return xs[rank - 1]


class Counter:
    """A monotone-by-convention event count.  ``set`` exists for the
    back-compat dict view (tests zero counters by assignment) and for
    absolute mirrors of an external source of truth."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def set(self, v) -> None:
        self.value = v


class Gauge:
    """A point-in-time level; ``track_max`` keeps high-water marks."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def track_max(self, v) -> None:
        if v > self.value:
            self.value = v


class Histogram:
    """A sample distribution: stores the samples (the serving stack's
    populations are bounded by the soak sizes) and answers count / sum /
    max / nearest-rank quantiles.  Prometheus exposition renders it as a
    real cumulative histogram (``_bucket{le=...}`` series over
    ``BOUNDS`` plus ``_sum``/``_count``); bucket counts are integers
    over fixed bounds, so the exposition stays byte-deterministic under
    seeded workloads."""

    __slots__ = ("samples",)

    QUANTILES = (50.0, 99.0)

    #: cumulative upper bounds for the Prometheus ``_bucket`` series
    #: (seconds -- the serving stack's histograms are latencies); the
    #: ``+Inf`` bucket is implicit in the exposition
    BOUNDS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
              0.5, 1.0, 2.5)

    def __init__(self):
        self.samples: list[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(v)

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return sum(self.samples)

    @property
    def max(self) -> float:
        return max(self.samples) if self.samples else 0.0

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)

    def bucket_counts(self, bounds=None) -> list[int]:
        """Cumulative counts at each upper bound (samples <= bound); the
        implicit ``+Inf`` bucket is ``count``, appended by the exporter."""
        bs = self.BOUNDS if bounds is None else bounds
        return [sum(1 for s in self.samples if s <= b) for b in bs]


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """All instruments sharing one name: the unlabeled default child
    and/or one child per label-value combination."""

    __slots__ = ("name", "kind", "help", "labelnames", "children")

    def __init__(self, name: str, kind: str, help: str = "",
                 labelnames: tuple = ()):
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self.children: dict[tuple, object] = {}

    def labels(self, **kv):
        """The child instrument for this label-value combination
        (created on first use).  Values stringify -- size classes are
        ints at the call site, label values in the exposition."""
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} expects labels {self.labelnames}, "
                f"got {tuple(sorted(kv))}")
        key = tuple(str(kv[ln]) for ln in self.labelnames)
        child = self.children.get(key)
        if child is None:
            child = self.children[key] = _KINDS[self.kind]()
        return child

    def default(self):
        """The unlabeled instrument (only valid without labelnames)."""
        if self.labelnames:
            raise ValueError(
                f"metric {self.name!r} is labeled by {self.labelnames}; "
                "use .labels(...)")
        return self.labels()


class MetricsRegistry:
    """One scope's instruments (the process-global serving aggregate, or
    one server's own registry), keyed by name in declaration order.

        m = MetricsRegistry("serving")
        m.counter("launches").inc()
        m.counter("requests", labels=("tenant",)).labels(tenant="a").inc()
        m.value("launches")                 # -> 1
        obs.export.prometheus_text(m)       # exposition

    Declaring the same name twice returns the same family (and checks
    the kind/labels agree), so modules can declare lazily at use sites.
    """

    def __init__(self, namespace: str = ""):
        self.namespace = namespace
        self.families: dict[str, _Family] = {}

    def _family(self, name: str, kind: str, help: str,
                labels: tuple) -> _Family:
        fam = self.families.get(name)
        if fam is None:
            fam = self.families[name] = _Family(name, kind, help,
                                                tuple(labels))
        elif fam.kind != kind or fam.labelnames != tuple(labels):
            raise ValueError(
                f"metric {name!r} re-declared as {kind}{tuple(labels)} "
                f"(was {fam.kind}{fam.labelnames})")
        if help and not fam.help:
            fam.help = help
        return fam

    def counter(self, name: str, help: str = "", labels: tuple = ()):
        fam = self._family(name, "counter", help, labels)
        return fam if labels else fam.default()

    def gauge(self, name: str, help: str = "", labels: tuple = ()):
        fam = self._family(name, "gauge", help, labels)
        return fam if labels else fam.default()

    def histogram(self, name: str, help: str = "", labels: tuple = ()):
        fam = self._family(name, "histogram", help, labels)
        return fam if labels else fam.default()

    # -- read side -----------------------------------------------------------

    def value(self, name: str, **labels):
        """The numeric value of a counter/gauge (0 for a never-touched
        name -- reading must not create state the exposition then shows)."""
        fam = self.families.get(name)
        if fam is None:
            return 0
        key = tuple(str(labels[ln]) for ln in fam.labelnames) \
            if labels or fam.labelnames else ()
        child = fam.children.get(key)
        return 0 if child is None else child.value

    def as_dict(self) -> dict:
        """Unlabeled counter/gauge values by name (the debugging dump;
        labeled children and histograms have richer dedicated reads)."""
        out = {}
        for name, fam in self.families.items():
            if fam.kind == "histogram" or fam.labelnames:
                continue
            child = fam.children.get(())
            out[name] = 0 if child is None else child.value
        return out

    def reset(self) -> None:
        """Zero every instrument in place (families and label children
        survive, so held instrument references stay live)."""
        for fam in self.families.values():
            for child in fam.children.values():
                if isinstance(child, Histogram):
                    child.samples.clear()
                else:
                    child.value = 0


class StatsView(MutableMapping):
    """The back-compat dict facade: a fixed key set of counters in a
    registry, behaving exactly like the plain dict it replaces --
    ``stats["launches"] += 1``, ``for k in stats``, ``dict(stats)``,
    ``stats == {...}``, ``stats[k] = 0`` all work unchanged.  The key
    set is CLOSED: an unknown key raises ``KeyError`` like the old dict
    (typos in counter names must not mint new counters silently)."""

    __slots__ = ("_registry", "_counters")

    def __init__(self, registry: MetricsRegistry, keys: tuple,
                 help_by_key: dict | None = None):
        self._registry = registry
        helps = help_by_key or {}
        self._counters = {k: registry.counter(k, help=helps.get(k, ""))
                          for k in keys}

    def __getitem__(self, key: str):
        return self._counters[key].value

    def __setitem__(self, key: str, value) -> None:
        if key not in self._counters:
            raise KeyError(key)
        self._counters[key].set(value)

    def __delitem__(self, key: str) -> None:
        raise TypeError("StatsView keys are fixed; counters cannot be "
                        "deleted")

    def __iter__(self):
        return iter(self._counters)

    def __len__(self) -> int:
        return len(self._counters)

    def __contains__(self, key) -> bool:
        return key in self._counters

    def __repr__(self) -> str:
        return f"StatsView({dict(self)!r})"
