"""Bench-trajectory analytics: the committed ``BENCH_*.json`` files as a
time series.

Every PR commits a ``benchmarks/BENCH_<timestamp>.json`` record, and CI's
exact-match gate (``tools/check_bench.py``) pins a fresh run against the
LATEST one.  That gate is blind to one whole class of regression: a PR
that makes a counter worse AND commits the worse value -- the fresh run
matches the new record exactly, so the gate passes while the trajectory
degrades.  This module closes that hole by reading the committed files
as a history and checking DIRECTION across consecutive records: for
counters where lower is strictly better (launches, padded bytes, lost
requests, failures), a later record may equal or improve on its
predecessor for the same row, never worsen.  ``tools/bench_trend.py`` is
the CLI gate; it exits nonzero on any such drift.

The comparison is name-matched per row over the intersection of
consecutive record pairs, exactly like the exact-match gate -- a row
that appears, disappears, or is renamed is not a regression (new
benchmarks arrive every PR), only a shared row whose directional counter
moved the wrong way is.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import typing

#: derived fields where a LARGER value in a later committed record for
#: the SAME row name is a genuine regression: the launch economy
#: (launches / shards / padded traffic / bytes moved), the padding
#: waste ratio, and the never-acceptable loss counters.  Deliberately
#: absent: admission rejections (queue_full / rate_limited shed load BY
#: DESIGN), recovery counters driven by injected fault schedules
#: (retries / bisections follow the injector seed, not code quality),
#: and every wall-clock field (noise).
LOWER_IS_BETTER = frozenset({
    "launches", "shards", "padded_points", "hbm_bytes",
    "padding_waste", "extra_launches",
    "lost", "mismatches", "failed_requests", "launch_failures",
    # scene-graph fold economy (scene_* rows): fold work creeping up for
    # the same animated edit schedule means the CSE cache or the dirty
    # propagation regressed (cse_hits is deliberately absent: it is
    # exact-gated, and "more hits" is not monotonically good)
    "folds", "folds_per_frame", "refolds", "dirtied",
})


@dataclasses.dataclass(frozen=True)
class BenchRecord:
    """One committed benchmark record."""
    path: str
    timestamp: str
    smoke: bool
    rows: dict[str, dict]

    @property
    def name(self) -> str:
        return os.path.basename(self.path)


@dataclasses.dataclass(frozen=True)
class Regression:
    """One directional drift: ``row.field`` worsened between two
    consecutive committed records."""
    row: str
    field: str
    prev_record: str
    record: str
    prev: typing.Any
    value: typing.Any

    def __str__(self) -> str:
        return (f"{self.row}: {self.field} worsened {self.prev!r} -> "
                f"{self.value!r} ({self.prev_record} -> {self.record})")


def load_history(bench_dir: str) -> list[BenchRecord]:
    """Every committed ``BENCH_*.json`` in filename (= timestamp) order."""
    records = []
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        with open(path) as f:
            doc = json.load(f)
        records.append(BenchRecord(
            path=path, timestamp=doc.get("timestamp", ""),
            smoke=bool(doc.get("smoke", False)),
            rows={row["name"]: row for row in doc.get("rows", [])}))
    return records


def series(history: typing.Sequence[BenchRecord], row: str,
           field: str) -> list[tuple[str, typing.Any]]:
    """One counter's trajectory: ``(record name, value)`` for every
    record that carries the row and field."""
    out = []
    for rec in history:
        r = rec.rows.get(row)
        if r is not None and field in r:
            out.append((rec.name, r[field]))
    return out


def _comparable(a, b) -> bool:
    return isinstance(a, (int, float)) and isinstance(b, (int, float)) \
        and not isinstance(a, bool) and not isinstance(b, bool)


def find_regressions(
        history: typing.Sequence[BenchRecord],
        fields: frozenset = LOWER_IS_BETTER) -> list[Regression]:
    """Directional drift across every consecutive record pair: for each
    shared row, each lower-is-better field present in both must not
    increase.  Equal is fine (the common case: deterministic counters
    repeat exactly); smaller is an improvement."""
    out = []
    for prev, cur in zip(history, history[1:]):
        for name in sorted(set(prev.rows) & set(cur.rows)):
            p_row, c_row = prev.rows[name], cur.rows[name]
            for field in sorted(fields & set(p_row) & set(c_row)):
                p, c = p_row[field], c_row[field]
                if _comparable(p, c) and c > p:
                    out.append(Regression(
                        row=name, field=field, prev_record=prev.name,
                        record=cur.name, prev=p, value=c))
    return out


def drift_report(history: typing.Sequence[BenchRecord]) -> str:
    """Markdown summary of the trajectory: record inventory, then the
    per-counter drift (first -> last value over the records sharing the
    row) for every directional field, improvements flagged."""
    lines = ["# Bench trajectory", "",
             f"{len(history)} committed records:", ""]
    for rec in history:
        lines.append(f"- `{rec.name}` (smoke={rec.smoke}, "
                     f"{len(rec.rows)} rows)")
    lines += ["", "## Directional counters (lower is better)", "",
              "| row | field | first | last | drift |",
              "| --- | --- | ---: | ---: | --- |"]
    rows_seen: dict[tuple[str, str], None] = {}
    for rec in history:
        for name, row in rec.rows.items():
            for field in sorted(LOWER_IS_BETTER & set(row)):
                rows_seen.setdefault((name, field))
    for name, field in sorted(rows_seen):
        traj = series(history, name, field)
        if len(traj) < 2:
            continue
        (_, first), (_, last) = traj[0], traj[-1]
        if not _comparable(first, last):
            continue
        drift = "flat" if last == first else \
            ("IMPROVED" if last < first else "WORSENED")
        lines.append(f"| {name} | {field} | {first} | {last} "
                     f"| {drift} |")
    return "\n".join(lines) + "\n"
