"""Cost-model-attributed profiler: fold the span stream into attribution.

The source paper's deliverable is a *performance analysis* -- per-stage
cycle and byte accounting, predicted analytically and checked against
measurement.  This module is that deliverable at serving scale: it folds
a ``repro.obs.trace`` span stream (PR 8) into

  * an **attribution tree** -- spans grouped by their name path, with
    call counts, total wall time, and SELF wall time (total minus child
    extents), so "where does a flush spend its time" is one table;
  * **per-kernel / per-bucket / per-plan-kind launch tables** -- every
    ``launch`` instant carries its kernel, bucket track, plan kind,
    observed HBM bytes, and the cost model's dispatch-time prediction
    (``autotune.costmodel.predict_launch``: bytes / FLOPs / M1-cycle
    projection), so launches aggregate along all three axes without
    re-deriving launch shapes;
  * **model-error ratios** -- observed/predicted HBM bytes per launch.
    The byte formulas are shared between ``kernels.opcount`` (what the
    engine records) and ``costmodel.packed_chain_cost`` (what it
    predicts), so the ratio is EXACTLY 1.0 by construction and any
    drift is a real accounting bug; the profile-smoke CI lane gates
    ``byte_ratio_exact=1``.

Determinism contract: every COUNTER-valued quantity (span counts, launch
counts, bytes, predictions, ratios) is bit-deterministic under a
``serving.clock.VirtualClock`` -- ``counters()`` returns exactly those,
and the benchmark rows gate on them.  Wall-clock quantities (the time
columns of the report) are reported for humans and NEVER gated.

CLI (also reachable as ``benchmarks/run.py --profile``)::

    PYTHONPATH=src python -m repro.obs.profile --smoke
    PYTHONPATH=src python -m repro.obs.profile --spans dump.jsonl \
        --markdown report.md --chrome trace.json

``--smoke`` drives a small seeded workload through a traced
``GeometryServer`` on a virtual clock; ``--spans`` loads a raw span
stream written by ``dump_span_stream`` (the Chrome export is lossy --
it drops span ids and parent links -- so the profiler round-trips
through its own JSON-lines dump format).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import typing

from repro.obs.metrics import percentile
from repro.obs.trace import NullTracer, Span, Tracer


@dataclasses.dataclass
class ProfileNode:
    """One attribution-tree node: every span with this name path.

    ``self_s`` is ``total_s`` minus the extents of child spans -- the
    time this stage spent NOT delegating -- which is the number that
    makes a hot stage stand out even when its children are cheap."""
    name: str
    count: int = 0
    total_s: float = 0.0
    self_s: float = 0.0
    children: dict[str, "ProfileNode"] = dataclasses.field(
        default_factory=dict)

    def child(self, name: str) -> "ProfileNode":
        node = self.children.get(name)
        if node is None:
            node = self.children[name] = ProfileNode(name)
        return node

    def walk(self, depth: int = 0) -> typing.Iterator[
            tuple[int, "ProfileNode"]]:
        """Depth-first, children in first-seen (= stream) order."""
        yield depth, self
        for c in self.children.values():
            yield from c.walk(depth + 1)


@dataclasses.dataclass
class LaunchGroup:
    """Launch instants aggregated along one axis (kernel, bucket track,
    or plan kind).  All fields are deterministic counters."""
    key: str
    launches: int = 0
    rows: int = 0              # packed requests across the launches
    padded_points: int = 0     # rows * lpad, summed
    hbm_bytes: int = 0         # observed (opcount) bytes
    pred_hbm_bytes: int = 0    # cost-model bytes
    pred_flops: int = 0
    pred_m1_cycles: int = 0

    def add(self, s: Span) -> None:
        a = s.attrs
        self.launches += 1
        self.rows += a.get("rows", 0)
        self.padded_points += a.get("rows", 0) * a.get("lpad", 0)
        self.hbm_bytes += a.get("hbm_bytes", 0)
        self.pred_hbm_bytes += a.get("pred_hbm_bytes", 0)
        self.pred_flops += a.get("pred_flops", 0)
        self.pred_m1_cycles += a.get("pred_m1_cycles", 0)


def _launch_key_kernel(s: Span) -> str:
    a = s.attrs
    k = a.get("kernel")
    if k:
        return k
    # pre-prediction streams: reconstruct the kernel name from kind + q
    return f"{a.get('kind', '?')}{'_q' if a.get('q') else ''}"


class Profile:
    """A folded span stream: attribution tree + launch tables + model
    error.  Build with ``Profile.from_tracer`` (or ``from_spans`` for a
    loaded dump)."""

    def __init__(self, spans: typing.Sequence[Span]):
        self.root = ProfileNode("")          # virtual root; depth-0 spans
        self.kernels: dict[str, LaunchGroup] = {}
        self.buckets: dict[str, LaunchGroup] = {}
        self.kinds: dict[str, LaunchGroup] = {}
        #: per-launch observed/predicted HBM byte ratios, stream order
        #: (empty when the stream predates prediction attachment)
        self.byte_ratios: list[float] = []
        self.n_events = len(spans)
        self.n_spans = sum(1 for s in spans if not s.instant)
        node_of: dict[int, ProfileNode] = {}
        for s in spans:
            parent = node_of.get(s.parent) if s.parent is not None \
                else None
            node = (parent if parent is not None else self.root) \
                .child(s.name)
            node_of[s.sid] = node
            node.count += 1
            dur = s.duration
            node.total_s += dur
            node.self_s += dur
            if not s.instant and parent is not None:
                parent.self_s -= dur       # child extent is not parent self
            if s.name == "launch":
                self._fold_launch(s)

    @classmethod
    def from_tracer(cls, tracer: Tracer | NullTracer) -> "Profile":
        return cls(list(tracer.spans))

    @classmethod
    def from_spans(cls, spans: typing.Sequence[Span]) -> "Profile":
        return cls(list(spans))

    def _fold_launch(self, s: Span) -> None:
        a = s.attrs
        for table, key in (
                (self.kernels, _launch_key_kernel(s)),
                (self.buckets, s.track or "?"),
                (self.kinds,
                 f"{a.get('kind', '?')}{'_q' if a.get('q') else ''}")):
            group = table.get(key)
            if group is None:
                group = table[key] = LaunchGroup(key)
            group.add(s)
        if a.get("pred_hbm_bytes"):
            self.byte_ratios.append(a["hbm_bytes"] / a["pred_hbm_bytes"])

    # -- deterministic reads --------------------------------------------------

    @property
    def launches(self) -> int:
        return sum(g.launches for g in self.kernels.values())

    @property
    def byte_ratio_exact(self) -> bool:
        """True when every launch's observed/predicted byte ratio is
        exactly 1.0 (and at least one launch carried a prediction)."""
        return bool(self.byte_ratios) \
            and all(r == 1.0 for r in self.byte_ratios)

    def counters(self) -> dict:
        """The bit-deterministic quantities (under a virtual clock):
        what the profile benchmark rows gate on.  No wall time here."""
        return {
            "events": self.n_events,
            "spans": self.n_spans,
            "launches": self.launches,
            "kernels": len(self.kernels),
            "launch_buckets": len(self.buckets),
            "hbm_bytes": sum(g.hbm_bytes for g in self.kernels.values()),
            "pred_hbm_bytes": sum(g.pred_hbm_bytes
                                  for g in self.kernels.values()),
            "pred_flops": sum(g.pred_flops for g in self.kernels.values()),
            "pred_m1_cycles": sum(g.pred_m1_cycles
                                  for g in self.kernels.values()),
            "byte_ratio_exact": int(self.byte_ratio_exact),
        }

    # -- rendering ------------------------------------------------------------

    def render_markdown(self) -> str:
        """The human report: attribution tree, launch tables, model
        error.  Counter columns are deterministic; the wall-time columns
        are reported, never gated."""
        out = ["# Serving profile", "",
               f"{self.n_events} events ({self.n_spans} extent spans, "
               f"{self.launches} launches)", "",
               "## Attribution tree (self vs total wall time; "
               "counts are exact)", "",
               "| stage | count | total ms | self ms |",
               "| --- | ---: | ---: | ---: |"]
        for depth, node in self.root.walk():
            if node is self.root:
                continue
            pad = "&nbsp;" * 2 * (depth - 1)
            out.append(f"| {pad}{node.name} | {node.count} "
                       f"| {node.total_s * 1e3:.3f} "
                       f"| {node.self_s * 1e3:.3f} |")
        for title, table in (("kernel", self.kernels),
                             ("bucket", self.buckets),
                             ("plan kind", self.kinds)):
            out += ["", f"## Launches by {title}", "",
                    f"| {title} | launches | rows | padded pts "
                    "| HBM bytes | pred bytes | pred MFLOP "
                    "| pred M1 cycles |",
                    "| --- | ---: | ---: | ---: | ---: | ---: | ---: "
                    "| ---: |"]
            for key in sorted(table):
                g = table[key]
                out.append(
                    f"| {g.key} | {g.launches} | {g.rows} "
                    f"| {g.padded_points} | {g.hbm_bytes} "
                    f"| {g.pred_hbm_bytes} "
                    f"| {g.pred_flops / 1e6:.3f} | {g.pred_m1_cycles} |")
        out += ["", "## Model error (observed / predicted HBM bytes)", ""]
        if self.byte_ratios:
            rs = self.byte_ratios
            out += [f"- launches with predictions: {len(rs)}",
                    f"- min {min(rs):.6f} / p50 {percentile(rs, 50):.6f} "
                    f"/ p99 {percentile(rs, 99):.6f} / max {max(rs):.6f}",
                    f"- exact (every ratio == 1.0): "
                    f"{self.byte_ratio_exact}"]
        else:
            out.append("- no launches carried predictions "
                       "(pre-prediction span stream)")
        return "\n".join(out) + "\n"


# -- span-stream persistence --------------------------------------------------

def dump_span_stream(tracer: Tracer | NullTracer, path: str) -> int:
    """Write the raw span stream as JSON lines (one ``Span.as_dict`` per
    line, deterministic key order) -- the lossless dump the profiler can
    reload.  The Chrome export cannot serve here: it drops span ids and
    parent links, which the attribution tree needs.  Returns the number
    of records written."""
    with open(path, "w") as f:
        for s in tracer.spans:
            f.write(json.dumps(s.as_dict(), sort_keys=True,
                               separators=(",", ":")) + "\n")
    return len(tracer.spans)


def load_span_stream(path: str) -> list[Span]:
    """Reload a ``dump_span_stream`` file as ``Span`` records."""
    spans = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            spans.append(Span(
                sid=d["sid"], parent=d.get("parent"), name=d["name"],
                t0=d["t0"], t1=d.get("t1"), ticket=d.get("ticket"),
                tickets=tuple(d.get("tickets", ())),
                track=d.get("track"), instant=bool(d.get("instant")),
                attrs=d.get("attrs", {})))
    return spans


# -- CLI ----------------------------------------------------------------------

def profile_smoke_workload(n_requests: int = 64, *, backend: str = "ref",
                           seed: int = 17, max_points: int = 48):
    """Serve one seeded mixed-lane workload under a traced virtual
    clock, from cold plan caches; returns ``(tracer, server)``.  The
    self-driving mode of the CLI, the example, and the profile
    benchmark all run exactly this, so their counters agree."""
    # late imports: obs sits BELOW serving in the import graph; only the
    # CLI entry points reach upward
    from repro.core import transform_chain as tc
    from repro.serving import engine, workload
    from repro.serving.clock import VirtualClock
    from repro.obs import trace as obst
    engine.clear_plan_cache()
    tc.clear_plan_cache()
    clock = VirtualClock()
    tracer = Tracer(clock=clock)
    server = engine.GeometryServer(backend=backend)
    pool = workload.mixed_lane_workload(seed, n_requests,
                                        max_points=max_points)
    with obst.installed(tracer):
        for chain, pts, qname in pool:
            server.submit(chain, pts, qformat=qname)
        server.flush()
    return tracer, server


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.profile",
        description="fold a span stream into the attribution report")
    ap.add_argument("--spans", default=None, metavar="DUMP.jsonl",
                    help="profile a span stream written by "
                         "dump_span_stream")
    ap.add_argument("--smoke", action="store_true",
                    help="drive the seeded 64-request smoke workload "
                         "through a traced server and profile that")
    ap.add_argument("--markdown", default=None, metavar="OUT.md",
                    help="write the markdown report here (default: "
                         "print to stdout)")
    ap.add_argument("--chrome", default=None, metavar="OUT.json",
                    help="also export the stream as Chrome-trace JSON")
    ap.add_argument("--spans-out", default=None, metavar="OUT.jsonl",
                    help="with --smoke: dump the raw span stream")
    args = ap.parse_args(argv)
    if (args.spans is None) == (not args.smoke):
        ap.error("exactly one of --spans / --smoke is required")

    if args.smoke:
        tracer, _server = profile_smoke_workload()
        spans = list(tracer.spans)
    else:
        spans = load_span_stream(args.spans)
        tracer = None

    prof = Profile.from_spans(spans)
    report = prof.render_markdown()
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(report)
        print(f"profile: wrote {args.markdown} "
              f"({prof.launches} launches, {prof.n_events} events)")
    else:
        print(report, end="")
    if args.chrome:
        from repro.obs.export import dump_chrome_trace
        holder = tracer if tracer is not None else Tracer()
        holder.spans = spans
        dump_chrome_trace(holder, args.chrome)
        print(f"profile: wrote {args.chrome}")
    if args.spans_out:
        if tracer is None:
            ap.error("--spans-out needs --smoke (the stream came from "
                     "a dump already)")
        dump_span_stream(tracer, args.spans_out)
        print(f"profile: wrote {args.spans_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
