"""Flight recorder: a bounded ring buffer over the span stream for
post-mortems.

A full trace of a long soak is hundreds of thousands of events; what a
failure investigation actually needs is the last N events *leading into*
the failure.  The ``FlightRecorder`` is that window: attach one to a
``Tracer`` (``Tracer(recorder=...)``) and every finished span lands in a
``deque(maxlen=capacity)`` -- O(1) per event, bounded memory no matter
how long the process runs.

Consumers:

  * the serving engine snapshots the recorder into every terminal
    ``LaunchError`` resolution (``err.flight``) -- the request that
    exhausted its recovery ladder carries the event window that led
    there;
  * ``serving.faults.run_chaos_soak`` runs under a recorder-equipped
    tracer and attaches per-bucket recovery post-mortems to its
    ``ChaosReport`` (``report.postmortems``), so a chaos failure in CI
    is debuggable from the report alone.

Snapshots are lists of plain-JSON event dicts (``Span.as_dict``), cheap
to embed in error objects and reports and safe to serialize.
"""
from __future__ import annotations

import collections


class FlightRecorder:
    """Last-N-events window over a tracer's finished spans."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._buf: collections.deque = collections.deque(maxlen=capacity)
        #: total events ever offered (recorded - len(buffer) = dropped)
        self.recorded = 0

    def record(self, span) -> None:
        """Sink hook called by the tracer for every finished span."""
        self._buf.append(span)
        self.recorded += 1

    @property
    def dropped(self) -> int:
        return self.recorded - len(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    def snapshot(self) -> list[dict]:
        """The window as plain-JSON event dicts, oldest first."""
        return [s.as_dict() for s in self._buf]

    def clear(self) -> None:
        self._buf.clear()
        self.recorded = 0
