"""Exporters: Chrome-trace-event JSON (Perfetto-loadable) and Prometheus
text exposition.

The Chrome export maps the flat span stream onto the trace-event format
(one ``"X"`` complete event per extent span, one ``"i"`` instant event
per instant), with one *track* (tid) per span ``track`` tag -- the
engine tags bucket-scoped spans with their bucket signature and recovery
spans with ``recovery:<bucket>``, so Perfetto renders one timeline per
plan bucket plus one per recovery ladder, with request-scoped spans on
the main track.  Timestamps are clock seconds scaled to the format's
microseconds; under a ``VirtualClock`` they are exact rationals of the
seed, so the serialized file is byte-identical across runs -- the
obs-smoke CI lane diffs two independent runs and the committed trace.

The Prometheus exposition is the standard text format, families sorted
by name and label sets sorted by value tuple, so the output is also
deterministic and snapshot-gateable.  Histograms render as real
cumulative histograms -- ``_bucket{le="<bound>"}`` series over
``Histogram.BOUNDS`` ending in ``le="+Inf"``, then ``_sum`` and
``_count`` -- so downstream ``histogram_quantile()`` works on the
scrape, not just on our nearest-rank summaries.
"""
from __future__ import annotations

import json

from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.trace import NullTracer, Tracer

#: the track instant/request-scoped spans land on when untagged
MAIN_TRACK = "serve"


def chrome_trace_events(tracer: Tracer | NullTracer,
                        pid: int = 1) -> list[dict]:
    """The ``traceEvents`` list: thread-name metadata first (tracks in
    first-seen order, so tids are deterministic), then one event per
    span in stream order."""
    tids: dict[str, int] = {}

    def tid_of(track: str | None) -> int:
        name = track if track is not None else MAIN_TRACK
        if name not in tids:
            tids[name] = len(tids)
        return tids[name]

    events: list[dict] = []
    for s in tracer.spans:
        args: dict = {}
        if s.ticket is not None:
            args["ticket"] = s.ticket
        if s.tickets:
            args["tickets"] = list(s.tickets)
        args.update(s.attrs)
        ev = {"name": s.name, "ph": "i" if s.instant else "X",
              "ts": round(s.t0 * 1e6, 3), "pid": pid,
              "tid": tid_of(s.track), "args": args}
        if s.instant:
            ev["s"] = "t"       # thread-scoped instant marker
        else:
            t1 = s.t1 if s.t1 is not None else s.t0
            ev["dur"] = round((t1 - s.t0) * 1e6, 3)
        events.append(ev)
    meta = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
             "args": {"name": track}} for track, tid in tids.items()]
    return meta + events


def chrome_trace(tracer: Tracer | NullTracer) -> dict:
    """The full Chrome/Perfetto JSON object."""
    return {"traceEvents": chrome_trace_events(tracer),
            "displayTimeUnit": "ms"}


def dump_chrome_trace(tracer: Tracer | NullTracer, path: str) -> dict:
    """Serialize deterministically (sorted keys, fixed separators, one
    trailing newline) so equal streams give byte-identical files."""
    doc = chrome_trace(tracer)
    with open(path, "w") as f:
        json.dump(doc, f, sort_keys=True, separators=(",", ":"))
        f.write("\n")
    return doc


# -- Prometheus ---------------------------------------------------------------

def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\"", "\\\"").replace("\n", "\\n")


def _name(namespace: str, metric: str) -> str:
    base = f"{namespace}_{metric}" if namespace else metric
    return "".join(c if c.isalnum() or c == "_" else "_" for c in base)


def _labelstr(labelnames: tuple, values: tuple, extra: str = "") -> str:
    parts = [f'{ln}="{_escape(v)}"' for ln, v in zip(labelnames, values)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    return repr(float(v))


def prometheus_text(*registries: MetricsRegistry) -> str:
    """Text exposition (version 0.0.4) of one or more registries.
    Families sort by exposition name and children by label values, so
    equal registry states render byte-identically -- the obs-smoke lane
    snapshots this output."""
    lines: list[str] = []
    fams = sorted(
        ((_name(reg.namespace, fam.name), fam)
         for reg in registries for fam in reg.families.values()),
        key=lambda p: p[0])
    for name, fam in fams:
        if fam.help:
            lines.append(f"# HELP {name} {_escape(fam.help)}")
        lines.append(f"# TYPE {name} {fam.kind}")
        for values in sorted(fam.children):
            child = fam.children[values]
            if isinstance(child, Histogram):
                counts = child.bucket_counts()
                for bound, c in zip(Histogram.BOUNDS, counts):
                    lelabel = 'le="%g"' % bound
                    lines.append(
                        f"{name}_bucket"
                        f"{_labelstr(fam.labelnames, values, lelabel)}"
                        f" {c}")
                inf = 'le="+Inf"'
                lines.append(
                    f"{name}_bucket"
                    f"{_labelstr(fam.labelnames, values, inf)}"
                    f" {child.count}")
                lines.append(f"{name}_sum"
                             f"{_labelstr(fam.labelnames, values)}"
                             f" {_fmt(child.sum)}")
                lines.append(f"{name}_count"
                             f"{_labelstr(fam.labelnames, values)}"
                             f" {child.count}")
            else:
                lines.append(f"{name}{_labelstr(fam.labelnames, values)}"
                             f" {_fmt(child.value)}")
    return "\n".join(lines) + "\n" if lines else ""
