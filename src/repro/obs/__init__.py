"""Observability: deterministic tracing, typed metrics, exporters, a
flight recorder, and the analysis layer built on top of them -- the
cost-model-attributed profiler, the SLO burn-rate monitor, and the
bench-trajectory trend analytics.

The layer's pieces (see ``docs/architecture.md`` sections 8-9):

  * ``obs.trace``    -- span-tree tracer with an injectable clock; under
    ``serving.clock.VirtualClock`` every timestamp and span count is
    bit-deterministic and exactly CI-gateable.  Disabled by default
    (``NullTracer``): each engine hook is one branch.
  * ``obs.metrics``  -- typed registry (counters / gauges / histograms
    with the shared nearest-rank ``percentile``) unifying the serving
    stack's ad-hoc stats dicts behind back-compat views, with labeled
    dimensions (tenant, plan kind, backend, dtype/qformat, size class).
  * ``obs.export``   -- Chrome-trace-event JSON (Perfetto: one track per
    plan bucket + one per recovery ladder) and Prometheus text
    exposition.
  * ``obs.recorder`` -- bounded ring-buffer flight recorder dumped into
    ``LaunchError`` / chaos post-mortems.
  * ``obs.profile``  -- folds a traced run's span stream into a
    self/child attribution tree and per-kernel launch tables, with the
    cost model's per-launch predictions (attached at dispatch time)
    compared against observed traffic; ``python -m repro.obs.profile``.
  * ``obs.slo``      -- multi-window burn-rate alerting over the
    latency / rejection error budgets, deterministic under a virtual
    clock, exported through ``prometheus_text``.
  * ``obs.bench_history`` -- the committed ``BENCH_*.json`` records as
    a time series; ``tools/bench_trend.py`` gates directional drift.

Quickstart::

    from repro import obs
    from repro.serving.clock import VirtualClock

    trc = obs.Tracer(clock=VirtualClock(),
                     recorder=obs.FlightRecorder(256))
    with obs.installed(trc):
        ...serve...
    obs.dump_chrome_trace(trc, "out.json")       # open in Perfetto
    print(obs.prometheus_text(my_registry))
"""
from repro.obs.export import (chrome_trace, chrome_trace_events,
                              dump_chrome_trace, prometheus_text)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               StatsView, percentile)
from repro.obs.recorder import FlightRecorder
from repro.obs.trace import (NullTracer, Span, SpanNode, Tracer, active,
                             install, installed)

#: analysis-layer symbols resolved lazily (PEP 562): ``repro.obs.profile``
#: is also a ``python -m`` entry point, and an eager package-level import
#: of it would trip runpy's double-import warning on every CLI invocation
_LAZY = {
    "LaunchGroup": "profile", "Profile": "profile",
    "ProfileNode": "profile", "dump_span_stream": "profile",
    "load_span_stream": "profile",
    "BurnRule": "slo", "SLOMonitor": "slo",
}


def __getattr__(name):
    submodule = _LAZY.get(name)
    if submodule is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    value = getattr(importlib.import_module(f"repro.obs.{submodule}"),
                    name)
    globals()[name] = value
    return value

__all__ = [
    "BurnRule", "Counter", "FlightRecorder", "Gauge", "Histogram",
    "LaunchGroup", "MetricsRegistry", "NullTracer", "Profile",
    "ProfileNode", "SLOMonitor", "Span", "SpanNode", "StatsView", "Tracer",
    "active", "chrome_trace", "chrome_trace_events", "dump_chrome_trace",
    "dump_span_stream", "install", "installed", "load_span_stream",
    "percentile", "prometheus_text",
]
