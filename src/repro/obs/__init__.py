"""Observability: deterministic tracing, typed metrics, exporters, and a
flight recorder for the serving stack.

The layer has four pieces (see ``docs/architecture.md`` section 8):

  * ``obs.trace``    -- span-tree tracer with an injectable clock; under
    ``serving.clock.VirtualClock`` every timestamp and span count is
    bit-deterministic and exactly CI-gateable.  Disabled by default
    (``NullTracer``): each engine hook is one branch.
  * ``obs.metrics``  -- typed registry (counters / gauges / histograms
    with the shared nearest-rank ``percentile``) unifying the serving
    stack's ad-hoc stats dicts behind back-compat views, with labeled
    dimensions (tenant, plan kind, backend, dtype/qformat, size class).
  * ``obs.export``   -- Chrome-trace-event JSON (Perfetto: one track per
    plan bucket + one per recovery ladder) and Prometheus text
    exposition.
  * ``obs.recorder`` -- bounded ring-buffer flight recorder dumped into
    ``LaunchError`` / chaos post-mortems.

Quickstart::

    from repro import obs
    from repro.serving.clock import VirtualClock

    trc = obs.Tracer(clock=VirtualClock(),
                     recorder=obs.FlightRecorder(256))
    with obs.installed(trc):
        ...serve...
    obs.dump_chrome_trace(trc, "out.json")       # open in Perfetto
    print(obs.prometheus_text(my_registry))
"""
from repro.obs.export import (chrome_trace, chrome_trace_events,
                              dump_chrome_trace, prometheus_text)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               StatsView, percentile)
from repro.obs.recorder import FlightRecorder
from repro.obs.trace import (NullTracer, Span, SpanNode, Tracer, active,
                             install, installed)

__all__ = [
    "Counter", "FlightRecorder", "Gauge", "Histogram", "MetricsRegistry",
    "NullTracer", "Span", "SpanNode", "StatsView", "Tracer", "active",
    "chrome_trace", "chrome_trace_events", "dump_chrome_trace", "install",
    "installed", "percentile", "prometheus_text",
]
