"""Multi-window burn-rate SLO monitoring over the serving error budget.

An SLO ("99% of requests resolve inside the scheduling deadline") defines
an error budget: the 1% of requests ALLOWED to be bad.  The monitor
tracks how fast that budget is being spent -- the **burn rate**, bad
fraction in a trailing window divided by the budget fraction -- and
fires an alert only when the burn is high on a LONG window (the spend is
sustained, not a blip) AND on a SHORT window (it is still happening
right now).  That is the multi-window pattern production SRE practice
settled on: the long window keeps one bad bucket from paging, the short
window un-pages the moment the bleeding stops.

Two objectives, matching the async front-end's ``SLOConfig`` contract:

  * ``latency`` -- a resolved request is *bad* when its
    admission-to-resolution latency exceeds the threshold (defaults to
    the engine's ``max_wait_s``: the scheduling-latency SLO knob).
  * ``rejections`` -- a submission is *bad* when admission refuses it
    (queue-full / rate-limit); admitted submissions are the good events.

**Determinism is the design driver** (same rule as the tracer): the
monitor reads time ONLY through the injectable clock, so under a
``serving.clock.VirtualClock`` every burn-rate value, alert firing
instant, and resolution instant is a bit-deterministic function of the
arrival script -- ``tests/test_slo.py`` pins firing times to exact
virtual seconds, and the ``slo_burn_smoke`` benchmark row gates them.
Alert state lives in a ``MetricsRegistry`` (``slo_*`` instruments), so
the existing ``obs.export.prometheus_text`` exposes it unchanged.

Wiring::

    clock = VirtualClock()
    mon = SLOMonitor(clock, latency_slo_s=0.02)
    eng = AsyncGeometryServer(clock=clock, slo_monitor=mon, ...)
    ...serve...
    print(prometheus_text(mon.metrics))     # slo_alert_active{...} etc.
"""
from __future__ import annotations

import collections
import dataclasses
import typing

from repro.obs.metrics import MetricsRegistry
from repro.obs import trace as obst

#: the objective label values (the one label dimension of every slo_*
#: instrument)
LATENCY = "latency"
REJECTIONS = "rejections"


@dataclasses.dataclass(frozen=True)
class BurnRule:
    """One (long, short) window pair: the alert condition is
    ``burn(long) >= threshold AND burn(short) >= threshold``.  A burn
    of 1.0 spends exactly the budget over the window; the classic page
    thresholds (14.4 over 1h/5m, 6 over 6h/30m) scale to whatever
    timescale the deployment's windows use."""
    long_s: float
    short_s: float
    threshold: float

    def __post_init__(self):
        if not 0 < self.short_s <= self.long_s:
            raise ValueError(
                f"windows must satisfy 0 < short <= long, got "
                f"{self.short_s}/{self.long_s}")
        if self.threshold <= 0:
            raise ValueError(f"threshold must be > 0, got {self.threshold}")


#: the SRE-book pairs, on their canonical hour scale; virtual-clock
#: tests and the soak pass second-scale rules explicitly
DEFAULT_RULES = (BurnRule(long_s=3600.0, short_s=300.0, threshold=14.4),
                 BurnRule(long_s=21600.0, short_s=1800.0, threshold=6.0))


@dataclasses.dataclass
class AlertState:
    """One objective's alert: current activity plus the full transition
    history (virtual-clock instants -- pinnable)."""
    objective: str
    active: bool = False
    fired_at: list[float] = dataclasses.field(default_factory=list)
    resolved_at: list[float] = dataclasses.field(default_factory=list)

    @property
    def fired(self) -> int:
        return len(self.fired_at)


class SLOMonitor:
    """Error-budget accounting for one serving engine.

    Feed it events (``observe_latency`` / ``observe_admission`` /
    ``observe_rejection``); it timestamps each through the injectable
    clock, maintains the trailing windows, and re-evaluates the burn
    rules on every event -- so an alert fires AT the event that crossed
    the threshold, a deterministic instant under a virtual clock.
    """

    def __init__(self, clock, *, latency_slo_s: float,
                 latency_target: float = 0.99,
                 rejection_target: float = 0.99,
                 rules: typing.Sequence[BurnRule] = DEFAULT_RULES,
                 registry: MetricsRegistry | None = None):
        if not rules:
            raise ValueError("SLOMonitor needs at least one BurnRule")
        for name, target in (("latency", latency_target),
                             ("rejection", rejection_target)):
            if not 0 < target < 1:
                raise ValueError(f"{name}_target must be in (0, 1), "
                                 f"got {target}")
        self.clock = clock
        self.latency_slo_s = latency_slo_s
        self.rules = tuple(rules)
        self.targets = {LATENCY: latency_target,
                        REJECTIONS: rejection_target}
        self._horizon = max(r.long_s for r in self.rules)
        #: per-objective event windows: (t, bad) in time order
        self._events: dict[str, collections.deque] = {
            LATENCY: collections.deque(), REJECTIONS: collections.deque()}
        self.alerts = {LATENCY: AlertState(LATENCY),
                       REJECTIONS: AlertState(REJECTIONS)}
        self.metrics = registry if registry is not None \
            else MetricsRegistry("slo")
        self._c_events = self.metrics.counter(
            "events", help="SLO-classified events", labels=("objective",))
        self._c_bad = self.metrics.counter(
            "bad_events", help="events that spent error budget",
            labels=("objective",))
        self._c_fired = self.metrics.counter(
            "alerts_fired", help="alert activations",
            labels=("objective",))
        self._g_active = self.metrics.gauge(
            "alert_active", help="1 while the alert is firing",
            labels=("objective",))
        self._g_burn = self.metrics.gauge(
            "burn_rate", help="budget burn over the trailing window",
            labels=("objective", "window"))

    # -- event intake ---------------------------------------------------------

    def observe_latency(self, latency_s: float) -> None:
        """One resolved request; bad when it blew the latency SLO."""
        self._observe(LATENCY, bad=latency_s > self.latency_slo_s)

    def observe_admission(self) -> None:
        """One admitted submission (a good rejection-objective event)."""
        self._observe(REJECTIONS, bad=False)

    def observe_rejection(self) -> None:
        """One refused submission (queue-full / rate-limit): budget
        spend on the rejection objective."""
        self._observe(REJECTIONS, bad=True)

    def _observe(self, objective: str, *, bad: bool) -> None:
        now = self.clock.now()
        events = self._events[objective]
        events.append((now, bad))
        cutoff = now - self._horizon
        while events and events[0][0] < cutoff:
            events.popleft()
        self._c_events.labels(objective=objective).inc()
        if bad:
            self._c_bad.labels(objective=objective).inc()
        self._evaluate(objective, now)

    # -- burn arithmetic ------------------------------------------------------

    def bad_fraction(self, objective: str, window_s: float,
                     now: float | None = None) -> float:
        """Bad events / all events over the trailing window (0.0 when
        the window is empty: an idle engine spends no budget)."""
        now = self.clock.now() if now is None else now
        cutoff = now - window_s
        total = bad = 0
        for t, b in self._events[objective]:
            if t >= cutoff:
                total += 1
                bad += b
        return bad / total if total else 0.0

    def burn_rate(self, objective: str, window_s: float,
                  now: float | None = None) -> float:
        """Budget burn over the window: 1.0 = spending exactly the
        budget, N = burning it N times too fast."""
        budget = 1.0 - self.targets[objective]
        return self.bad_fraction(objective, window_s, now) / budget

    def _evaluate(self, objective: str, now: float) -> None:
        burns: dict[float, float] = {}

        def burn(w: float) -> float:
            if w not in burns:
                burns[w] = self.burn_rate(objective, w, now)
            return burns[w]

        firing = any(burn(r.long_s) >= r.threshold
                     and burn(r.short_s) >= r.threshold
                     for r in self.rules)
        # export the burn gauges for every window the rules read
        for r in self.rules:
            for w in (r.long_s, r.short_s):
                self._g_burn.labels(objective=objective,
                                    window=f"{w:g}s").set(burn(w))
        alert = self.alerts[objective]
        if firing and not alert.active:
            alert.active = True
            alert.fired_at.append(now)
            self._c_fired.labels(objective=objective).inc()
            self._g_active.labels(objective=objective).set(1)
            trc = obst.active()
            if trc.enabled:
                trc.instant("slo.fire", objective=objective,
                            burn=max(burns.values()))
        elif not firing and alert.active:
            alert.active = False
            alert.resolved_at.append(now)
            self._g_active.labels(objective=objective).set(0)
            trc = obst.active()
            if trc.enabled:
                trc.instant("slo.resolve", objective=objective)

    # -- reads ----------------------------------------------------------------

    def counters(self) -> dict:
        """The deterministic summary (virtual-clock instants in µs, so
        they survive a round trip through benchmark rows exactly)."""
        out = {}
        for obj, alert in sorted(self.alerts.items()):
            out[f"{obj}_alerts_fired"] = alert.fired
            out[f"{obj}_alert_active"] = int(alert.active)
            out[f"{obj}_bad_events"] = \
                self.metrics.value("bad_events", objective=obj)
            out[f"{obj}_events"] = \
                self.metrics.value("events", objective=obj)
            if alert.fired_at:
                out[f"{obj}_first_fire_us"] = \
                    round(alert.fired_at[0] * 1e6, 1)
            if alert.resolved_at:
                out[f"{obj}_first_resolve_us"] = \
                    round(alert.resolved_at[0] * 1e6, 1)
        return out
