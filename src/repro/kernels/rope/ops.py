"""Public RoPE entry (paper rotation transform -> rotary embedding)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.rope import ref
from repro.kernels.rope import rope as K

rope_tables = ref.rope_tables


def rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
         *, backend: str | None = None) -> jnp.ndarray:
    """Apply rotary embedding to x (..., S, D); cos/sin (S, D/2)."""
    b = dispatch.resolve(backend)
    if b == "ref":
        return ref.rope(x, cos, sin)
    lead = x.shape[:-2]
    s, d = x.shape[-2:]
    out = K.rope_3d(x.reshape(-1, s, d), cos, sin, interpret=(b == "interpret"))
    return out.reshape(*lead, s, d)
