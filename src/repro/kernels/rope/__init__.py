from repro.kernels.rope.ops import rope, rope_tables

__all__ = ["rope", "rope_tables"]
