"""Pure-jnp RoPE oracle (half-split pairing, LLaMA convention)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_tables(positions: jnp.ndarray, d_head: int,
                theta: float = 10000.0, dtype=jnp.float32):
    """cos/sin tables (len(positions), d_head/2)."""
    d2 = d_head // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, d2, dtype=jnp.float32) / d2))
    angles = positions.astype(jnp.float32)[:, None] * inv_freq[None, :]
    return jnp.cos(angles).astype(dtype), jnp.sin(angles).astype(dtype)


def rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Apply RoPE to x (..., S, D) with cos/sin (S, D/2)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    cos = cos.astype(x.dtype)
    sin = sin.astype(x.dtype)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
