"""Pallas TPU kernel for rotary position embedding -- the paper's rotation.

Section 5.3 maps 2D point rotation onto the array as a matrix product with
[[cos, -sin], [sin, cos]].  RoPE is exactly that transformation applied to
(x1, x2) coordinate pairs of each attention head dimension, with a
position-dependent angle: the modern descendant of the paper's geometric
rotation.  We use the half-split pairing convention (x1 = first half of the
head dim, x2 = second half), so the rotation is two fused affine ops:

    y1 = x1*cos - x2*sin
    y2 = x2*cos + x1*sin

The sin/cos tables are staged per sequence block (the "context" for that
block); heads stream through the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import SUBLANES, pad_axis, pick_block


def _rope_kernel(x_ref, cos_ref, sin_ref, o_ref):
    x = x_ref[0]                      # (bs, d)
    d2 = x.shape[-1] // 2
    x1, x2 = x[:, :d2], x[:, d2:]
    cos, sin = cos_ref[...], sin_ref[...]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    o_ref[0] = jnp.concatenate([y1, y2], axis=-1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rope_3d(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
            *, interpret: bool = False) -> jnp.ndarray:
    """Apply RoPE to x (BH, S, D) with cos/sin (S, D/2)."""
    bh, s, d = x.shape
    bs = pick_block(s, 512, SUBLANES)
    xp = pad_axis(x, 1, bs)
    cosp = pad_axis(cos.astype(x.dtype), 0, bs)
    sinp = pad_axis(sin.astype(x.dtype), 0, bs)
    sp = xp.shape[1]
    out = pl.pallas_call(
        _rope_kernel,
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        grid=(bh, sp // bs),
        in_specs=[
            pl.BlockSpec((1, bs, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((bs, d // 2), lambda h, i: (i, 0)),
            pl.BlockSpec((bs, d // 2), lambda h, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, d), lambda h, i: (h, i, 0)),
        interpret=interpret,
    )(xp, cosp, sinp)
    return out[:, :s, :]
