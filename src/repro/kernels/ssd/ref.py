"""Pure-jnp oracle for the SSD intra-chunk core (matches models/ssm.py)."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_intra(xdt, b_in, c_in, cum):
    """xdt (BC, lc, h, p), b_in/c_in (BC, lc, n), cum (BC, lc, h) ->
    (y_intra (BC, lc, h, p) f32, s_c (BC, h, p, n) f32)."""
    xdt = xdt.astype(jnp.float32)
    b_in = b_in.astype(jnp.float32)
    c_in = c_in.astype(jnp.float32)
    cum = cum.astype(jnp.float32)
    lc = xdt.shape[1]
    g = jnp.einsum("cin,cjn->cij", c_in, b_in)
    diff = cum[:, :, None, :] - cum[:, None, :, :]      # (BC, i, j, h)
    ii = jnp.arange(lc)
    mask = (ii[:, None] >= ii[None, :])[None, :, :, None]
    decay = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    att = g[..., None] * decay
    y = jnp.einsum("cijh,cjhp->cihp", att, xdt)
    sdecay = jnp.exp(cum[:, -1:, :] - cum)              # (BC, lc, h)
    w = xdt * sdecay[..., None]
    s_c = jnp.einsum("cjhp,cjn->chpn", w, b_in)
    return y, s_c
