"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk core.

EXPERIMENTS.md section Perf (hymba/mamba2 cells) shows the XLA path's
remaining memory term is the materialized intra-chunk tensors: G = C B^T,
the masked decay, their product `att`, all (lc x lc) per (chunk, head).
This kernel is the paper's thesis applied once more: the whole chunk
computation is *blocked matrix algebra*, so it streams through VMEM like
the MorphoSys frame buffer and only the (lc, p) outputs + (p, n) state
contributions ever touch HBM.

Per grid step (one (batch*chunk, head) pair), entirely in VMEM:

    G     = C B^T                       (lc, lc)   one MXU dot
    att   = G * exp(mask(cum_i - cum_j))
    y     = att @ (x*dt)                (lc, p)    one MXU dot
    w     = (x*dt) * exp(cum_last - cum)
    S_c   = w^T B                       (p, n)     one MXU dot

Working set ~ 3*(lc*lc) + 4*(lc*(n+p)) floats: lc=256, n=128, p=64 ->
~1 MB, comfortably VMEM-resident.  The inter-chunk associative scan stays
in jnp (log-depth, tiny).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_intra_kernel(c_ref, b_ref, cum_ref, xdt_ref, y_ref, s_ref):
    cc = c_ref[0].astype(jnp.float32)                 # (lc, n)
    bb = b_ref[0].astype(jnp.float32)                 # (lc, n)
    cum = cum_ref[0, :, 0].astype(jnp.float32)        # (lc,)
    xdt = xdt_ref[0, :, 0, :].astype(jnp.float32)     # (lc, p)
    lc = cc.shape[0]

    g = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (lc, lc)
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 1)
    decay = jnp.exp(jnp.where(ii >= jj, diff, -jnp.inf))
    att = g * decay

    y = jax.lax.dot_general(att, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (lc, p)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    sdecay = jnp.exp(cum[-1] - cum)                    # (lc,)
    w = xdt * sdecay[:, None]                          # (lc, p)
    s_c = jax.lax.dot_general(w, bb, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (p, n)
    s_ref[0, 0] = s_c.astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra(xdt: jnp.ndarray, b_in: jnp.ndarray, c_in: jnp.ndarray,
              cum: jnp.ndarray, *, interpret: bool = False):
    """Intra-chunk SSD.  xdt (BC, lc, h, p), b_in/c_in (BC, lc, n),
    cum (BC, lc, h).  Returns (y_intra (BC, lc, h, p), s_c (BC, h, p, n))."""
    bc, lc, h, p = xdt.shape
    n = b_in.shape[-1]
    y, s_c = pl.pallas_call(
        _ssd_intra_kernel,
        out_shape=(jax.ShapeDtypeStruct((bc, lc, h, p), jnp.float32),
                   jax.ShapeDtypeStruct((bc, h, p, n), jnp.float32)),
        grid=(bc, h),
        in_specs=[
            pl.BlockSpec((1, lc, n), lambda i, hh: (i, 0, 0)),
            pl.BlockSpec((1, lc, n), lambda i, hh: (i, 0, 0)),
            pl.BlockSpec((1, lc, 1), lambda i, hh: (i, 0, hh)),
            pl.BlockSpec((1, lc, 1, p), lambda i, hh: (i, 0, hh, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, lc, 1, p), lambda i, hh: (i, 0, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, hh: (i, hh, 0, 0)),
        ),
        interpret=interpret,
    )(c_in, b_in, cum, xdt)
    return y, s_c
