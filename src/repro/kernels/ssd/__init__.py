from repro.kernels.ssd.ops import ssd_intra

__all__ = ["ssd_intra"]
