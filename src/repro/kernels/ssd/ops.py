"""Public SSD intra-chunk entry, backend-dispatched."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.ssd import ref
from repro.kernels.ssd import ssd as K


def ssd_intra(xdt: jnp.ndarray, b_in: jnp.ndarray, c_in: jnp.ndarray,
              cum: jnp.ndarray, *, backend: str | None = None):
    """Mamba-2 SSD intra-chunk core (VMEM-resident masked attention form).

    Computes the within-chunk term of the state-space dual: scores
    C·Bᵀ gated by the segment-sum decay ``cum``, applied to ``xdt``.
    Shapes as documented in ``kernels/ssd/ssd.py``; returns the chunk
    outputs plus the per-chunk state contribution.  Backend per
    ``repro.kernels.dispatch``.
    """
    be = dispatch.resolve(backend)
    if be == "ref":
        return ref.ssd_intra(xdt, b_in, c_in, cum)
    return K.ssd_intra(xdt, b_in, c_in, cum, interpret=(be == "interpret"))
