from repro.kernels.projective.ops import chain_project, chain_project_batch

__all__ = ["chain_project", "chain_project_batch"]
