"""Pallas TPU kernels for fused projective (homogeneous) transform chains.

The graphics companion paper maps full 2D/3D viewing pipelines -- model
affines, camera, perspective/orthographic projection, cull, viewport --
onto the same RC array as the source paper's affine primitives.  Here the
whole folded pipeline is ONE lane-dense kernel over the flattened point
buffer, extending the ``chain_matrix_1d`` discipline with a second rolled
MAC set and an in-kernel divide:

  * the linear block H[:d, :d] applies as the usual 2d-1 lane-rolled
    multiply-adds against d-periodic coefficient rows;
  * the perspective column H[:d, d] applies as a SECOND set of 2d-1 rolled
    MACs producing each point's homogeneous w on every one of its lanes;
  * the divide q = acc / w happens in-register (w <= 0 divides by 1 and is
    masked out), followed by the axis-aligned cull test against per-lane
    lo/hi bounds rows;
  * the per-lane inlier bits are AND-reduced across each point's d lanes
    with the same roll trick (wrapped or cross-point lanes contribute a
    neutral 1), so the emitted mask is constant over a point's lanes.

One HBM read of the points, one write of the projected points, one write
of the mask -- no homogeneous-coordinate materialisation, no padding of
the d-wide trailing axis to 128 lanes, and still pure VPU work.  The
batched forms are row-aligned like ``chain_matrix_batch_2d``: request b's
block row meets request b's folded (H, lo, hi), so a whole serving bucket
of heterogeneous projective requests is a single launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import SUBLANES, pad_axis, stage_flat, stage_packed


def _proj_rows(h: jnp.ndarray, lane_coord: jnp.ndarray, d: int):
    """The rolled-MAC coefficient patterns for one homogeneous ``h``:
    linear rows C_delta[j] = H[c+delta, c], perspective rows
    W_delta[j] = H[c+delta, d], and the 0/1 same-point validity rows
    G_delta[j] = [0 <= c+delta < d] (shared by the single-chain and
    batched lowerings so the MAC and mask schedules cannot diverge).
    Returns three (2d-1, g) stacks with g = len(lane_coord)."""
    rows, wrows, grows = [], [], []
    for delta in range(-(d - 1), d):
        src = lane_coord + delta
        valid = (src >= 0) & (src < d)
        srcc = jnp.clip(src, 0, d - 1)
        zero = jnp.zeros((), h.dtype)
        rows.append(jnp.where(valid, h[srcc, lane_coord], zero))
        wrows.append(jnp.where(valid, h[srcc, d], zero))
        grows.append(valid.astype(h.dtype))
    return jnp.stack(rows), jnp.stack(wrows), jnp.stack(grows)


def _chain_project_kernel(x_ref, c_ref, wc_ref, g_ref, p_ref, o_ref, m_ref,
                          *, d: int):
    x = x_ref[...]
    p = p_ref[...]                   # rows: t, w-translation, lo, hi
    acc = jnp.zeros_like(x) + p[0:1, :]
    wacc = jnp.zeros_like(x) + p[1:2, :]
    for i, delta in enumerate(range(-(d - 1), d)):
        xr = jnp.roll(x, -delta, axis=1)
        acc = acc + xr * c_ref[i:i + 1, :]
        wacc = wacc + xr * wc_ref[i:i + 1, :]
    w_ok = wacc > 0.0
    v = acc / jnp.where(w_ok, wacc, jnp.ones_like(wacc))
    inl = jnp.where(w_ok & (v >= p[2:3, :]) & (v <= p[3:4, :]),
                    jnp.ones_like(x), jnp.zeros_like(x))
    mask = jnp.ones_like(x)
    for i, delta in enumerate(range(-(d - 1), d)):
        g = g_ref[i:i + 1, :]
        mask = mask * (jnp.roll(inl, -delta, axis=1) * g + (1.0 - g))
    o_ref[...] = v
    m_ref[...] = mask


@functools.partial(jax.jit, static_argnames=("d", "interpret", "block_rows",
                                             "lane_target"))
def chain_project_1d(flat: jnp.ndarray, h: jnp.ndarray, lo: jnp.ndarray,
                     hi: jnp.ndarray, *, d: int, interpret: bool = False,
                     block_rows: int | None = None,
                     lane_target: int | None = None):
    """Fused projective chain on the flat (N*d,) point buffer.

    ``h`` is the folded (d+1, d+1) homogeneous matrix (row-vector
    convention), ``lo``/``hi`` the (d,) cull bounds.  Returns the projected
    flat buffer and a flat per-lane mask (constant across each point's d
    lanes; 1.0 = inside).  ``block_rows``/``lane_target`` are the
    autotuner's launch parameters (``None`` = historical defaults); they
    steer staging only -- the MAC/divide schedule per lane is identical
    under any staging, so every configuration is bit-identical."""
    (l,) = flat.shape
    if l == 0:
        return flat, flat
    xp, lane_coord, bm, w = stage_flat(flat, d, block_rows=block_rows,
                                       lane_target=lane_target)
    hc = h.astype(flat.dtype)
    coef, wcoef, gmask = _proj_rows(hc, lane_coord, d)
    prow = jnp.stack([hc[d, :d][lane_coord],
                      jnp.broadcast_to(hc[d, d], (w,)),
                      lo.astype(flat.dtype)[lane_coord],
                      hi.astype(flat.dtype)[lane_coord]])
    out, mask = pl.pallas_call(
        functools.partial(_chain_project_kernel, d=d),
        out_shape=[jax.ShapeDtypeStruct(xp.shape, flat.dtype)] * 2,
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, w), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANES, w), lambda i: (0, 0)),  # linear rows
            pl.BlockSpec((SUBLANES, w), lambda i: (0, 0)),  # perspective rows
            pl.BlockSpec((SUBLANES, w), lambda i: (0, 0)),  # same-point rows
            pl.BlockSpec((SUBLANES, w), lambda i: (0, 0)),  # t/wt/lo/hi rows
        ],
        out_specs=[pl.BlockSpec((bm, w), lambda i: (i, 0))] * 2,
        interpret=interpret,
    )(xp, pad_axis(coef, 0, SUBLANES), pad_axis(wcoef, 0, SUBLANES),
      pad_axis(gmask, 0, SUBLANES), pad_axis(prow, 0, SUBLANES))
    return out.reshape(-1)[:l], mask.reshape(-1)[:l]


def _chain_project_batch_kernel(x_ref, c_ref, wc_ref, g_ref, p_ref, o_ref,
                                m_ref, *, d: int, g: int):
    x = x_ref[...]                                   # (bm, wr) -- bm requests
    bm, wr = x.shape
    reps = wr // g
    p = p_ref[...]                                   # (bm, 4g): t, wt, lo, hi
    acc = jnp.zeros_like(x).reshape(bm, reps, g) + p[:, None, 0:g]
    wacc = jnp.zeros_like(x).reshape(bm, reps, g) + p[:, None, g:2 * g]
    for i, delta in enumerate(range(-(d - 1), d)):
        xr = jnp.roll(x, -delta, axis=1).reshape(bm, reps, g)
        acc = acc + xr * c_ref[...][:, None, i * g:(i + 1) * g]
        wacc = wacc + xr * wc_ref[...][:, None, i * g:(i + 1) * g]
    w_ok = wacc > 0.0
    v = acc / jnp.where(w_ok, wacc, jnp.ones_like(wacc))
    inl = jnp.where(w_ok & (v >= p[:, None, 2 * g:3 * g])
                    & (v <= p[:, None, 3 * g:4 * g]),
                    jnp.ones_like(v), jnp.zeros_like(v))
    inl2 = inl.reshape(bm, wr)
    mask = jnp.ones_like(inl)
    for i, delta in enumerate(range(-(d - 1), d)):
        gm = g_ref[...][0:1, None, i * g:(i + 1) * g]
        mask = mask * (jnp.roll(inl2, -delta, axis=1).reshape(bm, reps, g)
                       * gm + (1.0 - gm))
    o_ref[...] = v.reshape(bm, wr)
    m_ref[...] = mask.reshape(bm, wr)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def chain_project_batch_2d(pts3: jnp.ndarray, h: jnp.ndarray,
                           lo: jnp.ndarray, hi: jnp.ndarray, *,
                           interpret: bool = False,
                           block_rows: int | None = None):
    """Batched folded projective chains: one launch for a whole bucket.

    ``pts3`` is a packed (B, L, d) batch (one serving request per row,
    padded to a common L); ``h`` (B, d+1, d+1) / ``lo``/``hi`` (B, d) are
    per-request folded parameters.  Same rolled MAC + divide + mask
    schedule as ``chain_project_1d`` -- rolls stay inside a block row, so
    they never mix requests -- but every coefficient/bounds row is
    *row-aligned* (request b's block row meets request b's parameters).
    Returns the projected (B, L, d) batch and a (B, L) float mask.
    ``block_rows`` pins the batch-axis block (``None`` = VMEM heuristic).
    """
    b, l, d = pts3.shape
    if b == 0 or l == 0:
        return pts3, jnp.zeros((b, l), pts3.dtype)
    xp, lane_coord, bm, g = stage_packed(pts3, d, block_rows=block_rows)
    hc = h.astype(pts3.dtype)
    coef, wcoef, gmask = jax.vmap(
        lambda hb: _proj_rows(hb, lane_coord, d))(hc)  # (B, 2d-1, g) each
    coef = pad_axis(coef.reshape(b, (2 * d - 1) * g), 0, bm)
    wcoef = pad_axis(wcoef.reshape(b, (2 * d - 1) * g), 0, bm)
    grow = gmask[:1].reshape(1, (2 * d - 1) * g)       # same for every request
    prow = pad_axis(jnp.concatenate([
        hc[:, d, :d][:, lane_coord],
        jnp.broadcast_to(hc[:, d, d][:, None], (b, g)),
        lo.astype(pts3.dtype)[:, lane_coord],
        hi.astype(pts3.dtype)[:, lane_coord]], axis=1), 0, bm)
    out, mask = pl.pallas_call(
        functools.partial(_chain_project_batch_kernel, d=d, g=g),
        out_shape=[jax.ShapeDtypeStruct(xp.shape, pts3.dtype)] * 2,
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, xp.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bm, (2 * d - 1) * g), lambda i: (i, 0)),
            pl.BlockSpec((bm, (2 * d - 1) * g), lambda i: (i, 0)),
            pl.BlockSpec((1, (2 * d - 1) * g), lambda i: (0, 0)),
            pl.BlockSpec((bm, 4 * g), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((bm, xp.shape[1]), lambda i: (i, 0))] * 2,
        interpret=interpret,
    )(xp, coef, wcoef, grow, prow)
    out = out[:b, :l * d].reshape(b, l, d)
    mask = mask[:b, :l * d].reshape(b, l, d)[:, :, 0]
    return out, mask
