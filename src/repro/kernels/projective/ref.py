"""Pure-jnp oracle for the projective chain kernels (homogeneous form).

The projective composite is the graphics companion paper's full viewing
chain collapsed to a single homogeneous matrix: q_h = [p, 1] @ H, followed
by ONE perspective divide q = q_h[:d] / w and an axis-aligned cull test.
Like ``matmul.ref.chain_matrix``, the contraction is unrolled into
elementwise multiply-adds for the point dims that occur in practice
(d <= 3): a (N, 3) @ (4, 4) homogeneous product is a degenerate matmul on
CPU, and the unrolled form fuses into the single memory pass the fused
kernel is meant to be.  The accumulation order (left fold over m, then the
translation row) is the contract the bit-for-bit oracle tests pin.
"""
from __future__ import annotations

import jax.numpy as jnp


def chain_project(p: jnp.ndarray, h: jnp.ndarray, lo: jnp.ndarray,
                  hi: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Folded projective chain on (..., d) points; H (d+1, d+1) row-vector
    homogeneous, lo/hi (d,) axis-aligned cull bounds (+-inf = no cull).

    Returns ``(projected (..., d), inside (...,) bool)``.  The divide is
    guarded: points with w <= 0 (behind the center of projection) keep a
    finite value (divided by 1) and are marked outside.  Bounds tests are
    inclusive, so points exactly ON a frustum plane are inside.
    """
    h = jnp.asarray(h, jnp.float32)
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    d = p.shape[-1]
    pf = p.astype(jnp.float32)
    cols = [sum(pf[..., m] * h[m, c] for m in range(d)) + h[d, c]
            for c in range(d)]
    w = sum(pf[..., m] * h[m, d] for m in range(d)) + h[d, d]
    w_ok = w > 0.0
    safe = jnp.where(w_ok, w, jnp.ones_like(w))
    v = jnp.stack([c / safe for c in cols], axis=-1)
    inside = w_ok & jnp.all((v >= lo) & (v <= hi), axis=-1)
    return v.astype(p.dtype), inside
