"""Public entries for the projective chain family (homogeneous viewing
chains: camera -> projection -> cull -> viewport collapsed to one matrix).

Both entries return ``(projected, inside)`` -- the perspective-divided
points plus the boolean frustum-cull mask (w > 0 and every coordinate
inside the folded [lo, hi] bounds; bounds tests are inclusive, so points
exactly on a frustum plane count as inside).  Backend dispatch per
``repro.kernels.dispatch``; chain-level HBM byte accounting happens in
``TransformChain.apply``/``project`` and the serving engine (these entries
are called under jit inside compiled plans).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.autotune.cache import KernelConfig
from repro.kernels import dispatch
from repro.kernels.projective import projective as K
from repro.kernels.projective import ref


def _bounds(lo, hi, d: int, batch: tuple = ()):
    shape = batch + (d,)
    lo = jnp.full(shape, -jnp.inf, jnp.float32) if lo is None \
        else jnp.broadcast_to(jnp.asarray(lo, jnp.float32), shape)
    hi = jnp.full(shape, jnp.inf, jnp.float32) if hi is None \
        else jnp.broadcast_to(jnp.asarray(hi, jnp.float32), shape)
    return lo, hi


def chain_project(points: jnp.ndarray, h: jnp.ndarray, lo=None, hi=None, *,
                  backend: str | None = None,
                  config: KernelConfig | None = None):
    """Folded projective chain q = divide([p, 1] @ H) in one fused pass.

    ``points`` is (..., d); ``h`` the composed (d+1, d+1) homogeneous
    matrix (row-vector convention); ``lo``/``hi`` optional (d,) cull
    bounds (``None`` = unbounded).  Returns ``(projected (..., d),
    inside (...,) bool)``.  Lowering target for projective
    ``TransformChain`` plans: one HBM read of the points, one write of the
    projected points, one write of the mask -- the divide and the cull
    never leave the kernel.  ``config`` carries tuned launch parameters;
    any config is bit-identical to any other (staging-only knobs).
    """
    b = dispatch.resolve(backend)
    d = points.shape[-1]
    h = jnp.asarray(h)
    lo, hi = _bounds(lo, hi, d)
    if b == "ref":
        return ref.chain_project(points, h, lo, hi)
    cfg = config or KernelConfig("chain_project")
    out, mask = K.chain_project_1d(points.reshape(-1), h, lo, hi, d=d,
                                   interpret=(b == "interpret"),
                                   block_rows=cfg.block_rows,
                                   lane_target=cfg.lane_target)
    return out.reshape(points.shape), \
        (mask.reshape(-1, d)[:, 0] != 0).reshape(points.shape[:-1])


def chain_project_batch(pts3: jnp.ndarray, h: jnp.ndarray, lo=None, hi=None,
                        *, backend: str | None = None,
                        config: KernelConfig | None = None):
    """Batched folded projective chains: one launch per serving bucket.

    ``pts3`` is a packed (B, L, d) batch -- one serving request per row,
    padded to a common length L; ``h`` (B, d+1, d+1) / ``lo``/``hi``
    (B, d) are per-request folded parameters.  Returns ``(projected
    (B, L, d), inside (B, L) bool)``.  On ``ref`` the oracle is the
    per-request ``chain_project`` under ``jax.vmap`` (same unrolled op
    order per row -- the serving engine's equality contract), on
    ``pallas``/``interpret`` the row-aligned ``chain_project_batch_2d``
    kernel.  Called under jit inside the serving engine's compiled bucket
    plans; packed-batch byte accounting happens there.
    """
    b = dispatch.resolve(backend)
    bsz, _, d = pts3.shape
    h = jnp.broadcast_to(jnp.asarray(h), (bsz, d + 1, d + 1))
    lo, hi = _bounds(lo, hi, d, batch=(bsz,))
    if b == "ref":
        return jax.vmap(ref.chain_project)(pts3, h, lo, hi)
    cfg = config or KernelConfig("chain_project_batch")
    out, mask = K.chain_project_batch_2d(pts3, h, lo, hi,
                                         interpret=(b == "interpret"),
                                         block_rows=cfg.block_rows)
    return out, mask != 0
