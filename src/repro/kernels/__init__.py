"""Pallas TPU kernels for the paper's linear-algebra primitive classes.

  affine          -- vector-vector + vector-scalar (translation/scaling, 5.1-5.2)
  rope            -- rotation transform on head-dim pairs (5.3)
  matmul          -- tiled MXU matmul (rotation/composite, 5.3)
  rmsnorm         -- derived-scalar scaling fusion (beyond paper)
  flash_attention -- streaming composite transform (beyond paper)
  ssd             -- Mamba-2 intra-chunk core, VMEM-resident (beyond paper)

Composite-chain lowering targets (the paper's one-pass "General Composite
Algorithm"): ``chain_diag`` (folded diagonal chains, VPU-only) and
``chain_apply`` (folded general chains, lane-rolled q = p @ A + t); both
are single-HBM-pass kernels over the flattened point buffer and are what
``repro.core.transform_chain`` compiles to.  ``chain_project`` extends
the family to *projective* plans (homogeneous viewing chains with an
in-kernel perspective divide + frustum-cull mask -- the graphics
companion paper's 2D/3D pipelines).  The batched forms
``chain_diag_batch`` / ``chain_apply_batch`` / ``chain_project_batch``
take a packed (B, L, d) request batch with per-request folded parameters
and are what ``repro.serving`` lowers a whole plan bucket to -- one
launch per bucket.

The fixed-point lane (``kernels.fixedpoint``) re-expresses the chain
family on the M1's int16 Qm.n datapath: ``chain_diag_q`` /
``chain_apply_q`` (+ batch forms) run int32-accumulate MACs with a
single requantising shift over int16 point buffers -- half the HBM
bytes per point -- and are what quantised ``TransformChain`` plans
(``dtype="q8.7"``) and serving buckets lower to.  Projective plans have
no fixed-point form (the in-kernel divide stays float).

Every family ships ``ops.py`` (public entry, backend-dispatched) and
``ref.py`` (pure-jnp oracle).  See ``repro.kernels.dispatch``; HBM byte
accounting for perf tests lives in ``repro.kernels.opcount``.
"""
from repro.kernels import dispatch, opcount
from repro.kernels.affine import (affine, chain_diag, chain_diag_batch, scale,
                                  translate, vecadd)
from repro.kernels.fixedpoint import (chain_apply_batch_q, chain_apply_q,
                                      chain_diag_batch_q, chain_diag_q)
from repro.kernels.flash_attention import attention, blockwise_attention
from repro.kernels.matmul import chain_apply, chain_apply_batch, matmul, rotate2d
from repro.kernels.projective import chain_project, chain_project_batch
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rope import rope, rope_tables
from repro.kernels.ssd import ssd_intra

__all__ = [
    "dispatch", "opcount", "affine", "chain_diag", "chain_diag_batch",
    "scale", "translate", "vecadd", "attention", "blockwise_attention",
    "chain_apply", "chain_apply_batch", "chain_apply_batch_q",
    "chain_apply_q", "chain_diag_batch_q", "chain_diag_q", "chain_project",
    "chain_project_batch", "matmul", "rotate2d", "rmsnorm",
    "rope", "rope_tables", "ssd_intra",
]
