"""Pure-jnp RMSNorm oracle (fp32 statistics, LLaMA convention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x: jnp.ndarray, gain: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * gain.astype(jnp.float32)).astype(x.dtype)
