"""Public RMSNorm entry (fused derived-scalar scaling)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.rmsnorm import ref
from repro.kernels.rmsnorm import rmsnorm as K


def rmsnorm(x: jnp.ndarray, gain: jnp.ndarray, *, eps: float = 1e-6,
            backend: str | None = None) -> jnp.ndarray:
    """y = x / rms(x) * gain over the trailing dim of ``x`` (any rank).

    The paper's vector-scalar scaling with a *derived* scalar: the scale
    factor is computed from the row itself and fused into the same pass,
    so the row is read once.  ``gain`` is (N,); backend per
    ``repro.kernels.dispatch``.
    """
    b = dispatch.resolve(backend)
    if b == "ref":
        return ref.rmsnorm(x, gain, eps)
    n = x.shape[-1]
    out = K.rmsnorm_2d(x.reshape(-1, n), gain, eps=eps,
                       interpret=(b == "interpret"))
    return out.reshape(x.shape)
