"""Public RMSNorm entry (fused derived-scalar scaling)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.autotune import cache as tuning
from repro.autotune.cache import KernelConfig
from repro.kernels import dispatch
from repro.kernels.rmsnorm import ref
from repro.kernels.rmsnorm import rmsnorm as K


def rmsnorm(x: jnp.ndarray, gain: jnp.ndarray, *, eps: float = 1e-6,
            backend: str | None = None,
            config: KernelConfig | None = None) -> jnp.ndarray:
    """y = x / rms(x) * gain over the trailing dim of ``x`` (any rank).

    The paper's vector-scalar scaling with a *derived* scalar: the scale
    factor is computed from the row itself and fused into the same pass,
    so the row is read once.  ``gain`` is (N,); backend per
    ``repro.kernels.dispatch``.  Row-block size: explicit ``config``
    wins; otherwise the tuning cache is consulted when autotuning is
    enabled (rows normalise independently, so the block never changes
    results).
    """
    b = dispatch.resolve(backend)
    if b == "ref":
        return ref.rmsnorm(x, gain, eps)
    n = x.shape[-1]
    cfg = config or tuning.config_for("rmsnorm", b, str(jnp.dtype(x.dtype)),
                                      x.size)
    out = K.rmsnorm_2d(x.reshape(-1, n), gain, eps=eps,
                       interpret=(b == "interpret"),
                       block_rows=cfg.block_rows)
    return out.reshape(x.shape)
