"""Pallas TPU fused RMSNorm -- beyond-paper fusion of the scaling primitive.

The paper's vector-scalar op multiplies a vector by a constant held in the
context word.  RMSNorm is the same op with the "constant" *derived from the
data* (1/rms) and a learned per-channel gain -- fusing the reduction and the
scale into one VMEM-resident pass is the natural TPU extension (one HBM read
+ one HBM write instead of three passes).

Rows are normalised over the full trailing dim, so the block is
(block_rows, N) and N is NOT padded (padding would corrupt the mean); Mosaic
handles non-128-multiple trailing dims for full-width blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import SUBLANES, pad_axis, pick_block


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    o_ref[...] = (x * inv * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret",
                                              "block_rows"))
def rmsnorm_2d(x: jnp.ndarray, gain: jnp.ndarray, *, eps: float = 1e-6,
               interpret: bool = False,
               block_rows: int | None = None) -> jnp.ndarray:
    """``block_rows`` is the autotuner's row-block knob (``None`` = the
    historical 256); rows normalise independently, so the block choice
    never changes arithmetic."""
    m, n = x.shape
    bm = pick_block(m, block_rows or 256, SUBLANES)
    xp = pad_axis(x, 0, bm)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        interpret=interpret,
    )(xp, gain.reshape(1, n))
    return out[:m]
