"""Shared kernel utilities: alignment, padding, block-size selection.

TPU alignment discipline (the MorphoSys analogue of "one column = 8 cells"):
last dim in multiples of 128 lanes, second-to-last in multiples of 8
sublanes; MXU tiles are 128x128.  ``ops.py`` wrappers pad to block multiples
and slice back so the public API stays shape-polymorphic.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

LANES = 128
SUBLANES = 8

#: version-portable Pallas-TPU compiler params (renamed across jax versions)
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def pick_block(dim: int, preferred: int, align: int) -> int:
    """Largest aligned block <= preferred that is reasonable for ``dim``."""
    if dim <= preferred:
        return round_up(dim, align)
    return preferred


def lane_group(d: int) -> int:
    """lcm(d, LANES): the smallest lane width at which a d-wide point
    pattern is periodic and no point straddles a row edge.  The one
    source of truth for this quantity -- the chain stagers AND the
    autotune cost model build on it, so they cannot drift apart."""
    return d * LANES // math.gcd(d, LANES)


def packed_budget_rows(wr: int, itemsize: int) -> int:
    """Batch-axis block-row heuristic for ``stage_packed``: as many
    sublane-aligned rows as keep one ``wr``-lane input block inside a
    2 MiB VMEM budget (shared with the autotune cost model's feasibility
    and step accounting)."""
    budget_rows = max(1, (1 << 21) // (wr * max(1, itemsize)))
    return max(SUBLANES, budget_rows // SUBLANES * SUBLANES)


def chain_width(d: int, target: int = 512) -> int:
    """Lane width for the flattened point-buffer chain kernels.

    The fused transform-chain kernels view an (N, d) point array as one
    flat buffer reshaped to rows of ``w`` lanes, so ``w`` must be a
    multiple of both the lane count (alignment) and ``d`` (no point may
    straddle a row/block edge).  The smallest such width is
    lcm(d, LANES), scaled up toward ``target`` lanes per row.  ``target``
    is the autotuner's lane-packing knob (``KernelConfig.lane_target``).
    """
    base = lane_group(d)
    return base * max(1, target // base)


def stage_flat(flat: jnp.ndarray, d: int, *, block_rows: int | None = None,
               lane_target: int | None = None):
    """Stage a flat (N*d,) point buffer for the chain kernels: pad and
    reshape to (rows_p, w) blocks of ``w = chain_width(d)`` lanes and
    return ``(xp, lane_coord, bm, w)`` where ``lane_coord[j] = j % d`` is
    the coordinate index of each lane (for building d-periodic parameter
    rows).  Shared by ``chain_diag_1d`` and ``chain_matrix_1d`` so the
    blocking/padding discipline cannot diverge between them.
    ``block_rows``/``lane_target`` are the tuned launch parameters;
    ``None`` keeps the historical defaults (256-row blocks, ~512 lanes).
    Block choice never changes arithmetic -- the per-lane op sequence is
    identical under any staging, so tuned and default results are
    bit-identical."""
    (l,) = flat.shape
    w = chain_width(d, target=lane_target or 512)
    rows = cdiv(l, w)
    bm = pick_block(rows, block_rows or 256, SUBLANES)
    rows_p = round_up(rows, bm)
    xp = jnp.pad(flat, (0, rows_p * w - l)).reshape(rows_p, w)
    lane_coord = jnp.arange(w) % d
    return xp, lane_coord, bm, w


def stage_packed(pts3: jnp.ndarray, d: int, *, block_rows: int | None = None):
    """Stage a packed (B, L, d) point batch for the batched chain kernels.

    Each batch row is one request's flat point buffer (the serving engine's
    pack/pad product).  Rows are padded to ``wr`` lanes where ``wr`` is a
    multiple of ``g = lcm(d, LANES)`` -- so the per-coordinate parameter
    pattern is ``g``-periodic along every row and no point straddles a row
    edge -- and the batch dim is padded to a ``bm``-row block.  With
    ``block_rows=None`` (the default), ``bm`` shrinks as rows widen so an
    input block stays within a fixed VMEM budget (oversized single rows
    are the serving engine's shard cap's problem, not this stager's); a
    tuned ``block_rows`` pins the batch block directly.  Returns
    ``(xp (Bp, wr), lane_coord (g,), bm, g)`` with ``lane_coord[j] = j % d``.
    """
    b, l, _ = pts3.shape
    g = lane_group(d)
    wr = round_up(max(l * d, g), g)
    if block_rows is None:
        block_rows = packed_budget_rows(wr, pts3.dtype.itemsize)
    bm = pick_block(b, block_rows, SUBLANES)
    bp = round_up(b, bm)
    flat = pts3.reshape(b, l * d)
    xp = jnp.pad(flat, ((0, bp - b), (0, wr - l * d)))
    lane_coord = jnp.arange(g) % d
    return xp, lane_coord, bm, g


def pad_axis(x: jnp.ndarray, axis: int, multiple: int,
             value: float = 0.0) -> jnp.ndarray:
    size = x.shape[axis]
    target = round_up(size, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def pad2d(x: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    return pad_axis(pad_axis(x, -2, bm), -1, bn)
