"""Shared kernel utilities: alignment, padding, block-size selection.

TPU alignment discipline (the MorphoSys analogue of "one column = 8 cells"):
last dim in multiples of 128 lanes, second-to-last in multiples of 8
sublanes; MXU tiles are 128x128.  ``ops.py`` wrappers pad to block multiples
and slice back so the public API stays shape-polymorphic.
"""
from __future__ import annotations

import jax.numpy as jnp

LANES = 128
SUBLANES = 8


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def pick_block(dim: int, preferred: int, align: int) -> int:
    """Largest aligned block <= preferred that is reasonable for ``dim``."""
    if dim <= preferred:
        return round_up(dim, align)
    return preferred


def pad_axis(x: jnp.ndarray, axis: int, multiple: int,
             value: float = 0.0) -> jnp.ndarray:
    size = x.shape[axis]
    target = round_up(size, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def pad2d(x: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    return pad_axis(pad_axis(x, -2, bm), -1, bn)
