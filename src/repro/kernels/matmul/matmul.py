"""Pallas TPU tiled matmul -- the paper's section-5.3 matrix mapping.

The MorphoSys mapping streams rows of A through the context plane while rows
of B are broadcast to the array, accumulating in each cell's output register.
The MXU analogue: A and B tiles stream HBM->VMEM along the contraction grid
axis ("arbitrary" semantics = sequential, revisiting the same output block),
accumulating into an fp32 VMEM scratch -- the cell output register writ
large.  Block shapes default to MXU-native (128, 128) output tiles with a
512-deep K panel; working set 2*(bm*bk + bk*bn) + bm*bn*4 bytes stays well
under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import (LANES, SUBLANES, CompilerParams, pad_axis,
                                pick_block, stage_flat, stage_packed)


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype"))
def matmul_2d(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = 128, bn: int = 128,
              bk: int = 512, interpret: bool = False,
              out_dtype=None) -> jnp.ndarray:
    """C = X @ Y for X (M, K), Y (K, N); fp32 accumulation."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    out_dtype = out_dtype or x.dtype
    bm = pick_block(m, bm, SUBLANES)
    bn = pick_block(n, bn, LANES)
    bk = pick_block(k, bk, LANES)
    xp = pad_axis(pad_axis(x, 0, bm), 1, bk)
    yp = pad_axis(pad_axis(y, 0, bk), 1, bn)
    mp, kp = xp.shape
    np_ = yp.shape[1]
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]


# -- fused transform-chain kernel (the paper's one-pass composite) -----------
#
# A folded chain q = p @ A + t over (N, d) points with d in {2, 3} would
# waste 128/d of the lane bandwidth if lowered through the tiled matmul
# (the trailing dim pads 2 -> 128).  Instead the point buffer is kept
# flat and lane-dense: flat index j = point*d + coord, and
#
#   out[j] = sum_m x[point*d + m] * A[m, c] + t[c],   c = j mod d,
#
# becomes 2d-1 lane-rolled multiply-adds against precomputed d-periodic
# coefficient rows C_delta[j] = A[c+delta, c] (zero where c+delta falls
# outside [0, d)).  Rolls never mix points because chain_width(d) is a
# multiple of d, and wrapped lanes always carry a zero coefficient.  One
# HBM read of the points, one write, pure VPU work.

def _chain_matrix_kernel(x_ref, c_ref, t_ref, o_ref, *, d: int):
    x = x_ref[...]
    c = c_ref[...]
    acc = jnp.zeros_like(x) + t_ref[...]
    for i, delta in enumerate(range(-(d - 1), d)):
        acc = acc + jnp.roll(x, -delta, axis=1) * c[i:i + 1, :]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("d", "interpret", "block_rows",
                                              "lane_target"))
def chain_matrix_1d(flat: jnp.ndarray, a: jnp.ndarray, t: jnp.ndarray,
                    *, d: int, interpret: bool = False,
                    block_rows: int | None = None,
                    lane_target: int | None = None) -> jnp.ndarray:
    """Fused q = p @ A + t on the flat (N*d,) point buffer; A (d, d), t (d,).

    ``block_rows``/``lane_target`` are the autotuner's launch parameters
    (``None`` = historical defaults).  They steer staging only; the 2d-1
    rolled-MAC schedule per lane is identical under any staging, so every
    configuration produces bit-identical results."""
    (l,) = flat.shape
    if l == 0:
        return flat
    xp, lane_coord, bm, w = stage_flat(flat, d, block_rows=block_rows,
                                       lane_target=lane_target)
    coef = pad_axis(_coef_rows(a.astype(flat.dtype), lane_coord, d),
                    0, SUBLANES)                            # (8, w)
    trow = t.astype(flat.dtype)[lane_coord].reshape(1, w)
    out = pl.pallas_call(
        functools.partial(_chain_matrix_kernel, d=d),
        out_shape=jax.ShapeDtypeStruct(xp.shape, flat.dtype),
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, w), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANES, w), lambda i: (0, 0)),  # coefficient rows
            pl.BlockSpec((1, w), lambda i: (0, 0)),         # translation row
        ],
        out_specs=pl.BlockSpec((bm, w), lambda i: (i, 0)),
        interpret=interpret,
    )(xp, coef, trow)
    return out.reshape(-1)[:l]


def _coef_rows(a: jnp.ndarray, lane_coord: jnp.ndarray, d: int) -> jnp.ndarray:
    """The 2d-1 d-periodic coefficient patterns C_delta[j] = A[c+delta, c]
    for one composed matrix ``a`` (zero where c+delta falls outside [0, d));
    returns (2d-1, g) with g = len(lane_coord).  Shared by the single-chain
    and batched (vmapped) lowerings so the MAC schedule cannot diverge."""
    rows = []
    for delta in range(-(d - 1), d):
        src = lane_coord + delta
        valid = (src >= 0) & (src < d)
        rows.append(jnp.where(valid, a[jnp.clip(src, 0, d - 1), lane_coord],
                              jnp.zeros((), a.dtype)))
    return jnp.stack(rows)


def _chain_matrix_batch_kernel(x_ref, c_ref, t_ref, o_ref, *, d: int, g: int):
    x = x_ref[...]                                   # (bm, wr) -- bm requests
    bm, wr = x.shape
    reps = wr // g
    acc = jnp.zeros_like(x).reshape(bm, reps, g) + t_ref[...][:, None, :]
    for i, delta in enumerate(range(-(d - 1), d)):
        xr = jnp.roll(x, -delta, axis=1).reshape(bm, reps, g)
        acc = acc + xr * c_ref[...][:, i * g:(i + 1) * g][:, None, :]
    o_ref[...] = acc.reshape(bm, wr)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def chain_matrix_batch_2d(pts3: jnp.ndarray, a: jnp.ndarray, t: jnp.ndarray,
                          *, interpret: bool = False,
                          block_rows: int | None = None) -> jnp.ndarray:
    """Batched folded general chains: q[b] = p[b] @ A[b] + t[b].

    ``pts3`` is a packed (B, L, d) batch (one serving request per row,
    padded to a common L); ``a`` (B, d, d) / ``t`` (B, d) are per-request
    folded parameters.  Same 2d-1 lane-rolled MAC schedule as
    ``chain_matrix_1d`` -- rolls stay inside a block row, so they never
    mix requests, and wrapped lanes always meet a zero coefficient -- but
    the coefficient rows are *row-aligned* (request b's block row meets
    request b's coefficients), making a whole plan bucket one launch.
    ``block_rows`` pins the batch-axis block (the autotuner's knob;
    ``None`` = VMEM-budget heuristic).
    """
    b, l, d = pts3.shape
    if b == 0 or l == 0:
        return pts3
    xp, lane_coord, bm, g = stage_packed(pts3, d, block_rows=block_rows)
    coef = jax.vmap(lambda ab: _coef_rows(ab, lane_coord, d))(
        a.astype(pts3.dtype))                        # (B, 2d-1, g)
    coef = pad_axis(coef.reshape(b, (2 * d - 1) * g), 0, bm)
    trow = pad_axis(t.astype(pts3.dtype)[:, lane_coord], 0, bm)
    out = pl.pallas_call(
        functools.partial(_chain_matrix_batch_kernel, d=d, g=g),
        out_shape=jax.ShapeDtypeStruct(xp.shape, pts3.dtype),
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, xp.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bm, (2 * d - 1) * g), lambda i: (i, 0)),
            pl.BlockSpec((bm, g), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, xp.shape[1]), lambda i: (i, 0)),
        interpret=interpret,
    )(xp, coef, trow)
    return out[:b, :l * d].reshape(b, l, d)
