"""Pallas TPU tiled matmul -- the paper's section-5.3 matrix mapping.

The MorphoSys mapping streams rows of A through the context plane while rows
of B are broadcast to the array, accumulating in each cell's output register.
The MXU analogue: A and B tiles stream HBM->VMEM along the contraction grid
axis ("arbitrary" semantics = sequential, revisiting the same output block),
accumulating into an fp32 VMEM scratch -- the cell output register writ
large.  Block shapes default to MXU-native (128, 128) output tiles with a
512-deep K panel; working set 2*(bm*bk + bk*bn) + bm*bn*4 bytes stays well
under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import LANES, SUBLANES, pad_axis, pick_block


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype"))
def matmul_2d(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = 128, bn: int = 128,
              bk: int = 512, interpret: bool = False,
              out_dtype=None) -> jnp.ndarray:
    """C = X @ Y for X (M, K), Y (K, N); fp32 accumulation."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    out_dtype = out_dtype or x.dtype
    bm = pick_block(m, bm, SUBLANES)
    bn = pick_block(n, bn, LANES)
    bk = pick_block(k, bk, LANES)
    xp = pad_axis(pad_axis(x, 0, bm), 1, bk)
    yp = pad_axis(pad_axis(y, 0, bk), 1, bn)
    mp, kp = xp.shape
    np_ = yp.shape[1]
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]
