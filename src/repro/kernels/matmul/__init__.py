from repro.kernels.matmul.ops import (chain_apply, chain_apply_batch, matmul,
                                      rotate2d)

__all__ = ["chain_apply", "chain_apply_batch", "matmul", "rotate2d"]
