from repro.kernels.matmul.ops import matmul, rotate2d

__all__ = ["matmul", "rotate2d"]
