from repro.kernels.matmul.ops import chain_apply, matmul, rotate2d

__all__ = ["chain_apply", "matmul", "rotate2d"]
