"""Public matmul entry (paper section 5.3: rotation/composite transforms)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.matmul import matmul as K
from repro.kernels.matmul import ref


def matmul(x: jnp.ndarray, y: jnp.ndarray, *, backend: str | None = None,
           out_dtype=None, bm: int = 128, bn: int = 128, bk: int = 512) -> jnp.ndarray:
    """C = X @ Y with fp32 accumulation; X rank >= 2 (leading dims batched)."""
    b = dispatch.resolve(backend)
    if b == "ref":
        return ref.matmul(x, y, out_dtype=out_dtype)
    lead = x.shape[:-2]
    x2 = x.reshape(-1, x.shape[-1]) if lead else x
    out = K.matmul_2d(x2, y, bm=bm, bn=bn, bk=bk,
                      interpret=(b == "interpret"), out_dtype=out_dtype)
    return out.reshape(*lead, x.shape[-2] if lead else out.shape[0], y.shape[-1]) \
        if lead else out


def rotate2d(points: jnp.ndarray, theta, *, backend: str | None = None) -> jnp.ndarray:
    """Rotate (..., 2) points by angle theta -- the paper's rotation
    transformation as a 2x2 matmul."""
    c, s = jnp.cos(theta), jnp.sin(theta)
    rot = jnp.array([[c, s], [-s, c]], points.dtype)  # right-multiply form
    return matmul(points.reshape(-1, 2), rot, backend=backend).reshape(points.shape)
