"""Public matmul entry (paper section 5.3: rotation/composite transforms)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.autotune import cache as tuning
from repro.autotune.cache import KernelConfig
from repro.kernels import dispatch, opcount
from repro.kernels.matmul import matmul as K
from repro.kernels.matmul import ref


def matmul(x: jnp.ndarray, y: jnp.ndarray, *, backend: str | None = None,
           out_dtype=None, bm: int | None = None, bn: int | None = None,
           bk: int | None = None) -> jnp.ndarray:
    """C = X @ Y with fp32 accumulation; X rank >= 2 (leading dims batched).

    Tile shape: explicit ``bm``/``bn``/``bk`` win; otherwise the tuning
    cache is consulted when autotuning is enabled, else the MXU-native
    (128, 128, 512) defaults.  Tile choice never changes results -- the
    contraction accumulates in the same fp32 VMEM scratch per output tile.
    """
    out_itemsize = jnp.dtype(out_dtype or x.dtype).itemsize
    out_elems = x.size // x.shape[-1] * y.shape[-1]
    opcount.record("matmul", x.nbytes + y.nbytes + out_elems * out_itemsize)
    b = dispatch.resolve(backend)
    if b == "ref":
        return ref.matmul(x, y, out_dtype=out_dtype)
    if bm is None or bn is None or bk is None:
        cfg = tuning.config_for("matmul", b, str(jnp.dtype(x.dtype)),
                                out_elems)
        bm, bn, bk = bm or cfg.bm or 128, bn or cfg.bn or 128, \
            bk or cfg.bk or 512
    lead = x.shape[:-2]
    x2 = x.reshape(-1, x.shape[-1]) if lead else x
    out = K.matmul_2d(x2, y, bm=bm, bn=bn, bk=bk,
                      interpret=(b == "interpret"), out_dtype=out_dtype)
    return out.reshape(*lead, x.shape[-2] if lead else out.shape[0], y.shape[-1]) \
        if lead else out


def rotate2d(points: jnp.ndarray, theta, *, backend: str | None = None) -> jnp.ndarray:
    """Rotate (..., 2) points by angle theta -- the paper's rotation
    transformation as a 2x2 matmul."""
    c, s = jnp.cos(theta), jnp.sin(theta)
    rot = jnp.array([[c, s], [-s, c]], points.dtype)  # right-multiply form
    return matmul(points.reshape(-1, 2), rot, backend=backend).reshape(points.shape)


def chain_apply(points: jnp.ndarray, a: jnp.ndarray, t: jnp.ndarray, *,
                backend: str | None = None,
                config: KernelConfig | None = None) -> jnp.ndarray:
    """Folded transform chain q = p @ A + t in one fused pass.

    ``points`` is (..., d); ``a`` is the composed (d, d) linear part and
    ``t`` the composed (d,) translation.  Lowered to the lane-dense
    ``chain_matrix_1d`` kernel (2d-1 rolled multiply-adds on the flat
    buffer): one HBM read of the points, one write, no homogeneous-column
    materialisation and no 128-lane padding of the d-wide trailing axis.
    Lowering target for general ``TransformChain`` plans; chain-level byte
    accounting happens in ``TransformChain.apply``.
    """
    b = dispatch.resolve(backend)
    d = points.shape[-1]
    a = jnp.asarray(a)
    t = jnp.asarray(t)
    if b == "ref":
        return ref.chain_matrix(points, a, t)
    cfg = config or KernelConfig("chain_apply")
    out = K.chain_matrix_1d(points.reshape(-1), a, t, d=d,
                            interpret=(b == "interpret"),
                            block_rows=cfg.block_rows,
                            lane_target=cfg.lane_target)
    return out.reshape(points.shape)


def chain_apply_batch(pts3: jnp.ndarray, a: jnp.ndarray, t: jnp.ndarray, *,
                      backend: str | None = None,
                      config: KernelConfig | None = None) -> jnp.ndarray:
    """Batched folded general chains: q[b] = p[b] @ A[b] + t[b].

    ``pts3`` is a packed (B, L, d) batch -- one serving request per row,
    padded to a common length L; ``a`` (B, d, d) / ``t`` (B, d) are
    per-request folded parameters.  One launch serves the whole batch; on
    ``ref`` the oracle is the per-request ``chain_matrix`` under
    ``jax.vmap`` (same unrolled MAC order per row -- the serving engine's
    bit-identity contract), on ``pallas``/``interpret`` the row-aligned
    ``chain_matrix_batch_2d`` kernel.  Called under jit inside the serving
    engine's compiled bucket plans; packed-batch byte accounting happens
    there via ``opcount.packed_chain_bytes``.
    """
    b = dispatch.resolve(backend)
    a = jnp.asarray(a)
    t = jnp.asarray(t)
    if b == "ref":
        return jax.vmap(ref.chain_matrix)(pts3, a, t)
    cfg = config or KernelConfig("chain_apply_batch")
    return K.chain_matrix_batch_2d(pts3, a, t, interpret=(b == "interpret"),
                                   block_rows=cfg.block_rows)
