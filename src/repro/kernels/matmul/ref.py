"""Pure-jnp oracle for the tiled matmul (fp32 accumulation semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jnp.ndarray, y: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or x.dtype
    acc = jax.lax.dot_general(x, y, (((x.ndim - 1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)
