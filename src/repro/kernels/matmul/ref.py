"""Pure-jnp oracle for the tiled matmul (fp32 accumulation semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jnp.ndarray, y: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or x.dtype
    acc = jax.lax.dot_general(x, y, (((x.ndim - 1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


def chain_matrix(p: jnp.ndarray, a: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Folded transform chain q = p @ A + t for (..., d) points; A (d, d),
    t (d,) -- the one-pass composite oracle (fp32 accumulation).

    For the point dims that occur in practice (d <= 4) the contraction is
    unrolled into d^2 fused multiply-adds: a (N, 2) @ (2, 2) dot_general is
    a degenerate matmul that XLA CPU executes far slower than the
    equivalent elementwise expression, and the unrolled form fuses into
    the single memory pass the fused chain is meant to be."""
    a = jnp.asarray(a, jnp.float32)
    t = jnp.asarray(t, jnp.float32)
    d = p.shape[-1]
    if d <= 4:
        pf = p.astype(jnp.float32)
        cols = [sum(pf[..., m] * a[m, c] for m in range(d)) + t[c]
                for c in range(d)]
        return jnp.stack(cols, axis=-1).astype(p.dtype)
    acc = jax.lax.dot_general(p, a.astype(p.dtype),
                              (((p.ndim - 1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return (acc + t).astype(p.dtype)
