from repro.kernels.fixedpoint.ops import (chain_apply_batch_q, chain_apply_q,
                                          chain_diag_batch_q, chain_diag_q)

__all__ = ["chain_diag_q", "chain_apply_q", "chain_diag_batch_q",
           "chain_apply_batch_q"]
