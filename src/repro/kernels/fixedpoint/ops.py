"""Public entries for the fixed-point chain family (Qm.n int16 lane).

Mirrors the float chain entries (``kernels.chain_diag`` /
``chain_apply`` and their batch forms) with int16 Qm.n operands and an
explicit ``n_frac``.  All operands are already-quantised int16 words --
quantisation happens upstream, once per folded chain, in
``repro.quantize.quantize_fold`` (the chain compiler and the serving
engine both call it there), so these entries never touch floats.
Backend dispatch per ``repro.kernels.dispatch``; on ``ref`` the oracle
is the traceable jnp twin of the numpy Q oracle (bit-identical -- the
arithmetic is integer).  Called under jit inside compiled plans;
chain-level byte accounting happens in ``TransformChain.apply`` and the
serving engine (2-byte words -- the lane's whole perf case).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.autotune.cache import KernelConfig
from repro.kernels import dispatch
from repro.kernels.fixedpoint import fixedpoint as K
from repro.kernels.fixedpoint import ref


def _as_q(x, shape) -> jnp.ndarray:
    q = jnp.asarray(x)
    if q.dtype != jnp.int16:
        raise TypeError(f"fixed-point operands must be int16 Qm.n words, "
                        f"got {q.dtype} (quantise first -- see "
                        "repro.quantize)")
    return jnp.broadcast_to(q, shape)


def chain_diag_q(points: jnp.ndarray, s, t, *, n_frac: int,
                 backend: str | None = None,
                 config: KernelConfig | None = None) -> jnp.ndarray:
    """Folded diagonal chain q = requant(s (.) p + t) in one fused pass
    over (..., d) int16 Qm.n points; ``s``/``t`` are (d,) int16 words,
    ``n_frac`` the shared fraction-bit count."""
    b = dispatch.resolve(backend)
    d = points.shape[-1]
    s = _as_q(s, (d,))
    t = _as_q(t, (d,))
    if b == "ref":
        return ref.chain_diag_q(points, s, t, n_frac)
    cfg = config or KernelConfig("chain_diag_q")
    out = K.chain_diag_1d_q(points.reshape(-1), s, t, d=d, n_frac=n_frac,
                            interpret=(b == "interpret"),
                            block_rows=cfg.block_rows,
                            lane_target=cfg.lane_target)
    return out.reshape(points.shape)


def chain_apply_q(points: jnp.ndarray, a, t, *, n_frac: int,
                  backend: str | None = None,
                  config: KernelConfig | None = None) -> jnp.ndarray:
    """Folded general chain q = requant(p @ A + t) in one fused pass;
    ``a`` (d, d) / ``t`` (d,) int16 Qm.n words."""
    b = dispatch.resolve(backend)
    d = points.shape[-1]
    a = _as_q(a, (d, d))
    t = _as_q(t, (d,))
    if b == "ref":
        return ref.chain_matrix_q(points, a, t, n_frac)
    cfg = config or KernelConfig("chain_apply_q")
    out = K.chain_matrix_1d_q(points.reshape(-1), a, t, d=d, n_frac=n_frac,
                              interpret=(b == "interpret"),
                              block_rows=cfg.block_rows,
                              lane_target=cfg.lane_target)
    return out.reshape(points.shape)


def chain_diag_batch_q(pts3: jnp.ndarray, s, t, *, n_frac: int,
                       backend: str | None = None,
                       config: KernelConfig | None = None) -> jnp.ndarray:
    """Batched folded diagonal chains on a packed int16 (B, L, d) batch;
    ``s``/``t`` (B, d) per-request Qm.n words.  One launch per bucket, as
    on the float lane; integer arithmetic makes the per-request results
    bit-identical to per-request ``chain_diag_q`` on EVERY backend."""
    bsz, _, d = pts3.shape
    s = _as_q(s, (bsz, d))
    t = _as_q(t, (bsz, d))
    b = dispatch.resolve(backend)
    if b == "ref":
        return jax.vmap(lambda p, sb, tb: ref.chain_diag_q(p, sb, tb,
                                                           n_frac))(
            pts3, s, t)
    cfg = config or KernelConfig("chain_diag_batch_q")
    return K.chain_diag_batch_2d_q(pts3, s, t, n_frac=n_frac,
                                   interpret=(b == "interpret"),
                                   block_rows=cfg.block_rows)


def chain_apply_batch_q(pts3: jnp.ndarray, a, t, *, n_frac: int,
                        backend: str | None = None,
                        config: KernelConfig | None = None) -> jnp.ndarray:
    """Batched folded general chains on a packed int16 (B, L, d) batch;
    ``a`` (B, d, d) / ``t`` (B, d) per-request Qm.n words."""
    bsz, _, d = pts3.shape
    a = _as_q(a, (bsz, d, d))
    t = _as_q(t, (bsz, d))
    b = dispatch.resolve(backend)
    if b == "ref":
        return jax.vmap(lambda p, ab, tb: ref.chain_matrix_q(p, ab, tb,
                                                             n_frac))(
            pts3, a, t)
    cfg = config or KernelConfig("chain_apply_batch_q")
    return K.chain_matrix_batch_2d_q(pts3, a, t, n_frac=n_frac,
                                     interpret=(b == "interpret"),
                                     block_rows=cfg.block_rows)
