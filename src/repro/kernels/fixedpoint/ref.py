"""Q-arithmetic oracles for the fixed-point chain kernels.

Two twins of the SAME arithmetic, asserted bit-identical by
``tests/test_fixedpoint.py``:

  * ``np_chain_diag_q`` / ``np_chain_matrix_q`` -- the pure-numpy Qm.n
    oracle: int32 multiply-accumulate, one requantising shift
    ``(acc + 2**(n-1)) >> n``, int16 wrap.  This is the ground truth the
    Pallas kernels are tested against, and at n = 0 it is bit-for-bit
    the ``core.morphosys`` emulator's integer datapath (int16 wrap-around
    is a ring homomorphism: accumulating wide and wrapping once equals
    wrapping every step, as the M1 ALU does).
  * ``chain_diag_q`` / ``chain_matrix_q`` -- the traceable jnp twins the
    ``ref`` backend dispatches to (the serving engine jits its bucket
    plans, so the ref path must trace).  Integer ops are exact and
    order-independent, so the two twins cannot diverge.

All overflow wraps mod 2**32 in the accumulator and mod 2**16 at the
output -- everywhere, including numpy (``errstate(over="ignore")``), so
the three execution paths (numpy, jnp ref, Pallas) share ONE semantics.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _np_requant(acc: np.ndarray, n_frac: int) -> np.ndarray:
    """int32 accumulator -> int16 words: round-half-up shift, then wrap."""
    with np.errstate(over="ignore"):
        if n_frac:
            acc = (acc + np.int32(1 << (n_frac - 1))) >> n_frac
    return (acc & 0xFFFF).astype(np.uint16).view(np.int16).copy()


def np_chain_diag_q(p: np.ndarray, s: np.ndarray, t: np.ndarray,
                    n_frac: int) -> np.ndarray:
    """Numpy Q oracle, diagonal plan: q = requant(p*s + (t << n))."""
    with np.errstate(over="ignore"):
        acc = (np.asarray(p, np.int16).astype(np.int32)
               * np.asarray(s, np.int16).astype(np.int32)
               + (np.asarray(t, np.int16).astype(np.int32) << n_frac))
    return _np_requant(acc, n_frac)


def np_chain_matrix_q(p: np.ndarray, a: np.ndarray, t: np.ndarray,
                      n_frac: int) -> np.ndarray:
    """Numpy Q oracle, matrix plan: q = requant(p @ A + (t << n)) over
    (..., d) int16 points; A (d, d), t (d,) int16 words."""
    p32 = np.asarray(p, np.int16).astype(np.int32)
    a32 = np.asarray(a, np.int16).astype(np.int32)
    t32 = np.asarray(t, np.int16).astype(np.int32)
    d = p32.shape[-1]
    with np.errstate(over="ignore"):
        cols = [
            sum(p32[..., m] * a32[m, c] for m in range(d)) + (t32[c] << n_frac)
            for c in range(d)
        ]
        acc = np.stack(cols, axis=-1).astype(np.int32)
    return _np_requant(acc, n_frac)


# -- traceable jnp twins (the ``ref`` dispatch target) ------------------------

def _requant(acc, n_frac: int):
    if n_frac:
        acc = (acc + jnp.int32(1 << (n_frac - 1))) >> n_frac
    return acc.astype(jnp.int16)


def chain_diag_q(p, s, t, n_frac: int):
    """jnp Q oracle, diagonal plan (bit-identical to ``np_chain_diag_q``)."""
    acc = (jnp.asarray(p, jnp.int16).astype(jnp.int32)
           * jnp.asarray(s, jnp.int16).astype(jnp.int32)
           + (jnp.asarray(t, jnp.int16).astype(jnp.int32) << n_frac))
    return _requant(acc, n_frac)


def chain_matrix_q(p, a, t, n_frac: int):
    """jnp Q oracle, matrix plan (bit-identical to ``np_chain_matrix_q``)."""
    p32 = jnp.asarray(p, jnp.int16).astype(jnp.int32)
    a32 = jnp.asarray(a, jnp.int16).astype(jnp.int32)
    t32 = jnp.asarray(t, jnp.int16).astype(jnp.int32)
    d = p32.shape[-1]
    cols = [
        sum(p32[..., m] * a32[m, c] for m in range(d)) + (t32[c] << n_frac)
        for c in range(d)
    ]
    return _requant(jnp.stack(cols, axis=-1), n_frac)
