"""Pallas TPU kernels for fused fixed-point (Qm.n) transform chains.

The M1's RC array executes the paper's transforms on 16-bit integer ALUs;
this module is that datapath on the TPU mapping.  The kernels mirror the
float chain kernels lane for lane -- ``chain_diag_1d_q`` is
``chain_diag_1d`` and ``chain_matrix_1d_q`` is ``chain_matrix_1d`` with
the same staging (``stage_flat``/``stage_packed``), the same d-periodic
context-word parameter rows, and the same 2d-1 lane-rolled MAC schedule
(``_coef_rows`` is literally shared) -- but the arithmetic is the M1's:

  * the point buffer lives in HBM as int16 Qm.n words -- HALF the bytes
    per point of the float32 lane, which is the whole perf case;
  * multiply-accumulate runs in int32 (products carry scale 2**2n; the
    translation row is aligned up by ``<< n``), exact and
    order-independent, so every backend is bit-identical;
  * ONE requantising shift ``(acc + 2**(n-1)) >> n`` brings the result
    back to Qm.n, and the store wraps to int16 -- wrap-around, never
    saturation, exactly like ``core.morphosys.rc_array`` (at n = 0 the
    shift vanishes and the lane IS the emulator's integer datapath).

``block_rows``/``lane_target`` are the autotuner's launch parameters, as
on the float kernels: staging-only, never arithmetic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.matmul.matmul import _coef_rows
from repro.kernels.util import SUBLANES, pad_axis, stage_flat, stage_packed


def _requant_store(acc, n_frac: int):
    """The single requantising shift + int16 wrap (see module docstring)."""
    if n_frac:
        acc = (acc + jnp.int32(1 << (n_frac - 1))) >> n_frac
    return acc.astype(jnp.int16)


def _chain_diag_q_kernel(x_ref, s_ref, t_ref, o_ref, *, n_frac: int):
    x = x_ref[...].astype(jnp.int32)
    s = s_ref[...].astype(jnp.int32)
    t = t_ref[...].astype(jnp.int32) << n_frac
    o_ref[...] = _requant_store(x * s + t, n_frac)


@functools.partial(jax.jit, static_argnames=("d", "n_frac", "interpret",
                                             "block_rows", "lane_target"))
def chain_diag_1d_q(flat: jnp.ndarray, s: jnp.ndarray, t: jnp.ndarray,
                    *, d: int, n_frac: int, interpret: bool = False,
                    block_rows: int | None = None,
                    lane_target: int | None = None) -> jnp.ndarray:
    """Folded diagonal chain on the flat int16 Qm.n point buffer.

    ``flat`` is an (N*d,) int16 view of (N, d) points; ``s``/``t`` are
    (d,) int16 Qm.n words.  Same staging as ``chain_diag_1d`` (rows of
    ``chain_width(d)`` lanes, d-periodic parameter rows staged once per
    block); int32 MAC + one shift per lane.  One HBM read of the points,
    one write -- at HALF the float32 byte volume."""
    (l,) = flat.shape
    if l == 0:
        return flat
    xp, lane_coord, bm, w = stage_flat(flat, d, block_rows=block_rows,
                                       lane_target=lane_target)
    srow = s.astype(jnp.int16)[lane_coord].reshape(1, w)
    trow = t.astype(jnp.int16)[lane_coord].reshape(1, w)
    out = pl.pallas_call(
        functools.partial(_chain_diag_q_kernel, n_frac=n_frac),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.int16),
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),   # context-word params
            pl.BlockSpec((1, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, w), lambda i: (i, 0)),
        interpret=interpret,
    )(xp, srow, trow)
    return out.reshape(-1)[:l]


def _chain_matrix_q_kernel(x_ref, c_ref, t_ref, o_ref, *, d: int,
                           n_frac: int):
    x = x_ref[...].astype(jnp.int32)
    c = c_ref[...].astype(jnp.int32)
    acc = jnp.zeros_like(x) + (t_ref[...].astype(jnp.int32) << n_frac)
    for i, delta in enumerate(range(-(d - 1), d)):
        acc = acc + jnp.roll(x, -delta, axis=1) * c[i:i + 1, :]
    o_ref[...] = _requant_store(acc, n_frac)


@functools.partial(jax.jit, static_argnames=("d", "n_frac", "interpret",
                                             "block_rows", "lane_target"))
def chain_matrix_1d_q(flat: jnp.ndarray, a: jnp.ndarray, t: jnp.ndarray,
                      *, d: int, n_frac: int, interpret: bool = False,
                      block_rows: int | None = None,
                      lane_target: int | None = None) -> jnp.ndarray:
    """Fused q = requant(p @ A + t) on the flat int16 buffer; A (d, d),
    t (d,) int16 Qm.n words.  The 2d-1 rolled-MAC schedule is the float
    kernel's (``_coef_rows`` shared), so the two lanes cannot diverge in
    anything but arithmetic width."""
    (l,) = flat.shape
    if l == 0:
        return flat
    xp, lane_coord, bm, w = stage_flat(flat, d, block_rows=block_rows,
                                       lane_target=lane_target)
    coef = pad_axis(_coef_rows(a.astype(jnp.int16), lane_coord, d),
                    0, SUBLANES)                            # (8, w)
    trow = t.astype(jnp.int16)[lane_coord].reshape(1, w)
    out = pl.pallas_call(
        functools.partial(_chain_matrix_q_kernel, d=d, n_frac=n_frac),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.int16),
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, w), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANES, w), lambda i: (0, 0)),  # coefficient rows
            pl.BlockSpec((1, w), lambda i: (0, 0)),         # translation row
        ],
        out_specs=pl.BlockSpec((bm, w), lambda i: (i, 0)),
        interpret=interpret,
    )(xp, coef, trow)
    return out.reshape(-1)[:l]


def _chain_diag_batch_q_kernel(x_ref, s_ref, t_ref, o_ref, *, g: int,
                               n_frac: int):
    x = x_ref[...].astype(jnp.int32)                 # (bm, wr) -- bm requests
    bm, wr = x.shape
    x3 = x.reshape(bm, wr // g, g)
    s = s_ref[...].astype(jnp.int32)[:, None, :]     # per-request params,
    t = (t_ref[...].astype(jnp.int32) << n_frac)[:, None, :]
    o_ref[...] = _requant_store((x3 * s + t).reshape(bm, wr), n_frac)


@functools.partial(jax.jit, static_argnames=("n_frac", "interpret",
                                             "block_rows"))
def chain_diag_batch_2d_q(pts3: jnp.ndarray, s: jnp.ndarray, t: jnp.ndarray,
                          *, n_frac: int, interpret: bool = False,
                          block_rows: int | None = None) -> jnp.ndarray:
    """Batched folded diagonal chains on a packed int16 (B, L, d) batch;
    ``s``/``t`` are (B, d) per-request Qm.n words, row-aligned with the
    batch exactly like ``chain_diag_batch_2d``."""
    b, l, d = pts3.shape
    if b == 0 or l == 0:
        return pts3
    xp, lane_coord, bm, g = stage_packed(pts3, d, block_rows=block_rows)
    srow = pad_axis(s.astype(jnp.int16)[:, lane_coord], 0, bm)      # (Bp, g)
    trow = pad_axis(t.astype(jnp.int16)[:, lane_coord], 0, bm)
    out = pl.pallas_call(
        functools.partial(_chain_diag_batch_q_kernel, g=g, n_frac=n_frac),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.int16),
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, xp.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bm, g), lambda i: (i, 0)),  # row-aligned params
            pl.BlockSpec((bm, g), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, xp.shape[1]), lambda i: (i, 0)),
        interpret=interpret,
    )(xp, srow, trow)
    return out[:b, :l * d].reshape(b, l, d)


def _chain_matrix_batch_q_kernel(x_ref, c_ref, t_ref, o_ref, *, d: int,
                                 g: int, n_frac: int):
    x = x_ref[...].astype(jnp.int32)                 # (bm, wr) -- bm requests
    bm, wr = x.shape
    reps = wr // g
    t = (t_ref[...].astype(jnp.int32) << n_frac)[:, None, :]
    acc = jnp.zeros_like(x).reshape(bm, reps, g) + t
    c = c_ref[...].astype(jnp.int32)
    for i, delta in enumerate(range(-(d - 1), d)):
        xr = jnp.roll(x, -delta, axis=1).reshape(bm, reps, g)
        acc = acc + xr * c[:, i * g:(i + 1) * g][:, None, :]
    o_ref[...] = _requant_store(acc.reshape(bm, wr), n_frac)


@functools.partial(jax.jit, static_argnames=("n_frac", "interpret",
                                             "block_rows"))
def chain_matrix_batch_2d_q(pts3: jnp.ndarray, a: jnp.ndarray,
                            t: jnp.ndarray, *, n_frac: int,
                            interpret: bool = False,
                            block_rows: int | None = None) -> jnp.ndarray:
    """Batched folded general chains on a packed int16 (B, L, d) batch;
    ``a`` (B, d, d) / ``t`` (B, d) are per-request Qm.n words.  Same
    row-aligned 2d-1 rolled-MAC schedule as ``chain_matrix_batch_2d``
    (rolls never mix requests; wrapped lanes meet zero coefficients)."""
    b, l, d = pts3.shape
    if b == 0 or l == 0:
        return pts3
    xp, lane_coord, bm, g = stage_packed(pts3, d, block_rows=block_rows)
    coef = jax.vmap(lambda ab: _coef_rows(ab, lane_coord, d))(
        a.astype(jnp.int16))                         # (B, 2d-1, g)
    coef = pad_axis(coef.reshape(b, (2 * d - 1) * g), 0, bm)
    trow = pad_axis(t.astype(jnp.int16)[:, lane_coord], 0, bm)
    out = pl.pallas_call(
        functools.partial(_chain_matrix_batch_q_kernel, d=d, g=g,
                          n_frac=n_frac),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.int16),
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, xp.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bm, (2 * d - 1) * g), lambda i: (i, 0)),
            pl.BlockSpec((bm, g), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, xp.shape[1]), lambda i: (i, 0)),
        interpret=interpret,
    )(xp, coef, trow)
    return out[:b, :l * d].reshape(b, l, d)
