"""HBM-traffic accounting for the eager kernel-dispatch path.

The paper's central perf argument is byte economy: a composite transform
that runs as one pass over the RC array moves (k+1)x fewer frame-buffer
bytes than k sequential primitive passes.  This module makes the same
accounting observable on the TPU mapping: every public op entry records
``(op_name, bytes_moved)`` -- bytes read from plus written to HBM under
the memory-bound model (inputs + outputs, parameters included) -- while a
``counting()`` scope is active.

Records fire when the op *entry* executes, i.e. on every call on the
eager path but only once (at trace time) under ``jax.jit``.  That is the
intended use: tests and benchmarks compare eager sequential dispatch
against the fused chain path, whose single record is emitted by
``TransformChain.apply`` outside the jitted plan.
"""
from __future__ import annotations

import contextlib

_ACTIVE: list[tuple[str, int]] | None = None


@contextlib.contextmanager
def counting():
    """Collect ``(op, nbytes)`` records emitted inside the scope."""
    global _ACTIVE
    prev, records = _ACTIVE, []
    _ACTIVE = records
    try:
        yield records
    finally:
        _ACTIVE = prev


def record(op: str, nbytes: int) -> None:
    if _ACTIVE is not None:
        _ACTIVE.append((op, int(nbytes)))


def total_bytes(records: list[tuple[str, int]]) -> int:
    return sum(b for _, b in records)
