"""HBM-traffic accounting for the eager kernel-dispatch path.

The paper's central perf argument is byte economy: a composite transform
that runs as one pass over the RC array moves (k+1)x fewer frame-buffer
bytes than k sequential primitive passes.  This module makes the same
accounting observable on the TPU mapping: every public op entry records
``(op_name, bytes_moved)`` -- bytes read from plus written to HBM under
the memory-bound model (inputs + outputs, parameters included) -- while a
``counting()`` scope is active.

Records fire when the op *entry* executes, i.e. on every call on the
eager path but only once (at trace time) under ``jax.jit``.  That is the
intended use: tests and benchmarks compare eager sequential dispatch
against the fused chain path, whose single record is emitted by
``TransformChain.apply`` outside the jitted plan.
"""
from __future__ import annotations

import contextlib

_ACTIVE: list[tuple[str, int]] | None = None


@contextlib.contextmanager
def counting():
    """Collect ``(op, nbytes)`` records emitted inside the scope."""
    global _ACTIVE
    prev, records = _ACTIVE, []
    _ACTIVE = records
    try:
        yield records
    finally:
        _ACTIVE = prev


def record(op: str, nbytes: int) -> None:
    if _ACTIVE is not None:
        _ACTIVE.append((op, int(nbytes)))


def total_bytes(records: list[tuple[str, int]]) -> int:
    return sum(b for _, b in records)


def chain_param_words(d: int, kind: str) -> int:
    """Composed-parameter words of one folded chain, by plan kind: (s, t)
    for diag, (A, t) for matrix, (H, lo, hi) for projective.  The ONE
    table -- ``TransformChain``'s byte records, the serving engine's
    packed accounting, and the autotune cost model all read it here, so
    the three cannot drift."""
    return {"diag": 2 * d, "matrix": d * d + d,
            "projective": (d + 1) ** 2 + 2 * d}[kind]


def chain_passes(kind: str) -> int:
    """HBM passes of one fused chain launch: read + write, plus the
    point-buffer-width cull-mask write for projective plans."""
    return 3 if kind == "projective" else 2


def fused_chain_bytes(n_points: int, d: int, *, itemsize: int = 4,
                      kind: str = "matrix") -> int:
    """HBM bytes moved by ONE fused single-chain launch over (N, d)
    points (memory-bound model): the point buffer once in and once out
    (plus the mask pass for projective plans) and the composed-parameter
    words, at ``itemsize`` bytes per word -- 4 on the float32 lane, 2 on
    the int16 fixed-point lane (the lane's whole perf case: the same
    chain moves half the bytes).  The ONE formula shared by
    ``TransformChain``'s records, the autotune cost model, and the
    fixed-point benchmark's f32-vs-q comparison."""
    return (chain_passes(kind) * n_points * d * itemsize
            + chain_param_words(d, kind) * itemsize)


def packed_chain_bytes(bsz: int, lpad: int, d: int, *, itemsize: int = 4,
                       kind: str = "matrix") -> int:
    """HBM bytes moved by one packed-batch chain launch (memory-bound model).

    A bucket of ``bsz`` requests packed to ``lpad`` points each moves the
    padded point buffer once in and once out (2*B*L*d*itemsize) plus the
    per-request folded parameters -- (d, d) + (d,) words for a ``matrix``
    plan, (d,) + (d,) for a ``diag`` plan, and (d+1)^2 homogeneous words
    plus the 2d cull bounds for a ``projective`` plan (which also writes
    a third, mask-sized pass: the in-kernel frustum-cull mask leaves at
    point-buffer width).  Per-request dispatch of the same bucket moves
    2*sum(n_i)*d*itemsize payload bytes but pays one launch per request;
    the packed launch trades (lpad - n_i) rows of padding per request for
    a Bx launch reduction.  The serving engine records this number per
    launch, so tests can assert both sides of that trade (waste cap,
    launch economy).
    """
    return (chain_passes(kind) * bsz * lpad * d * itemsize
            + bsz * chain_param_words(d, kind) * itemsize)
