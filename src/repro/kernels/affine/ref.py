"""Pure-jnp oracles for the affine kernel family (paper sections 5.1-5.2)."""
from __future__ import annotations

import jax.numpy as jnp


def affine(x: jnp.ndarray, s: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """y = s*x + t; s/t broadcast against x's trailing dims."""
    return (x * jnp.asarray(s, x.dtype) + jnp.asarray(t, x.dtype)).astype(x.dtype)


def vecadd(x: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    return (x + z.astype(x.dtype)).astype(x.dtype)


def translate(p: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """q = p + t (paper section 4, Translations)."""
    return vecadd(p, jnp.broadcast_to(jnp.asarray(t, p.dtype), p.shape))


def scale(p: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """q = S x p with diagonal S (paper section 4, Scaling)."""
    return (p * jnp.asarray(s, p.dtype)).astype(p.dtype)


def chain_diag(p: jnp.ndarray, s: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Folded diagonal transform chain: q = s (.) p + t, s/t (d,) rows
    broadcast over (..., d) points -- the one-pass composite oracle."""
    return affine(p, s, t)
