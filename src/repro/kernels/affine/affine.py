"""Pallas TPU kernels for the paper's vector-vector / vector-scalar ops.

This is the direct TPU re-expression of sections 5.1-5.2: the context word
becomes the kernel body, the column broadcast becomes the grid, and the
double-banked frame buffer becomes the (automatically double-buffered)
HBM->VMEM block pipeline that `BlockSpec` index maps describe.

Two bodies cover all four public ops:

  * ``_affine_kernel``  -- y = s (.) x + t with s, t broadcast row
    parameters staged once per column block (the "context word immediate"
    of Table 2, generalised from a scalar to a (1, bn) vector);
  * ``_vecadd_kernel``  -- y = x (+) z elementwise, both operands streamed
    through the double-buffered pipeline (Table 1's dbcdc).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import LANES, SUBLANES, cdiv, pad2d, pick_block


def _affine_kernel(x_ref, s_ref, t_ref, o_ref):
    o_ref[...] = x_ref[...] * s_ref[...] + t_ref[...]


def _vecadd_kernel(x_ref, z_ref, o_ref):
    o_ref[...] = x_ref[...] + z_ref[...]


def _blocks(m: int, n: int) -> tuple[int, int]:
    return pick_block(m, 256, SUBLANES), pick_block(n, 512, LANES)


@functools.partial(jax.jit, static_argnames=("interpret",))
def affine_2d(x: jnp.ndarray, s: jnp.ndarray, t: jnp.ndarray,
              *, interpret: bool = False) -> jnp.ndarray:
    """y = s*x + t for x (M, N); s, t are (1, N) row parameters."""
    m, n = x.shape
    bm, bn = _blocks(m, n)
    xp = pad2d(x, bm, bn)
    sp = pad2d(s.reshape(1, n).astype(x.dtype), 1, bn)
    tp = pad2d(t.reshape(1, n).astype(x.dtype), 1, bn)
    mp, np_ = xp.shape
    out = pl.pallas_call(
        _affine_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),   # context-word params
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(xp, sp, tp)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def vecadd_2d(x: jnp.ndarray, z: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """y = x + z elementwise for x, z (M, N) (Table 1 translation)."""
    m, n = x.shape
    bm, bn = _blocks(m, n)
    xp, zp = pad2d(x, bm, bn), pad2d(z.astype(x.dtype), bm, bn)
    mp, np_ = xp.shape
    out = pl.pallas_call(
        _vecadd_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(xp, zp)
    return out[:m, :n]
