"""Pallas TPU kernels for the paper's vector-vector / vector-scalar ops.

This is the direct TPU re-expression of sections 5.1-5.2: the context word
becomes the kernel body, the column broadcast becomes the grid, and the
double-banked frame buffer becomes the (automatically double-buffered)
HBM->VMEM block pipeline that `BlockSpec` index maps describe.

Three bodies cover the public ops:

  * ``_affine_kernel``  -- y = s (.) x + t with s, t broadcast row
    parameters staged once per column block (the "context word immediate"
    of Table 2, generalised from a scalar to a (1, bn) vector);
  * ``_vecadd_kernel``  -- y = x (+) z elementwise, both operands streamed
    through the double-buffered pipeline (Table 1's dbcdc);
  * ``_chain_diag_kernel`` -- the folded *diagonal* transform chain
    y[j] = s[j mod d] * x[j] + t[j mod d] over the flattened (N, d) point
    buffer.  The per-coordinate scale/shift pattern is tiled across the
    lane axis host-side, so an arbitrary translate/scale/affine chain is
    one lane-dense VPU pass: one HBM read of the points, one write, no
    per-point lane padding and no MXU involvement;
  * ``_chain_diag_batch_kernel`` -- the batched form used by the serving
    engine: each block row is a different request's flat point buffer and
    the parameter rows are row-aligned (request b meets its own folded
    (s, t)), so a whole plan bucket of heterogeneous requests is a single
    launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import (LANES, SUBLANES, pad2d, pad_axis, pick_block,
                                stage_flat, stage_packed)


def _affine_kernel(x_ref, s_ref, t_ref, o_ref):
    o_ref[...] = x_ref[...] * s_ref[...] + t_ref[...]


def _vecadd_kernel(x_ref, z_ref, o_ref):
    o_ref[...] = x_ref[...] + z_ref[...]


def _blocks(m: int, n: int) -> tuple[int, int]:
    return pick_block(m, 256, SUBLANES), pick_block(n, 512, LANES)


@functools.partial(jax.jit, static_argnames=("interpret",))
def affine_2d(x: jnp.ndarray, s: jnp.ndarray, t: jnp.ndarray,
              *, interpret: bool = False) -> jnp.ndarray:
    """y = s*x + t for x (M, N); s, t are (1, N) row parameters."""
    m, n = x.shape
    bm, bn = _blocks(m, n)
    xp = pad2d(x, bm, bn)
    sp = pad2d(s.reshape(1, n).astype(x.dtype), 1, bn)
    tp = pad2d(t.reshape(1, n).astype(x.dtype), 1, bn)
    mp, np_ = xp.shape
    out = pl.pallas_call(
        _affine_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),   # context-word params
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(xp, sp, tp)
    return out[:m, :n]


def _chain_diag_kernel(x_ref, s_ref, t_ref, o_ref):
    o_ref[...] = x_ref[...] * s_ref[...] + t_ref[...]


@functools.partial(jax.jit, static_argnames=("d", "interpret", "block_rows",
                                              "lane_target"))
def chain_diag_1d(flat: jnp.ndarray, s: jnp.ndarray, t: jnp.ndarray,
                  *, d: int, interpret: bool = False,
                  block_rows: int | None = None,
                  lane_target: int | None = None) -> jnp.ndarray:
    """Folded diagonal chain on the flat point buffer: y = s*x + t per coord.

    ``flat`` is an (N*d,) view of an (N, d) point array; ``s``/``t`` are
    (d,) per-coordinate parameters.  The buffer is reshaped to rows of
    ``w = chain_width(d)`` lanes (w a multiple of d, so points never
    straddle a block edge) and the d-periodic parameter pattern is tiled
    into (1, w) context-word rows staged once per block.
    ``block_rows``/``lane_target`` are the autotuner's launch parameters
    (``None`` = historical defaults); they steer staging only, never
    arithmetic, so every configuration is bit-identical.
    """
    (l,) = flat.shape
    if l == 0:
        return flat
    xp, lane_coord, bm, w = stage_flat(flat, d, block_rows=block_rows,
                                       lane_target=lane_target)
    srow = s.astype(flat.dtype)[lane_coord].reshape(1, w)
    trow = t.astype(flat.dtype)[lane_coord].reshape(1, w)
    out = pl.pallas_call(
        _chain_diag_kernel,
        out_shape=jax.ShapeDtypeStruct(xp.shape, flat.dtype),
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),   # context-word params
            pl.BlockSpec((1, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, w), lambda i: (i, 0)),
        interpret=interpret,
    )(xp, srow, trow)
    return out.reshape(-1)[:l]


def _chain_diag_batch_kernel(x_ref, s_ref, t_ref, o_ref, *, g: int):
    x = x_ref[...]                                   # (bm, wr) -- bm requests
    bm, wr = x.shape
    x3 = x.reshape(bm, wr // g, g)
    s = s_ref[...][:, None, :]                       # per-request params,
    t = t_ref[...][:, None, :]                       # row-aligned with x
    o_ref[...] = (x3 * s + t).reshape(bm, wr)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def chain_diag_batch_2d(pts3: jnp.ndarray, s: jnp.ndarray, t: jnp.ndarray,
                        *, interpret: bool = False,
                        block_rows: int | None = None) -> jnp.ndarray:
    """Batched folded diagonal chains: q[b] = s[b] (.) p[b] + t[b].

    ``pts3`` is a packed (B, L, d) batch (one serving request per row,
    padded to a common L); ``s``/``t`` are (B, d) per-request folded
    parameters.  Each batch row streams through the same one-pass VPU
    body as ``chain_diag_1d``, but the context-word parameter rows are
    *row-aligned* rather than broadcast: request b's block row meets
    request b's (g,)-tiled parameters, so B heterogeneous requests are
    one kernel launch.  ``block_rows`` pins the batch-axis block (the
    autotuner's knob; ``None`` = VMEM-budget heuristic).
    """
    b, l, d = pts3.shape
    if b == 0 or l == 0:
        return pts3
    xp, lane_coord, bm, g = stage_packed(pts3, d, block_rows=block_rows)
    srow = pad_axis(s.astype(pts3.dtype)[:, lane_coord], 0, bm)     # (Bp, g)
    trow = pad_axis(t.astype(pts3.dtype)[:, lane_coord], 0, bm)
    out = pl.pallas_call(
        functools.partial(_chain_diag_batch_kernel, g=g),
        out_shape=jax.ShapeDtypeStruct(xp.shape, pts3.dtype),
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, xp.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bm, g), lambda i: (i, 0)),  # row-aligned params
            pl.BlockSpec((bm, g), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, xp.shape[1]), lambda i: (i, 0)),
        interpret=interpret,
    )(xp, srow, trow)
    return out[:b, :l * d].reshape(b, l, d)


@functools.partial(jax.jit, static_argnames=("interpret",))
def vecadd_2d(x: jnp.ndarray, z: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """y = x + z elementwise for x, z (M, N) (Table 1 translation)."""
    m, n = x.shape
    bm, bn = _blocks(m, n)
    xp, zp = pad2d(x, bm, bn), pad2d(z.astype(x.dtype), bm, bn)
    mp, np_ = xp.shape
    out = pl.pallas_call(
        _vecadd_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(xp, zp)
    return out[:m, :n]
