from repro.kernels.affine.ops import affine, scale, translate, vecadd

__all__ = ["affine", "scale", "translate", "vecadd"]
