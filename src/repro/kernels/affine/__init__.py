from repro.kernels.affine.ops import affine, chain_diag, scale, translate, vecadd

__all__ = ["affine", "chain_diag", "scale", "translate", "vecadd"]
