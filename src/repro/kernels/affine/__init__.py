from repro.kernels.affine.ops import (affine, chain_diag, chain_diag_batch,
                                      scale, translate, vecadd)

__all__ = ["affine", "chain_diag", "chain_diag_batch", "scale", "translate",
           "vecadd"]
