"""Public entry points for the affine family (translate/scale/affine/vecadd).

Shape-polymorphic wrappers: inputs of any rank are flattened to (M, N) with
N = trailing dim; row parameters may be scalars or (N,) vectors.  Backend
dispatch per ``repro.kernels.dispatch``; every entry records its HBM byte
volume through ``repro.kernels.opcount`` so byte-economy claims (sequential
vs fused chains) are testable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.autotune.cache import KernelConfig
from repro.kernels import dispatch, opcount
from repro.kernels.affine import affine as K
from repro.kernels.affine import ref


def _as_row(p, n: int, dtype) -> jnp.ndarray:
    p = jnp.asarray(p, dtype)
    if p.ndim == 0:
        p = jnp.broadcast_to(p, (n,))
    return p.reshape(1, n)


def affine(x: jnp.ndarray, s, t, *, backend: str | None = None) -> jnp.ndarray:
    """y = s*x + t -- the fused translation+scaling composite.

    ``s``/``t`` are scalars or (N,) vectors over the trailing dim of x."""
    n = x.shape[-1]
    opcount.record("affine", 2 * x.nbytes + 2 * n * x.dtype.itemsize)
    b = dispatch.resolve(backend)
    if b == "ref":
        return ref.affine(x, s, t)
    x2 = x.reshape(-1, n)
    out = K.affine_2d(x2, _as_row(s, n, x.dtype), _as_row(t, n, x.dtype),
                      interpret=(b == "interpret"))
    return out.reshape(x.shape)


def scale(x: jnp.ndarray, s, *, backend: str | None = None) -> jnp.ndarray:
    """q = S x p, diagonal S (paper section 5.2 vector-scalar op)."""
    return affine(x, s, jnp.zeros((), x.dtype), backend=backend)


def translate(x: jnp.ndarray, t, *, backend: str | None = None) -> jnp.ndarray:
    """q = p + t (paper section 5.1 vector-vector op, broadcast form)."""
    return affine(x, jnp.ones((), x.dtype), t, backend=backend)


def vecadd(x: jnp.ndarray, z: jnp.ndarray, *, backend: str | None = None) -> jnp.ndarray:
    """y = x + z elementwise (Table 1; residual-add in the model stack)."""
    assert x.shape == z.shape, (x.shape, z.shape)
    opcount.record("vecadd", 3 * x.nbytes)
    b = dispatch.resolve(backend)
    if b == "ref":
        return ref.vecadd(x, z)
    n = x.shape[-1]
    out = K.vecadd_2d(x.reshape(-1, n), z.reshape(-1, n),
                      interpret=(b == "interpret"))
    return out.reshape(x.shape)


def chain_diag(points: jnp.ndarray, s, t, *, backend: str | None = None,
               config: KernelConfig | None = None) -> jnp.ndarray:
    """Folded diagonal transform chain q = s (.) p + t in one fused pass.

    ``points`` is (..., d); ``s``/``t`` are scalars or (d,) per-coordinate
    parameters.  Lowered to the lane-dense ``chain_diag_1d`` kernel: one
    HBM read of the points, one write, never touches the MXU.  This is
    the lowering target for diagonal ``TransformChain`` plans; byte
    accounting for the chain as a whole happens in ``TransformChain.apply``
    (this entry is called under jit inside the compiled plan).  ``config``
    carries tuned launch parameters (the chain compiler consults the
    tuning cache at plan-trace time); ``None`` means the deterministic
    defaults, and any config is bit-identical to any other.
    """
    b = dispatch.resolve(backend)
    d = points.shape[-1]
    s = jnp.broadcast_to(jnp.asarray(s, points.dtype), (d,))
    t = jnp.broadcast_to(jnp.asarray(t, points.dtype), (d,))
    if b == "ref":
        return ref.chain_diag(points, s, t)
    cfg = config or KernelConfig("chain_diag")
    out = K.chain_diag_1d(points.reshape(-1), s, t, d=d,
                          interpret=(b == "interpret"),
                          block_rows=cfg.block_rows,
                          lane_target=cfg.lane_target)
    return out.reshape(points.shape)


def chain_diag_batch(pts3: jnp.ndarray, s: jnp.ndarray, t: jnp.ndarray, *,
                     backend: str | None = None,
                     config: KernelConfig | None = None) -> jnp.ndarray:
    """Batched folded diagonal chains: q[b] = s[b] (.) p[b] + t[b].

    ``pts3`` is a packed (B, L, d) batch -- one serving request per row,
    padded to a common length L; ``s``/``t`` are (B, d) per-request folded
    parameters.  One launch serves the whole batch; on ``ref`` the oracle
    is the per-request ``chain_diag`` under ``jax.vmap``, so each row's
    arithmetic is element-for-element the per-request arithmetic (the
    serving engine's bit-identity contract).  Called under jit inside the
    serving engine's compiled bucket plans; packed-batch byte accounting
    happens there via ``opcount.packed_chain_bytes``.
    """
    bsz, _, d = pts3.shape
    s = jnp.broadcast_to(jnp.asarray(s, pts3.dtype), (bsz, d))
    t = jnp.broadcast_to(jnp.asarray(t, pts3.dtype), (bsz, d))
    b = dispatch.resolve(backend)
    if b == "ref":
        return jax.vmap(ref.chain_diag)(pts3, s, t)
    cfg = config or KernelConfig("chain_diag_batch")
    return K.chain_diag_batch_2d(pts3, s, t, interpret=(b == "interpret"),
                                 block_rows=cfg.block_rows)
