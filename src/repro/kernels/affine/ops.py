"""Public entry points for the affine family (translate/scale/affine/vecadd).

Shape-polymorphic wrappers: inputs of any rank are flattened to (M, N) with
N = trailing dim; row parameters may be scalars or (N,) vectors.  Backend
dispatch per ``repro.kernels.dispatch``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.affine import affine as K
from repro.kernels.affine import ref


def _as_row(p, n: int, dtype) -> jnp.ndarray:
    p = jnp.asarray(p, dtype)
    if p.ndim == 0:
        p = jnp.broadcast_to(p, (n,))
    return p.reshape(1, n)


def affine(x: jnp.ndarray, s, t, *, backend: str | None = None) -> jnp.ndarray:
    """y = s*x + t -- the fused translation+scaling composite.

    ``s``/``t`` are scalars or (N,) vectors over the trailing dim of x."""
    b = dispatch.resolve(backend)
    if b == "ref":
        return ref.affine(x, s, t)
    n = x.shape[-1]
    x2 = x.reshape(-1, n)
    out = K.affine_2d(x2, _as_row(s, n, x.dtype), _as_row(t, n, x.dtype),
                      interpret=(b == "interpret"))
    return out.reshape(x.shape)


def scale(x: jnp.ndarray, s, *, backend: str | None = None) -> jnp.ndarray:
    """q = S x p, diagonal S (paper section 5.2 vector-scalar op)."""
    return affine(x, s, jnp.zeros((), x.dtype), backend=backend)


def translate(x: jnp.ndarray, t, *, backend: str | None = None) -> jnp.ndarray:
    """q = p + t (paper section 5.1 vector-vector op, broadcast form)."""
    return affine(x, jnp.ones((), x.dtype), t, backend=backend)


def vecadd(x: jnp.ndarray, z: jnp.ndarray, *, backend: str | None = None) -> jnp.ndarray:
    """y = x + z elementwise (Table 1; residual-add in the model stack)."""
    assert x.shape == z.shape, (x.shape, z.shape)
    b = dispatch.resolve(backend)
    if b == "ref":
        return ref.vecadd(x, z)
    n = x.shape[-1]
    out = K.vecadd_2d(x.reshape(-1, n), z.reshape(-1, n),
                      interpret=(b == "interpret"))
    return out.reshape(x.shape)
