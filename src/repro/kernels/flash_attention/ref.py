"""Attention oracles: full-softmax reference + memory-bounded blockwise scan.

``attention`` is the fp32 full-softmax oracle used to validate the Pallas
kernel.  ``blockwise_attention`` is the production jnp path (lax.scan over KV
blocks with online softmax): differentiable, memory-bounded at 32k+ context,
and the lowering path for CPU dry-runs.  Both take

    q (B, Hq, S, D), k/v (B, Hkv, T, D)  ->  (B, Hq, S, D)

with GQA expressed by Hq = G * Hkv; ``q_offset`` aligns q positions to the
end of the KV axis for decode (qpos = q_offset + i, kpos = j).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def _mask(qpos, kpos, causal: bool, window: int | None, t_actual: int | None):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if t_actual is not None:
        m &= (kpos < t_actual)[None, :]
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def _expand_kv(x: jnp.ndarray, group: int) -> jnp.ndarray:
    if group == 1:
        return x
    b, hkv, t, d = x.shape
    return jnp.broadcast_to(x[:, :, None], (b, hkv, group, t, d)).reshape(
        b, hkv * group, t, d)


def attention(q, k, v, *, scale: float, causal: bool = True,
              window: int | None = None, q_offset: int = 0,
              t_actual: int | None = None) -> jnp.ndarray:
    """Full-softmax fp32 oracle (O(S*T) memory -- tests only)."""
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    k = _expand_kv(k, hq // hkv)
    v = _expand_kv(v, hq // hkv)
    q = (q * scale).astype(q.dtype)   # fold scale into q (one pass saved)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k,
                        preferred_element_type=jnp.float32)
    qpos = q_offset + jnp.arange(s)
    kpos = jnp.arange(t)
    logits = jnp.where(_mask(qpos, kpos, causal, window, t_actual),
                       logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def banded_swa_attention(q, k, v, *, scale: float, window: int) -> jnp.ndarray:
    """Sliding-window attention as banded block attention (beyond-paper
    optimization; see EXPERIMENTS.md section Perf, hymba cell).

    Each window-sized query block attends only to its own and the previous
    KV block -- O(S * 2W) score compute/memory instead of the blockwise
    path's O(S * T).  The block dim shards over the "model" mesh axis
    (sequence parallelism), which also rescues archs whose head count does
    not divide the axis (hymba: 25 heads on a 16-way axis).  Dot inputs
    stay bf16 with fp32 accumulation (MXU-native).

    Requires self-attention from position 0 (q_offset == 0, t == s):
    exactly the train/prefill shapes; decode uses the ring cache path.
    """
    from repro.distributed.sharding import constrain

    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    assert t == s, (t, s)
    k = _expand_kv(k, hq // hkv)
    v = _expand_kv(v, hq // hkv)
    win = window
    pad = (-s) % win
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    nb = qp.shape[2] // win

    def blocks(x):  # (B, H, S, D) -> (B, H, nb, win, D), nb sharded (SP)
        xb = x.reshape(b, hq, nb, win, d)
        return constrain(xb, "batch", None, "model", None, None)

    qb, kb, vb = blocks(qp), blocks(kp), blocks(vp)
    zero = jnp.zeros((b, hq, 1, win, d), kp.dtype)
    kband = jnp.concatenate(
        [jnp.concatenate([zero, kb[:, :, :-1]], axis=2), kb], axis=3)
    vband = jnp.concatenate(
        [jnp.concatenate([zero, vb[:, :, :-1]], axis=2), vb], axis=3)

    logits = jax.lax.dot_general(
        qb, kband, (((4,), (4,)), ((0, 1, 2), (0, 1, 2))),
        preferred_element_type=jnp.float32) * scale     # (B,H,nb,win,2win)

    ii = jnp.arange(win)
    jj = jnp.arange(2 * win)
    mask = (jj[None, :] <= win + ii[:, None]) & (jj[None, :] > ii[:, None])
    first = jj[None, :] >= win                           # block 0: no prev
    mask = jnp.where(jnp.arange(nb)[:, None, None] == 0,
                     mask[None] & first[None], mask[None])
    if pad:  # padded keys at the tail must not be attended
        kpos = (jnp.arange(nb)[:, None, None] - 1) * win + jj[None, None, :]
        mask = mask & (kpos < s)
    logits = jnp.where(mask[None, None], logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jax.lax.dot_general(
        p.astype(vband.dtype), vband,
        (((4,), (3,)), ((0, 1, 2), (0, 1, 2))),
        preferred_element_type=jnp.float32)              # (B,H,nb,win,D)
    out = out.astype(q.dtype).reshape(b, hq, nb * win, d)
    return out[:, :, :s]


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "q_offset", "block_kv", "t_actual"))
def blockwise_attention(q, k, v, *, scale: float, causal: bool = True,
                        window: int | None = None, q_offset: int = 0,
                        block_kv: int = 1024,
                        t_actual: int | None = None) -> jnp.ndarray:
    """Online-softmax scan over KV blocks; O(S * block_kv) live memory."""
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    group = hq // hkv
    if t <= block_kv:  # single block: direct softmax, no online corrections
        # (for 4k training this removes the inner KV scan whose per-step
        # residual stacks dominate HBM traffic; EXPERIMENTS.md section Perf)
        return attention(q, k, v, scale=scale, causal=causal, window=window,
                         q_offset=q_offset, t_actual=t_actual)
    if t % block_kv:   # pad KV to a block multiple; tail masked via t_actual
        pad = block_kv - t % block_kv
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        t_actual = t if t_actual is None else min(t, t_actual)
        t = k.shape[2]
    nblocks = t // block_kv
    kb = k.reshape(b, hkv, nblocks, block_kv, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nblocks, block_kv, d).transpose(2, 0, 1, 3, 4)
    qpos = q_offset + jnp.arange(s)
    q = (q * scale).astype(q.dtype)   # fold scale: saves one S x T pass

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        kblk, vblk, ki = blk
        kblk = _expand_kv(kblk, group)
        vblk = _expand_kv(vblk, group)
        # bf16 dot inputs, fp32 accumulation (MXU-native; see section Perf)
        sc = jnp.einsum("bhsd,bhtd->bhst", q, kblk,
                        preferred_element_type=jnp.float32)
        kpos = ki * block_kv + jnp.arange(block_kv)
        msk = jnp.ones((s, block_kv), bool)
        if t_actual is not None:
            msk &= (kpos < t_actual)[None, :]
        if causal:
            msk &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            msk &= kpos[None, :] > qpos[:, None] - window
        sc = jnp.where(msk, sc, _NEG_INF)
        m_cur = jnp.max(sc, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(sc - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.einsum(
            "bhst,bhtd->bhsd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, hq, s, 1), _NEG_INF, jnp.float32),
            jnp.zeros((b, hq, s, 1), jnp.float32),
            jnp.zeros((b, hq, s, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init,
                                  (kb, vb, jnp.arange(nblocks)))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
