"""Public attention entry: (B, Hq, S, D) x (B, Hkv, T, D) -> (B, Hq, S, D).

Backends: ``pallas``/``interpret`` use the flash kernel; ``ref`` uses the
blockwise-scan jnp path (differentiable; also the CPU dry-run lowering)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.flash_attention import flash_attention as K
from repro.kernels.flash_attention import ref

attention_reference = ref.attention
blockwise_attention = ref.blockwise_attention


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              scale: float | None = None, causal: bool = True,
              window: int | None = None, q_offset: int = 0,
              block_kv: int = 1024, backend: str | None = None) -> jnp.ndarray:
    """Scaled dot-product attention, GQA-aware (Hq may exceed Hkv).

    ``q`` (B, Hq, S, D) attends over ``k``/``v`` (B, Hkv, T, D); ``causal``
    masks with ``q_offset`` locating the query block inside the sequence
    (decode passes the cache position), ``window`` enables sliding-window
    attention, ``block_kv`` sets the streaming KV block.  Backend per
    ``repro.kernels.dispatch``; the ref oracle special-cases banded SWA
    prefill (O(S*2W) instead of O(S*T) masked).
    """
    b, hq, s, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    be = dispatch.resolve(backend)
    if be == "ref":
        # SWA train/prefill: banded block attention, O(S*2W) instead of
        # O(S*T) masked (EXPERIMENTS.md section Perf)
        if (causal and window is not None and isinstance(q_offset, int)
                and q_offset == 0 and s > 1 and k.shape[2] == s
                and window < s and window % 128 == 0):
            return ref.banded_swa_attention(q, k, v, scale=scale,
                                            window=window)
        return ref.blockwise_attention(q, k, v, scale=scale, causal=causal,
                                       window=window, q_offset=q_offset,
                                       block_kv=block_kv)
    hkv, t = k.shape[1], k.shape[2]
    out = K.flash_attention_3d(
        q.reshape(b * hq, s, d), k.reshape(b * hkv, t, d),
        v.reshape(b * hkv, t, d), scale=scale, causal=causal, window=window,
        q_offset=q_offset, interpret=(be == "interpret"))
    return out.reshape(b, hq, s, d)
