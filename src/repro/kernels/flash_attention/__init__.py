from repro.kernels.flash_attention.ops import (
    attention, attention_reference, blockwise_attention,
)

__all__ = ["attention", "attention_reference", "blockwise_attention"]
