"""Pallas TPU flash attention (forward) -- beyond-paper composite transform.

The paper's section-5.3 "composite algorithms" chain its three primitives
(matmul, vector-scalar, vector-vector).  Attention is exactly such a chain --
S = QK^T (matmul), online softmax (vector-scalar with a data-derived scalar,
like RMSNorm), O = PV (matmul) -- and the MorphoSys frame-buffer discipline
maps directly: KV blocks stream through VMEM (bank 0/1 double-buffering by
the Pallas pipeline) while the accumulator lives in the cell output
registers (fp32 VMEM scratch).

Grid: (batch*heads, q_blocks, kv_blocks), kv innermost with "arbitrary"
semantics so the m/l/acc scratch carries across kv steps.  GQA is expressed
in the K/V index maps (q head h reads kv head h // group) -- no KV
materialisation.  Causal and sliding-window masks skip dead kv blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import (SUBLANES, CompilerParams, pad_axis,
                               pick_block)

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, nkv: int, scale: float, causal: bool,
                  window: int | None, q_offset: int, s_actual: int,
                  t_actual: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level liveness: any (q, k) pair in this tile unmasked?
    q_lo = q_offset + qi * bq
    q_hi = q_lo + bq - 1
    k_lo = ki * bk
    k_hi = k_lo + bk - 1
    live = k_lo < t_actual
    if causal:
        live &= k_lo <= q_hi
    if window is not None:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < t_actual
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]                           # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)      # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == nkv - 1)
    def _flush():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "q_offset", "bq", "bk", "interpret"))
def flash_attention_3d(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                       scale: float, causal: bool = True,
                       window: int | None = None, q_offset: int = 0,
                       bq: int = 128, bk: int = 128,
                       interpret: bool = False) -> jnp.ndarray:
    """q (BHq, S, D), k/v (BHkv, T, D) -> (BHq, S, D); GQA via index maps."""
    bhq, s, d = q.shape
    bhkv, t, _ = k.shape
    assert bhq % bhkv == 0, (bhq, bhkv)
    group = bhq // bhkv
    bq = pick_block(s, bq, SUBLANES)
    bk = pick_block(t, bk, SUBLANES)
    qp = pad_axis(q, 1, bq)
    kp = pad_axis(k, 1, bk)
    vp = pad_axis(v, 1, bk)
    nq, nkv = qp.shape[1] // bq, kp.shape[1] // bk
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nkv=nkv, scale=scale, causal=causal,
        window=window, q_offset=q_offset, s_actual=s, t_actual=t)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        grid=(bhq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, kk: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, kk, g=group: (h // g, kk, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, kk, g=group: (h // g, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, kk: (h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # m (running max)
            pltpu.VMEM((bq, 128), jnp.float32),   # l (running denominator)
            pltpu.VMEM((bq, d), jnp.float32),     # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :s, :]
