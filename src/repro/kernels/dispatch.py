"""Kernel backend dispatch.

Every kernel family exposes its public entry points through ``ops.py`` with a
``backend`` argument resolved here:

  * ``pallas``    -- compiled Pallas TPU kernel (the deployment path),
  * ``interpret`` -- the same Pallas kernel body executed with
                     ``interpret=True`` (CPU-correctness path; how this
                     container validates the TPU kernels),
  * ``ref``       -- the pure-jnp oracle in ``ref.py`` (also the lowering
                     path for the CPU dry-run, and the autodiff path).

This mirrors the paper's context-memory discipline: the *function* is fixed
("the context word"), only the execution substrate changes.
"""
from __future__ import annotations

import contextlib

import jax

_BACKEND: str = "auto"
_VALID = ("auto", "pallas", "interpret", "ref")

#: the degradation ladder, fastest substrate first: a launch that keeps
#: failing on one rung falls to the next -- ``pallas`` (compiled TPU
#: kernel) degrades to ``interpret`` (same kernel body, Pallas
#: interpreter: survives Mosaic/compile faults), which degrades to
#: ``ref`` (the pure-jnp oracle: survives kernel-body faults).  Every
#: rung computes the same function (the paper's context-word
#: discipline), so degrading trades speed, never results.
FALLBACK_ORDER = ("pallas", "interpret", "ref")


def fallback_ladder(backend: str | None = None) -> tuple[str, ...]:
    """The rungs a failing launch may degrade through, starting at (and
    including) the resolved ``backend``: ``("interpret", "ref")`` for an
    interpret server, just ``("ref",)`` at the bottom.  The serving
    engine walks this per failing bucket (see ``serving.engine``)."""
    b = resolve(backend)
    return FALLBACK_ORDER[FALLBACK_ORDER.index(b):]


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in _VALID:
        raise ValueError(f"backend must be one of {_VALID}, got {name!r}")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def resolve(backend: str | None = None) -> str:
    b = backend or _BACKEND
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return b


@contextlib.contextmanager
def use_backend(name: str):
    global _BACKEND
    prev = _BACKEND
    set_backend(name)
    try:
        yield
    finally:
        _BACKEND = prev
