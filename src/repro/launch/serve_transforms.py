"""Driver for the batched transform-serving engine.

Generates a synthetic mixed workload (bounded structure pool, random
parameters and point counts -- the serving hot path), runs it through
``GeometryServer``, and prints the per-bucket schedule plus a comparison
against per-request dispatch:

    PYTHONPATH=src python -m repro.launch.serve_transforms --requests 64
    PYTHONPATH=src python -m repro.launch.serve_transforms --smoke

``--smoke`` shrinks the workload to a seconds-long liveness run (what CI
executes so the documented command cannot rot).  ``--autotune`` enables
the tuning cache (``repro.autotune``): the size grid and kernel launch
parameters come from the committed winners instead of the hardcoded
defaults, and the schedule header names the grid's source.  ``--trace
out.json`` serves the counted flush under a ``repro.obs`` tracer and
writes the span stream as Chrome-trace JSON -- open it in Perfetto
(one track per plan bucket, request spans on the main track).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import obs, serving
from repro.serving import workload
from repro.serving.workload import timed as _timed


def run_workload(requests: int, *, backend: str,
                 waste_cap: float | None = None,
                 max_points: int, max_points_per_launch: int | None,
                 seed: int, compare: bool = True,
                 trace_path: str | None = None) -> dict:
    """Serve one workload; returns the timing/schedule summary dict.
    ``waste_cap=None`` defers to the server's grid resolution (the tuning
    cache when ``repro.autotune`` is enabled, else the default grid).
    ``trace_path`` traces the counted flush and writes Chrome JSON."""
    reqs = workload.random_workload(seed=seed, n_requests=requests,
                                    max_points=max_points)

    serving.reset_stats()
    srv = serving.GeometryServer(backend=backend, waste_cap=waste_cap,
                                 max_points_per_launch=max_points_per_launch)
    warm = srv.serve(reqs)                       # compile + trace once
    jax.block_until_ready(warm)
    serving.reset_stats()
    if trace_path is not None:
        tracer = obs.Tracer()
        with obs.installed(tracer):
            srv.serve(reqs)                      # one counted, traced flush
        obs.dump_chrome_trace(tracer, trace_path)
        print(f"wrote {tracer.n_events} trace events to {trace_path}")
    else:
        srv.serve(reqs)                          # one counted flush
    stats = dict(serving.stats)
    batched_s = min(_timed(lambda: srv.serve(reqs)) for _ in range(3))

    per_request_s = None
    if compare:
        for chain, pts in reqs:                  # warm per-request plans
            chain.apply(jnp.asarray(pts), backend=backend)
        per_request_s = min(
            _timed(lambda: [chain.apply(jnp.asarray(pts), backend=backend)
                            for chain, pts in reqs])
            for _ in range(3))

    return {"requests": requests, "batched_s": batched_s,
            "per_request_s": per_request_s, "report": srv.last_report,
            "stats": stats,
            "grid": (srv.min_len, srv.waste_cap, srv.grid_source)}


def print_summary(res: dict) -> None:
    st = res["stats"]
    min_len, cap, src = res["grid"]
    print(f"size grid: min_len={min_len} waste_cap={cap} ({src})")
    print(f"{'bucket':<12} {'plan':<10} {'lpad':>5} {'reqs':>5} "
          f"{'launches':>8} {'waste':>6}")
    for rep in res["report"]:
        print(f"{rep.structure:<12} {rep.kind:<10} {rep.lpad:>5} "
              f"{rep.requests:>5} {rep.launches:>8} {rep.waste:>6.1%}")
    print(f"\n{st['requests']} requests -> {st['launches']} launches "
          f"({st['buckets']} buckets, {st['shards']} extra shards); "
          f"padding {1 - st['payload_points'] / max(1, st['padded_points']):.1%}")
    line = f"batched: {res['batched_s'] * 1e3:.1f} ms"
    if res["per_request_s"] is not None:
        line += (f"   per-request: {res['per_request_s'] * 1e3:.1f} ms   "
                 f"speedup: {res['per_request_s'] / res['batched_s']:.2f}x")
    print(line)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--backend", default=None,
                    choices=[None, "ref", "interpret", "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--waste-cap", type=float, default=None,
                    help="explicit padding-waste cap; unset defers to the "
                         "tuning cache (with --autotune) or the default "
                         "grid")
    ap.add_argument("--autotune", action="store_true",
                    help="consult the tuning cache for the size grid and "
                         "kernel launch parameters")
    ap.add_argument("--max-points", type=int, default=4096)
    ap.add_argument("--max-points-per-launch", type=int, default=None,
                    help="shard buckets whose packed B*L exceeds this")
    ap.add_argument("--no-compare", action="store_true",
                    help="skip the per-request dispatch baseline")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload; CI liveness check")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write the counted flush's span stream as "
                         "Chrome-trace JSON (open in Perfetto)")
    args = ap.parse_args(argv)

    if args.autotune:
        import repro.autotune
        repro.autotune.set_enabled(True)
    requests = 16 if args.smoke else args.requests
    max_points = 128 if args.smoke else args.max_points
    res = run_workload(requests, backend=args.backend,
                       waste_cap=args.waste_cap, max_points=max_points,
                       max_points_per_launch=args.max_points_per_launch,
                       seed=args.seed, compare=not args.no_compare,
                       trace_path=args.trace)
    print_summary(res)


if __name__ == "__main__":
    main()
