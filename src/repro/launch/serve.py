"""Serving driver: batched prefill + decode against a KV/state cache.

The request path mirrors production continuous batching in miniature:
prompts are padded into one prefill batch, then the batch decodes in
lock-step (one serve_step per token) with greedy sampling.  The decode
step is the artifact the decode_32k / long_500k dry-run cells lower.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import build


def serve_batch(cfg, params, prompts: np.ndarray, *, gen_tokens: int = 16,
                model=None):
    """prompts (B, S_prompt) int32 -> generated tokens (B, gen_tokens)."""
    model = model or build(cfg)
    b, s = prompts.shape
    max_len = s + gen_tokens
    enc_len = s if cfg.is_encdec else 0
    cache = model.init_cache(b, max_len, enc_len)
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.zeros((b, cfg.n_frontend_tokens, cfg.d_model),
                                     jnp.float32)
    elif cfg.frontend == "audio":
        batch["frames"] = jnp.zeros((b, s, cfg.d_model), jnp.float32)
        batch["tokens"] = jnp.asarray(prompts[:, :1])

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode)
    logits, cache = prefill(params, batch, cache)
    out = []
    pos = prompts.shape[1] if not cfg.is_encdec else 1
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for i in range(gen_tokens):
        out.append(tok)
        logits, cache = decode(params, tok, pos + i, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return np.stack([np.asarray(t) for t in out], axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m",
                    choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    gen = serve_batch(cfg, params, prompts, gen_tokens=args.gen_tokens,
                      model=model)
    dt = time.time() - t0
    print(f"[serve] generated {gen.shape} in {dt:.2f}s "
          f"({args.batch * args.gen_tokens / dt:.1f} tok/s)")
    print(gen[:2])


if __name__ == "__main__":
    main()
