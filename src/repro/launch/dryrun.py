import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and record memory / cost / collective analysis.

The two lines above MUST stay first: jax locks the device count on first
initialisation, and the dry-run needs 512 placeholder host devices so
``jax.make_mesh`` can build the 2x16x16 production mesh.  (Smoke tests and
benches see 1 device -- this flag is set nowhere else.)

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results.jsonl
"""
import argparse
import json
import time
import traceback

import jax  # noqa: F401 -- imported HERE so the env lines above win the race

from repro import configs, hlo_analysis, roofline
from repro.configs.shapes import SHAPES, applicability
from repro.launch import cells
from repro.launch.mesh import make_production_mesh


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             verbose: bool = True, kv_int8: bool = False) -> dict:
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    cfg = configs.get(arch)
    ok, why = applicability(cfg, shape)
    if not ok:
        rec.update(status="SKIP", reason=why)
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.size
    cell = cells.build_cell(arch, shape, mesh, kv_int8=kv_int8)
    t_lower = time.time() - t0
    compiled = cell.lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    raw_cost = compiled.cost_analysis()
    if isinstance(raw_cost, (list, tuple)):
        raw_cost = raw_cost[0]
    text = compiled.as_text()
    # loop-aware analysis of the partitioned module (cost_analysis counts
    # while bodies once; see repro.hlo_analysis)
    ana = hlo_analysis.analyze(text)
    roof = roofline.roofline_terms(
        {"flops": ana["flops"], "bytes accessed": ana["hbm_bytes"]},
        roofline.CollectiveStats(ana["collective_bytes"],
                                 ana["collective_counts"]))
    mf = cells.model_flops_for_cell(cell, n_devices)
    util = roofline.model_flops_utilization(mf, roof)

    rec.update(
        status="OK",
        kind=cell.spec.kind,
        n_params=cell.meta["n_params"],
        accum_steps=cell.meta.get("accum_steps"),
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        bytes_per_device=dict(
            arguments=mem.argument_size_in_bytes,
            outputs=mem.output_size_in_bytes,
            temps=mem.temp_size_in_bytes,
            aliased=mem.alias_size_in_bytes,
            total_live=(mem.argument_size_in_bytes +
                        mem.output_size_in_bytes +
                        mem.temp_size_in_bytes -
                        mem.alias_size_in_bytes),
        ),
        hlo_flops_per_device=roof.flops,
        hlo_bytes_per_device=roof.hbm_bytes,
        collective_bytes_per_device=roof.collective_bytes,
        collective_breakdown=ana["collective_bytes"],
        collective_counts=ana["collective_counts"],
        raw_cost_analysis_flops=float((raw_cost or {}).get("flops", 0.0)),
        model_flops_per_device=mf,
        roofline=dict(t_compute=roof.t_compute, t_memory=roof.t_memory,
                      t_collective=roof.t_collective,
                      bottleneck=roof.bottleneck, **util),
    )
    if verbose:
        print(json.dumps(rec, indent=2, default=float))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized KV cache for decode cells")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    if args.all:
        todo = [(a, s) for a in configs.list_archs() for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    failures = 0
    for arch, shape in todo:
        for mp in meshes:
            try:
                rec = run_cell(arch, shape, multi_pod=mp,
                               verbose=not args.out, kv_int8=args.kv_int8)
            except Exception as e:  # a failing cell is a bug; record it
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                failures += 1
                print(f"FAIL {arch} x {shape} ({rec['mesh']}): {e}")
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec, default=float) + "\n")
                print(f"{rec['status']:5s} {arch} x {shape} ({rec['mesh']})",
                      flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
