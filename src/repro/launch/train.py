"""Training driver: data -> pjit'd train_step -> checkpoint/restart loop.

Fault-tolerance contract (tested in tests/test_checkpoint.py and
tests/test_elastic.py):
  * checkpoints are atomic (tmp-dir + rename) and carry the step;
  * ``--resume auto`` restarts from the latest complete checkpoint;
  * the data pipeline is stateless-seekable, so the resumed run sees the
    exact batches the lost run would have seen;
  * elastic resize: resuming on a different mesh re-places the same host
    arrays under the new sharding rules and rescales gradient-accumulation
    so the global batch is invariant (distributed/elastic.py);
  * straggler mitigation on a real fleet: per-step host heartbeat with a
    deadline -- a host missing two heartbeats is declared dead and the job
    restarts on the surviving mesh (hook stubbed here: single-host
    container), which the elastic path above makes cheap.

Run (CPU dev):  PYTHONPATH=src python -m repro.launch.train \
    --arch mamba2-130m --reduced --steps 50 --global-batch 16 --seq-len 128
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager, latest_step, load_checkpoint
from repro.data import DataConfig, SyntheticLMData
from repro.distributed import elastic, sharding
from repro.distributed.steps import make_train_step
from repro.launch.mesh import (make_local_mesh, make_production_mesh,
                              mesh_context)
from repro.models import build
from repro.optim import AdamWConfig, adamw_init


def train_loop(cfg, *, steps: int, global_batch: int, seq_len: int,
               mesh=None, micro_per_shard: int = 1, ckpt_dir: str | None = None,
               ckpt_interval: int = 50, resume: bool = False,
               opt_cfg: AdamWConfig | None = None, log_every: int = 10,
               seed: int = 0):
    """Shared by the CLI, examples and tests.  Returns (params, history)."""
    mesh = mesh or make_local_mesh()
    model = build(cfg)
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps,
                                     warmup_steps=max(1, steps // 20))
    accum = elastic.replan_accum(global_batch, micro_per_shard, mesh)
    micro = global_batch // accum

    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=global_batch,
        seed=seed, frontend=cfg.frontend,
        n_frontend_tokens=cfg.n_frontend_tokens, d_model=cfg.d_model))

    params = model.init(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params)
    start_step = 0
    manager = CheckpointManager(ckpt_dir, interval=ckpt_interval) \
        if ckpt_dir else None
    if resume and ckpt_dir and latest_step(ckpt_dir) is not None:
        (params, opt_state), start_step = load_checkpoint(
            ckpt_dir, (params, opt_state))
        print(f"[train] resumed from step {start_step}")

    pspecs = sharding.params_specs(params, mesh)
    psh = sharding.to_shardings(pspecs, mesh, params)
    osh = sharding.to_shardings(sharding.opt_specs(opt_state, pspecs), mesh,
                                opt_state)
    params = jax.tree.map(jax.device_put, params, psh)
    opt_state = jax.tree.map(jax.device_put, opt_state, osh)

    step_fn = make_train_step(model, opt_cfg, accum)
    with mesh_context(mesh):
        jitted = jax.jit(step_fn, in_shardings=(psh, osh, None),
                         out_shardings=(psh, osh, None),
                         donate_argnums=(0, 1))
        history = []
        t0 = time.time()
        for step in range(start_step, steps):
            raw = data.global_batch(step)
            batch = {k: np.reshape(v, (accum, micro) + v.shape[1:])
                     for k, v in raw.items()}
            params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
            history.append(loss)
            if manager:
                manager.maybe_save(step + 1, (params, opt_state))
            if step % log_every == 0 or step == steps - 1:
                dt = (time.time() - t0) / max(1, step - start_step + 1)
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"({dt*1e3:.0f} ms/step)", flush=True)
        if manager:
            manager.wait()
    return params, history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m",
                    choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--micro-per-shard", type=int, default=1)
    ap.add_argument("--mesh", choices=["local", "production", "multipod"],
                    default="local")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--resume", choices=["auto", "never"], default="auto")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = {"local": make_local_mesh,
            "production": make_production_mesh,
            "multipod": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    train_loop(cfg, steps=args.steps, global_batch=args.global_batch,
               seq_len=args.seq_len, mesh=mesh,
               micro_per_shard=args.micro_per_shard, ckpt_dir=args.ckpt_dir,
               ckpt_interval=args.ckpt_interval,
               resume=args.resume == "auto", seed=args.seed)


if __name__ == "__main__":
    main()
