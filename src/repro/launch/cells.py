"""Cell assembly: (arch x input-shape x mesh) -> lowered/compiled artifact.

A "cell" is one entry of the assignment's 40-cell grid.  ``build_cell``
returns the jitted step lowered with ShapeDtypeStruct stand-ins (no device
allocation), plus enough metadata for the roofline report.

Importable without the 512-device XLA flag; launch/dryrun.py sets that up.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.shapes import (
    MICROBATCH_PER_SHARD, SHAPES, ShapeSpec, applicability,
)
from repro.distributed import sharding
from repro.launch.mesh import mesh_context
from repro.distributed.steps import (
    make_decode_step, make_prefill_step, make_train_step,
)
from repro.models import attention_flops, build, flops_per_token
from repro.models.config import ModelConfig, ssd_flops
from repro.optim import AdamWConfig, adamw_init


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    spec: ShapeSpec
    lowered: Any
    meta: dict


def _data_width(mesh) -> int:
    fsdp, _ = sharding.axis_names(mesh)
    w = 1
    for a in fsdp:
        w *= mesh.shape[a]
    return w


def _train_batch_struct(cfg: ModelConfig, spec: ShapeSpec, accum: int,
                        micro: int):
    s = spec.seq_len
    b: dict = {
        "tokens": jax.ShapeDtypeStruct((accum, micro, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((accum, micro, s), jnp.int32),
    }
    if cfg.frontend == "vision":
        b["patches"] = jax.ShapeDtypeStruct(
            (accum, micro, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    elif cfg.frontend == "audio":
        b["frames"] = jax.ShapeDtypeStruct(
            (accum, micro, s, cfg.d_model), jnp.float32)
    return b


def _serve_batch_struct(cfg: ModelConfig, batch: int, seq: int):
    dec_len = 1 if cfg.is_encdec else seq
    b: dict = {"tokens": jax.ShapeDtypeStruct((batch, dec_len), jnp.int32)}
    if cfg.frontend == "vision":
        b["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    elif cfg.frontend == "audio":
        b["frames"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                           jnp.float32)
    return b


def input_specs(arch: str, shape: str, mesh, cfg=None) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the cell's step."""
    cfg = cfg or configs.get(arch)
    spec = SHAPES[shape]
    model = build(cfg)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    out = {"params": params_shape}
    if spec.kind == "train":
        micro = MICROBATCH_PER_SHARD[arch] * _data_width(mesh)
        accum = max(1, spec.global_batch // micro)
        micro = spec.global_batch // accum
        out["opt_state"] = jax.eval_shape(adamw_init, params_shape)
        out["batch"] = _train_batch_struct(cfg, spec, accum, micro)
        out["accum"] = accum
    else:
        b = spec.global_batch
        enc_len = spec.seq_len if cfg.is_encdec else 0
        out["batch"] = _serve_batch_struct(cfg, b, spec.seq_len)
        out["cache"] = model.cache_struct(b, spec.seq_len, enc_len)
        out["tokens"] = jax.ShapeDtypeStruct((b,), jnp.int32)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


def build_cell(arch: str, shape: str, mesh, *,
               opt_cfg: AdamWConfig | None = None,
               kv_int8: bool = False) -> Cell:
    cfg = configs.get(arch)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    spec = SHAPES[shape]
    ok, why = applicability(cfg, shape)
    if not ok:
        raise ValueError(f"cell ({arch}, {shape}) skipped: {why}")
    model = build(cfg)
    specs_in = input_specs(arch, shape, mesh, cfg)
    params_shape = specs_in["params"]
    pspecs = sharding.params_specs(params_shape, mesh)
    psh = sharding.to_shardings(pspecs, mesh, params_shape)
    meta: dict = {"arch": arch, "shape": shape, "kind": spec.kind}

    with mesh_context(mesh):
        if spec.kind == "train":
            accum = specs_in["accum"]
            ospecs = sharding.opt_specs(specs_in["opt_state"], pspecs)
            osh = sharding.to_shardings(ospecs, mesh, specs_in["opt_state"])
            bspecs = sharding.batch_specs(specs_in["batch"], mesh,
                                          accum_dim=True)
            bsh = sharding.to_shardings(bspecs, mesh, specs_in["batch"])
            step = make_train_step(model, opt_cfg or AdamWConfig(), accum)
            jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                             out_shardings=(psh, osh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_shape, specs_in["opt_state"],
                                   specs_in["batch"])
            meta["accum_steps"] = accum
            meta["tokens_per_step"] = spec.global_batch * spec.seq_len
        elif spec.kind == "prefill":
            bspecs = sharding.batch_specs(specs_in["batch"], mesh,
                                          accum_dim=False)
            bsh = sharding.to_shardings(bspecs, mesh, specs_in["batch"])
            cspecs = sharding.cache_specs(specs_in["cache"], cfg, mesh)
            csh = sharding.to_shardings(cspecs, mesh, specs_in["cache"])
            step = make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(psh, bsh, csh),
                             out_shardings=(None, csh), donate_argnums=(2,))
            lowered = jitted.lower(params_shape, specs_in["batch"],
                                   specs_in["cache"])
            meta["tokens_per_step"] = spec.global_batch * spec.seq_len
        else:  # decode
            fsdp, _ = sharding.axis_names(mesh)
            tsh = sharding.to_shardings(P(fsdp), mesh, specs_in["tokens"])
            cspecs = sharding.cache_specs(specs_in["cache"], cfg, mesh)
            csh = sharding.to_shardings(cspecs, mesh, specs_in["cache"])
            step = make_decode_step(model)
            jitted = jax.jit(step, in_shardings=(psh, tsh, None, csh),
                             out_shardings=(None, csh), donate_argnums=(3,))
            lowered = jitted.lower(params_shape, specs_in["tokens"],
                                   specs_in["pos"], specs_in["cache"])
            meta["tokens_per_step"] = spec.global_batch
        meta["n_params"] = cfg.param_count()
        meta["n_active_params"] = cfg.active_param_count()
        return Cell(arch, shape, cfg, spec, lowered, meta)


def model_flops_for_cell(cell: Cell, n_devices: int) -> float:
    """Analytic MODEL_FLOPS per device per step (6*N_active*D + attention)."""
    cfg, spec = cell.cfg, cell.spec
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        f = flops_per_token(cfg) * tokens
        f += attention_flops(cfg, spec.global_batch, spec.seq_len)
        f += ssd_flops(cfg, spec.global_batch, spec.seq_len)
    elif spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        f = flops_per_token(cfg) / 3 * tokens          # fwd only = 2N
        f += attention_flops(cfg, spec.global_batch, spec.seq_len) / 3
        f += ssd_flops(cfg, spec.global_batch, spec.seq_len) / 3
    else:
        f = flops_per_token(cfg) / 3 * spec.global_batch
        f += attention_flops(cfg, spec.global_batch, 1,
                             kv_len=spec.seq_len, causal=False) / 3
        # decode SSD: recurrent step only (no chunked quadratic term)
        if cfg.family in ("ssm", "hybrid"):
            f += (4.0 * cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state *
                  spec.global_batch * cfg.n_layers) / 3
    return f / n_devices
