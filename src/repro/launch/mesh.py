"""Production mesh factories (functions, never module-level constants --
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax


def make_mesh(shape, axes, *, devices=None):
    """Version-portable ``jax.make_mesh``: newer jax wants explicit
    ``axis_types`` (Auto) for the sharding pass; older jax (< AxisType)
    takes neither the kwarg nor the enum."""
    kwargs = {} if devices is None else {"devices": devices}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def mesh_context(mesh):
    """Version-portable ``jax.sharding.set_mesh``: on older jax the Mesh
    object itself is the context manager that scopes named-axis resolution."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Dev/test mesh over whatever devices exist (CPU included)."""
    n = len(jax.devices())
    assert n % model_axis == 0, (n, model_axis)
    return make_mesh((n // model_axis, model_axis), ("data", "model"))
