"""Production mesh factories (functions, never module-level constants --
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model_axis: int = 1):
    """Dev/test mesh over whatever devices exist (CPU included)."""
    n = len(jax.devices())
    assert n % model_axis == 0, (n, model_axis)
    return jax.make_mesh(
        (n // model_axis, model_axis), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
