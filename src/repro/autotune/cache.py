"""Tuning cache: persisted launch-parameter winners + deterministic defaults.

The autotune subsystem's contract with the rest of the repo lives here:

  * ``KernelConfig`` -- one kernel's launch parameters (block rows, lane
    width target, matmul tile, serving size-grid knobs).  Every field the
    kernels read is optional; ``None`` means "use the kernel's built-in
    heuristic", which is exactly what the pre-autotune code did.
  * ``DEFAULTS`` -- the deterministic configuration used whenever tuning
    is disabled or the cache has no entry.  These are the historical
    hardcoded values, so with autotuning off the system is bit-for-bit
    the pre-autotune system.
  * ``TuningCache`` -- a JSON-persisted map from
    ``(kernel, backend, dtype, size-class)`` to a winning config.  The
    repo commits ``default_cache.json`` (ref-backend winners from
    ``python -m repro.autotune --smoke --write-default``) so CI and fresh
    clones never depend on a tuning run.

Size classes are power-of-two buckets of the problem size (``p<k>`` holds
sizes in (2^(k-1), 2^k]), the same granularity the serving engine buckets
request lengths at; lookups fall back to the nearest tuned class before
falling back to the default config, so a cache tuned at two smoke shapes
still informs neighbouring sizes.

This module is stdlib-only on purpose: kernel ``ops.py`` entries import it
at module load, and it must never import back into ``repro.kernels``.
"""
from __future__ import annotations

import dataclasses
import json
import os

#: env switches (read once at first use; ``set_enabled`` overrides):
#:   REPRO_AUTOTUNE=1        -- consult the tuning cache
#:   REPRO_AUTOTUNE_CACHE=p  -- load winners from ``p`` instead of the
#:                              committed default_cache.json
ENV_ENABLE = "REPRO_AUTOTUNE"
ENV_CACHE = "REPRO_AUTOTUNE_CACHE"

DEFAULT_CACHE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                  "default_cache.json")

#: kernels the tuner knows how to search, and what each tunes:
#:   chain_diag / chain_apply / chain_project -- block rows + lane width
#:   chain_diag_q / chain_apply_q       -- same knobs, int16 Qm.n lane
#:                                         (cached under the format name
#:                                         as the dtype, e.g. "q8.7")
#:   chain_diag_batch / chain_apply_batch / chain_project_batch
#:   chain_diag_batch_q / chain_apply_batch_q
#:                                      -- batch-axis block rows
#:   matmul                             -- (bm, bn, bk) MXU tile
#:   rmsnorm                            -- block rows
#:   serving_grid                       -- size-bucket grid floor + waste cap
TUNABLE_KERNELS = ("chain_diag", "chain_apply", "chain_project",
                   "chain_diag_q", "chain_apply_q",
                   "chain_diag_batch", "chain_apply_batch",
                   "chain_project_batch", "chain_diag_batch_q",
                   "chain_apply_batch_q", "matmul", "rmsnorm",
                   "serving_grid")


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Launch parameters for one kernel family.  ``None`` fields defer to
    the kernel's built-in heuristic (the pre-autotune behaviour); only the
    fields a kernel reads are meaningful for it.  ``source`` records where
    the config came from: ``default`` (deterministic fallback), ``tuned``
    (fresh search winner this process), or ``cached`` (loaded winners
    file)."""
    kernel: str
    block_rows: int | None = None      # chain kernels / rmsnorm: grid row block
    lane_target: int | None = None     # chain_diag/chain_apply: lane width goal
    bm: int | None = None              # matmul output-tile rows
    bn: int | None = None              # matmul output-tile cols
    bk: int | None = None              # matmul K-panel depth
    grid_min_len: int | None = None    # serving size grid: floor
    grid_waste_cap: float | None = None  # serving size grid: padding cap
    source: str = "default"

    def key_fields(self) -> dict:
        """The tunable payload (everything except kernel/source) with
        ``None`` fields dropped -- what gets persisted and compared."""
        d = dataclasses.asdict(self)
        del d["kernel"], d["source"]
        return {k: v for k, v in d.items() if v is not None}

    def describe(self) -> str:
        """Compact ``k=v`` summary for benchmark rows and reports."""
        fields = self.key_fields()
        body = ",".join(f"{k}={v}" for k, v in sorted(fields.items()))
        return f"{self.source}({body})" if body else self.source


#: the deterministic defaults: exactly the values the kernels hardcoded
#: before the autotune subsystem existed.  Tuning disabled == this table.
DEFAULTS: dict[str, KernelConfig] = {
    "chain_diag": KernelConfig("chain_diag", block_rows=256, lane_target=512),
    "chain_apply": KernelConfig("chain_apply", block_rows=256,
                                lane_target=512),
    "chain_project": KernelConfig("chain_project", block_rows=256,
                                  lane_target=512),
    # the fixed-point lane defaults to the float lane's launch shape:
    # same staging maths, half the bytes per lane
    "chain_diag_q": KernelConfig("chain_diag_q", block_rows=256,
                                 lane_target=512),
    "chain_apply_q": KernelConfig("chain_apply_q", block_rows=256,
                                  lane_target=512),
    # batch kernels: block_rows=None keeps the VMEM-budget heuristic in
    # kernels.util.stage_packed
    "chain_diag_batch": KernelConfig("chain_diag_batch"),
    "chain_apply_batch": KernelConfig("chain_apply_batch"),
    "chain_project_batch": KernelConfig("chain_project_batch"),
    "chain_diag_batch_q": KernelConfig("chain_diag_batch_q"),
    "chain_apply_batch_q": KernelConfig("chain_apply_batch_q"),
    "matmul": KernelConfig("matmul", bm=128, bn=128, bk=512),
    "rmsnorm": KernelConfig("rmsnorm", block_rows=256),
    "serving_grid": KernelConfig("serving_grid", grid_min_len=8,
                                 grid_waste_cap=0.5),
}


def size_class(n: int) -> str:
    """Power-of-two size-class label: ``p<k>`` holds n in (2^(k-1), 2^k].
    The serving engine buckets request lengths at the same granularity, so
    one tuned entry covers one padded-length class."""
    return f"p{max(0, int(n - 1).bit_length())}" if n > 0 else "p0"


def _class_index(label: str) -> int:
    return int(label[1:])


def cache_key(kernel: str, backend: str, dtype: str, n: int = 0) -> str:
    return f"{kernel}|{backend}|{dtype}|{size_class(n)}"


class TuningCache:
    """A map from cache keys to winning ``KernelConfig``s with JSON
    persistence.  Entries are stored sorted so the same winners always
    serialize to the same bytes (the determinism tests diff files)."""

    def __init__(self, entries: dict[str, KernelConfig] | None = None):
        self.entries: dict[str, KernelConfig] = dict(entries or {})

    # -- lookup --------------------------------------------------------------

    def get(self, kernel: str, backend: str, dtype: str = "float32",
            n: int = 0) -> KernelConfig | None:
        """Exact-key lookup, then nearest tuned size-class for the same
        (kernel, backend, dtype), else None."""
        exact = self.entries.get(cache_key(kernel, backend, dtype, n))
        if exact is not None:
            return exact
        prefix = f"{kernel}|{backend}|{dtype}|"
        want = _class_index(size_class(n))
        best = None
        for key, cfg in self.entries.items():
            if not key.startswith(prefix):
                continue
            dist = abs(_class_index(key.rsplit("|", 1)[1]) - want)
            # deterministic tie-break: prefer the smaller class
            rank = (dist, _class_index(key.rsplit("|", 1)[1]))
            if best is None or rank < best[0]:
                best = (rank, cfg)
        return best[1] if best else None

    def put(self, kernel: str, backend: str, dtype: str, n: int,
            config: KernelConfig) -> None:
        self.entries[cache_key(kernel, backend, dtype, n)] = config

    def __len__(self) -> int:
        return len(self.entries)

    # -- persistence ---------------------------------------------------------

    def to_json(self) -> str:
        payload = {key: dict(sorted(cfg.key_fields().items()))
                   for key, cfg in sorted(self.entries.items())}
        return json.dumps({"version": 1, "entries": payload}, indent=1,
                          sort_keys=True) + "\n"

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "TuningCache":
        with open(path) as f:
            doc = json.load(f)
        entries = {}
        for key, fields in doc.get("entries", {}).items():
            kernel = key.split("|", 1)[0]
            entries[key] = KernelConfig(kernel=kernel, source="cached",
                                        **fields)
        return cls(entries)


# -- module state: the process-wide cache + enable switch --------------------

_ENABLED: bool | None = None          # None -> read env on first use
_CACHE: TuningCache | None = None
_CACHE_PATH: str | None = None        # None -> env or committed default


def enabled() -> bool:
    if _ENABLED is not None:
        return _ENABLED
    return os.environ.get(ENV_ENABLE, "") in ("1", "true", "yes")


def set_enabled(on: bool | None) -> None:
    """Flip cache consultation on/off (``None`` re-reads the env var).
    NOTE: compiled plans capture their config at trace time -- use
    ``repro.autotune.set_enabled``, which also clears the plan caches."""
    global _ENABLED
    _ENABLED = on


def set_cache_path(path: str | None) -> None:
    """Point the process at a different winners file (``None`` -> env /
    committed default) and drop the loaded cache."""
    global _CACHE_PATH, _CACHE
    _CACHE_PATH = path
    _CACHE = None


def set_cache(cache: TuningCache | None) -> None:
    """Install an in-memory cache directly (tests, fresh tuning runs)."""
    global _CACHE
    _CACHE = cache


def the_cache() -> TuningCache:
    """The process-wide winners cache, loaded lazily from (in order)
    ``set_cache_path``, ``$REPRO_AUTOTUNE_CACHE``, the committed
    ``default_cache.json``, else empty."""
    global _CACHE
    if _CACHE is None:
        path = _CACHE_PATH or os.environ.get(ENV_CACHE) or DEFAULT_CACHE_PATH
        _CACHE = TuningCache.load(path) if os.path.exists(path) \
            else TuningCache()
    return _CACHE


def config_for(kernel: str, backend: str, dtype: str = "float32",
               n: int = 0) -> KernelConfig:
    """THE lookup the integrated consumers call: the cached winner for
    (kernel, backend, dtype, size-class) when tuning is enabled, else the
    deterministic default.  Unknown kernels get an all-``None`` config
    (every field defers to the kernel heuristic)."""
    default = DEFAULTS.get(kernel, KernelConfig(kernel))
    if not enabled():
        return default
    hit = the_cache().get(kernel, backend, dtype, n)
    return hit if hit is not None else default


def merge(fallback: KernelConfig, override: KernelConfig) -> KernelConfig:
    """``override`` with its ``None`` fields filled from ``fallback``."""
    fields = {f.name: getattr(override, f.name)
              if getattr(override, f.name) is not None
              else getattr(fallback, f.name)
              for f in dataclasses.fields(KernelConfig)
              if f.name not in ("kernel", "source")}
    return KernelConfig(kernel=override.kernel, source=override.source,
                        **fields)
