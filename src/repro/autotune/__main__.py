"""Autotune CLI: run the pruned search, inspect the model, guard the cache.

    PYTHONPATH=src python -m repro.autotune --smoke
        pruned search on two small chain shapes + the serving grid
        (ref backend), printing the analytic paper-format table, the
        emulator cross-check, and every timed trial.

    PYTHONPATH=src python -m repro.autotune --smoke --check
        the CI gate: additionally verifies (deterministically, by replayed
        launch counts, then by generous wall-clock bounds) that the
        committed default cache does not regress versus the built-in
        defaults or versus a fresh search.

    PYTHONPATH=src python -m repro.autotune --smoke --write-default
        persist the winners to the committed default cache
        (src/repro/autotune/default_cache.json).

``--out PATH`` writes winners to an arbitrary path instead.
"""
from __future__ import annotations

import argparse
import sys

from repro.autotune import cache as tcache
from repro.autotune import costmodel, search
from repro.core import analysis


def _print_model_table() -> None:
    print("== analytic cost model, paper-format (source=model) ==")
    print(analysis.format_table(costmodel.perf_rows()))
    print("\n== cross-check vs the MorphoSys emulator ==")
    from repro.core.morphosys import programs
    import numpy as np
    rng = np.random.default_rng(0)
    ok = True
    for routine, runner in (("translation",
                             lambda n: programs.run_translation(
                                 rng.integers(-99, 99, n),
                                 rng.integers(-99, 99, n))),
                            ("scaling",
                             lambda n: programs.run_scaling(
                                 rng.integers(-99, 99, n), 5))):
        for n in (8, 64):
            model = costmodel.morphosys_cycles(routine, n)
            emu = runner(n).cycles
            ok &= model == emu
            print(f"  {routine:<12} n={n:<3} model={model:<4} emulator={emu:<4}"
                  f" {'OK' if model == emu else 'MISMATCH'}")
    if not ok:
        sys.exit("cost model disagrees with the emulator")


def _print_reports(reports) -> None:
    for rep in reports:
        print(f"\n== {rep.kernel} ({rep.backend}, {rep.dtype}, "
              f"n={rep.n}) ==")
        for t in rep.trials:
            mark = " <- winner" if t.config.key_fields() == \
                rep.winner.key_fields() else ""
            print(f"  {t.config.describe():<52} "
                  f"{t.seconds * 1e6:9.1f} us  "
                  f"(predicted {t.predicted_us:8.1f} us){mark}")


def _check(reports) -> None:
    """CI regression gate against the committed default cache.

    Deterministic first: replay the smoke workload's bucketing under the
    committed serving-grid entry and fail if it issues more launches than
    the built-in default grid.  Then wall-clock with generous slack: the
    committed config must not be grossly slower than this run's fresh
    winner (cache gone stale), and every expected key must be present.
    """
    committed = tcache.TuningCache.load(tcache.DEFAULT_CACHE_PATH)
    failures = []
    # deterministic grid gate, per traffic scale: replay each seeded
    # workload's bucketing under the committed entry for ITS size class
    default = tcache.DEFAULTS["serving_grid"]
    for label, wl in (("smoke", search.smoke_workload()),
                      ("bench64", search.bench_workload())):
        n = search.workload_size_class_n(wl)
        entry = committed.get("serving_grid", reports[0].backend,
                              "float32", n)
        if entry is None:
            failures.append(f"missing serving_grid entry for the {label} "
                            "workload")
            continue
        shape = costmodel.workload_shape(wl)
        merged = tcache.merge(default, entry)
        com_cost = costmodel.grid_cost(shape, merged.grid_min_len,
                                       merged.grid_waste_cap)
        def_cost = costmodel.grid_cost(shape, default.grid_min_len,
                                       default.grid_waste_cap)
        print(f"[check] serving_grid[{label}] launches: committed="
              f"{com_cost.launches} default={def_cost.launches}")
        if com_cost.launches > def_cost.launches:
            failures.append(
                f"committed grid {entry.describe()} schedules "
                f"{com_cost.launches} launches vs {def_cost.launches} "
                f"for the default grid on the {label} workload")
    for rep in reports:
        entry = committed.get(rep.kernel, rep.backend, rep.dtype, rep.n)
        if entry is None:
            failures.append(f"missing cache entry: {rep.kernel}|"
                            f"{rep.backend}|{rep.dtype}")
            continue
        # wall-clock guard: committed config vs this run's fresh winner,
        # measured in the same process (2x slack absorbs eager-CPU noise;
        # the launch-count gate above is the deterministic check)
        fresh = rep.winner_seconds
        timed = {tuple(sorted(t.config.key_fields().items())): t.seconds
                 for t in rep.trials}
        com_t = timed.get(tuple(sorted(entry.key_fields().items())))
        if com_t is not None and com_t > fresh * 2.0:
            failures.append(
                f"{rep.kernel}: committed config {entry.describe()} is "
                f"{com_t * 1e6:.0f}us vs fresh winner {fresh * 1e6:.0f}us")
    if failures:
        sys.exit("autotune check FAILED:\n  " + "\n  ".join(failures))
    print("[check] committed default cache: OK")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.autotune")
    ap.add_argument("--smoke", action="store_true",
                    help="pruned search on two small shapes + serving grid")
    ap.add_argument("--backend", default="ref",
                    choices=("ref", "interpret", "pallas"))
    ap.add_argument("--iters", type=int, default=5,
                    help="timer repetitions per candidate (best-of)")
    ap.add_argument("--out", default=None,
                    help="write winners JSON to this path")
    ap.add_argument("--write-default", action="store_true",
                    help="write winners to the committed default cache")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: fail if the committed cache regresses")
    args = ap.parse_args(argv)

    _print_model_table()
    if not (args.smoke or args.check):
        print("\n(nothing to tune; pass --smoke to run the pruned search)")
        return

    cache, reports = search.smoke_search(args.backend, iters=args.iters)
    _print_reports(reports)

    if args.check:
        _check(reports)
    if args.write_default:
        cache.save(tcache.DEFAULT_CACHE_PATH)
        print(f"\nwrote {len(cache)} winners -> {tcache.DEFAULT_CACHE_PATH}")
    elif args.out:
        cache.save(args.out)
        print(f"\nwrote {len(cache)} winners -> {args.out}")


if __name__ == "__main__":
    main()
