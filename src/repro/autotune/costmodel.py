"""Analytic per-kernel cost models: the paper's methodology as a pruner.

The source paper's loop is map -> predict analytically -> validate on the
emulator.  This module is the "predict" step for the TPU mapping: every
tunable kernel gets a closed-form cost built from the same byte accounting
``repro.kernels.opcount`` records at runtime (HBM bytes under the
memory-bound model), plus FLOPs and a per-launch / per-grid-step overhead
term.  The tuner uses these predictions to PRUNE the candidate space before
spending wall-clock on the empirical timer -- and because the byte formulas
are shared with ``opcount``, the predictions are cross-checkable against
what the runtime actually records (``tests/test_autotune.py``).

Two validation hooks tie the model back to the paper:

  * ``morphosys_cycles`` -- closed-form cycle counts for the paper's
    translation/scaling listings (Tables 1-2 structure + the fitted DMA
    wait model), exact against both the published Table 5 numbers and the
    ``core.morphosys`` emulator for the 8- and 64-element cases;
  * ``perf_rows`` -- the predictions rendered through the same
    ``core.analysis.PerfRow`` derivation the paper tables use, so
    predicted numbers print in paper-table format next to emulator rows.
"""
from __future__ import annotations

import dataclasses
import math
import typing

from repro.autotune.cache import DEFAULTS, KernelConfig, merge
from repro.core import analysis
from repro.core.morphosys.isa import dma_wait
from repro.core.morphosys.rc_array import N as RC_N

#: fixed per-launch dispatch overhead (python call + XLA arg staging +
#: result sync share), measured on the CPU ref path the tuner times; the
#: absolute value matters less than its ratio to the byte term -- it is
#: what makes "fewer launches" beat "fewer padded bytes" at small sizes.
LAUNCH_OVERHEAD_US = 30.0
#: per-grid-step overhead inside one launch (block bookkeeping); small,
#: but it is the term that rewards larger blocks until VMEM runs out.
STEP_OVERHEAD_US = 0.02
#: effective streaming bandwidth for the predicted-time denominator.  The
#: empirical timer runs wherever it runs; the model only needs candidate
#: ORDERING to be right, so one conservative CPU-class figure is used for
#: every backend (the TPU projection in benchmarks uses roofline.HBM_BW).
MODEL_BW = 20e9
#: VMEM feasibility budget per core (v5e-class); candidates whose working
#: set exceeds this are rejected before timing.
VMEM_BYTES = 16 * 2 ** 20


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """One candidate's analytic cost.  ``predicted_us`` is the pruning
    score: launch overhead + grid-step overhead + streaming time."""
    kernel: str
    hbm_bytes: int
    flops: int
    launches: int
    grid_steps: int
    feasible: bool = True

    @property
    def predicted_us(self) -> float:
        if not self.feasible:
            return math.inf
        return (self.launches * LAUNCH_OVERHEAD_US
                + self.grid_steps * STEP_OVERHEAD_US
                + self.hbm_bytes / MODEL_BW * 1e6)


def _cfg(kernel: str, config: KernelConfig | None) -> KernelConfig:
    base = DEFAULTS.get(kernel, KernelConfig(kernel))
    return base if config is None else merge(base, config)


def _cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


# -- chain kernels (the paper's one-pass composite) ---------------------------

#: plan kind -> (single-chain kernel, batched kernel).  The ``_q`` kinds
#: are the int16 fixed-point lane: same staging maths, 2-byte words.
_CHAIN_KERNELS = {"diag": ("chain_diag", "chain_diag_batch"),
                  "matrix": ("chain_apply", "chain_apply_batch"),
                  "projective": ("chain_project", "chain_project_batch"),
                  "diag_q": ("chain_diag_q", "chain_diag_batch_q"),
                  "matrix_q": ("chain_apply_q", "chain_apply_batch_q")}


def _base_kind(kind: str) -> str:
    """The plan-kind lattice rung of a (possibly fixed-point) cost kind:
    byte passes and parameter-word counts come from the ONE ``opcount``
    table keyed by the base kind; the ``_q`` suffix only halves the word
    size."""
    return kind[:-2] if kind.endswith("_q") else kind


def _kind_itemsize(kind: str, itemsize: int | None) -> int:
    return itemsize if itemsize is not None else \
        (2 if kind.endswith("_q") else 4)


def chain_param_bytes(d: int, kind: str, itemsize: int = 4) -> int:
    """Composed-parameter bytes of one folded chain: (d,d)+(d,) words for a
    matrix plan, (d,)+(d,) for a diagonal plan, (d+1)^2 + 2d (homogeneous
    H plus cull bounds) for a projective plan -- delegating to the ONE
    table in ``opcount`` that ``TransformChain.apply`` and the serving
    engine also record from."""
    from repro.kernels import opcount          # late: keep imports one-way
    return opcount.chain_param_words(d, _base_kind(kind)) * itemsize


def _chain_flops_per_point(d: int, kind: str) -> int:
    """VPU work per point: one MAC for diag lanes, 2d-1 rolled MACs for
    matrix lanes, and for projective lanes a second MAC set (the
    homogeneous w), the divide, and the cull compares.  The fixed-point
    kinds run the same MAC schedule (in int32)."""
    kind = _base_kind(kind)
    if kind == "diag":
        return 2 * d
    if kind == "matrix":
        return 2 * (2 * d - 1) * d
    return (4 * (2 * d - 1) + 4) * d


def _chain_passes(kind: str) -> int:
    from repro.kernels import opcount          # late: keep imports one-way
    return opcount.chain_passes(_base_kind(kind))


def chain_cost(n_points: int, d: int, kind: str,
               config: KernelConfig | None = None, *,
               itemsize: int | None = None) -> CostEstimate:
    """One fused single-chain launch over (N, d) points: the point buffer
    moves once in, once out (plus the mask pass for projective plans),
    plus the O(1) composed parameters.  ``itemsize`` defaults by kind: 4
    bytes on the float kinds, 2 on the ``_q`` (int16 Qm.n) kinds -- the
    halved-byte prediction the fixed-point benchmark validates."""
    from repro.kernels import opcount, util  # late: keep imports one-way
    kernel = _CHAIN_KERNELS[kind][0]
    itemsize = _kind_itemsize(kind, itemsize)
    cfg = _cfg(kernel, config)
    nbytes = opcount.fused_chain_bytes(n_points, d, itemsize=itemsize,
                                       kind=_base_kind(kind))
    # lane layout: w lanes per row, block_rows rows per grid step -- the
    # same staging math the kernels run (kernels.util is the one source)
    w = util.chain_width(d, target=cfg.lane_target or 512)
    rows = _cdiv(n_points * d, w)
    steps = _cdiv(rows, cfg.block_rows or 256)
    flops = n_points * _chain_flops_per_point(d, kind)
    block_bytes = 2 * (cfg.block_rows or 256) * w * itemsize
    return CostEstimate(kernel, nbytes, flops, launches=1, grid_steps=steps,
                        feasible=block_bytes <= VMEM_BYTES)


def packed_chain_cost(bsz: int, lpad: int, d: int, kind: str,
                      config: KernelConfig | None = None, *,
                      itemsize: int | None = None) -> CostEstimate:
    """One packed-bucket launch (B requests padded to L points): the same
    byte count ``opcount.packed_chain_bytes`` records per serving launch.
    ``itemsize`` defaults by kind (2-byte words on the ``_q`` kinds)."""
    from repro.kernels import opcount, util  # late: keep imports one-way
    kernel = _CHAIN_KERNELS[kind][1]
    itemsize = _kind_itemsize(kind, itemsize)
    cfg = _cfg(kernel, config)
    nbytes = opcount.packed_chain_bytes(bsz, lpad, d, itemsize=itemsize,
                                        kind=_base_kind(kind))
    g = util.lane_group(d)
    wr = max(1, _cdiv(lpad * d, g)) * g
    bm = cfg.block_rows or util.packed_budget_rows(wr, itemsize)
    steps = _cdiv(bsz, max(1, bm))
    flops = bsz * lpad * _chain_flops_per_point(d, kind)
    block_bytes = 2 * max(1, bm) * wr * itemsize
    return CostEstimate(kernel, nbytes, flops, launches=1, grid_steps=steps,
                        feasible=block_bytes <= VMEM_BYTES)


@dataclasses.dataclass(frozen=True)
class LaunchPrediction:
    """The cost model's view of ONE dispatched serving launch, attached
    to the launch's trace instant at dispatch time (``serving.engine.
    _count_launch``) so the profiler can fold predicted-vs-observed
    ratios out of the span stream.

    ``hbm_bytes`` and ``flops`` come from ``packed_chain_cost``, whose
    byte formula IS ``opcount.packed_chain_bytes`` -- the same number the
    engine records as the launch's observed ``hbm_bytes`` -- so the
    byte ratio is exactly 1.0 by construction on every backend, and any
    drift between the two is a real accounting bug, not model error.
    ``m1_cycles`` is the paper-methodology projection
    (``m1_chain_cycles``): what this launch would cost on the M1 array.
    """
    kernel: str
    hbm_bytes: int
    flops: int
    m1_cycles: int


def predict_launch(kind: str, bsz: int, lpad: int, d: int, *,
                   qformat: str | None = None,
                   itemsize: int | None = None) -> LaunchPrediction:
    """Predict one packed-bucket launch (B requests padded to L points)
    of a serving plan: the per-launch prediction API the engine calls at
    dispatch time.  ``kind`` is the plan kind (``diag`` / ``matrix`` /
    ``projective``); a non-None ``qformat`` selects the int16 ``_q``
    cost kind (2-byte words), mirroring how the engine's plans carry
    the format separately from the kind."""
    cost_kind = kind if kind.endswith("_q") or qformat is None \
        else kind + "_q"
    est = packed_chain_cost(bsz, lpad, d, cost_kind, itemsize=itemsize)
    return LaunchPrediction(kernel=est.kernel, hbm_bytes=est.hbm_bytes,
                            flops=est.flops,
                            m1_cycles=m1_chain_cycles(cost_kind,
                                                      bsz * lpad, d))


# -- matmul / rmsnorm ---------------------------------------------------------

def matmul_cost(m: int, k: int, n: int, config: KernelConfig | None = None,
                *, itemsize: int = 2) -> CostEstimate:
    """Tiled matmul: operands move once (accumulation lives in VMEM
    scratch), 2mkn FLOPs, grid steps follow the (bm, bn, bk) tile; the
    working set 2*(bm*bk + bk*bn)*itemsize + bm*bn*4 must fit VMEM."""
    cfg = _cfg("matmul", config)
    bm, bn, bk = cfg.bm or 128, cfg.bn or 128, cfg.bk or 512
    nbytes = (m * k + k * n + m * n) * itemsize
    steps = _cdiv(m, bm) * _cdiv(n, bn) * _cdiv(k, bk)
    working = 2 * (bm * bk + bk * bn) * itemsize + bm * bn * 4
    return CostEstimate("matmul", nbytes, 2 * m * k * n, launches=1,
                        grid_steps=steps, feasible=working <= VMEM_BYTES)


def rmsnorm_cost(m: int, n: int, config: KernelConfig | None = None, *,
                 itemsize: int = 4) -> CostEstimate:
    """Fused rmsnorm: one read + one write of (M, N) plus the (N,) gain;
    rows blocked by ``block_rows`` (trailing dim never splits -- the mean
    needs the whole row)."""
    cfg = _cfg("rmsnorm", config)
    bm = cfg.block_rows or 256
    nbytes = 2 * m * n * itemsize + n * itemsize
    working = 2 * bm * n * itemsize
    return CostEstimate("rmsnorm", nbytes, 4 * m * n, launches=1,
                        grid_steps=_cdiv(m, bm),
                        feasible=working <= VMEM_BYTES)


# -- serving size grid --------------------------------------------------------

def grid_cost(requests: typing.Sequence[tuple[typing.Hashable, str, int, int]],
              min_len: int, waste_cap: float, *,
              itemsize: int = 4) -> CostEstimate:
    """Analytic cost of serving one workload under a candidate size grid.

    ``requests`` is ``(structure_key, kind, d, n_points)`` per request --
    the shape of the workload, no point data needed.  The model replays
    the engine's bucketing ((structure, padded length) -> one launch) and
    charges each bucket its packed byte volume plus the per-launch
    overhead: exactly the trade the grid knobs steer (a coarser grid means
    fewer launches but more padded bytes).
    """
    from repro.kernels import opcount
    from repro.serving import bucketing
    buckets: dict[tuple, list[tuple[str, int, int]]] = {}
    for skey, kind, d, n in requests:
        if n <= 0:
            continue
        lpad = bucketing.padded_length(n, min_len=min_len,
                                       waste_cap=waste_cap)
        buckets.setdefault((skey, lpad), []).append((kind, d, n))
    nbytes = 0
    flops = 0
    for (_skey, lpad), reqs in buckets.items():
        kind, d, _ = reqs[0]
        nbytes += opcount.packed_chain_bytes(len(reqs), lpad, d,
                                             itemsize=itemsize, kind=kind)
        flops += len(reqs) * lpad * _chain_flops_per_point(d, kind)
    return CostEstimate("serving_grid", nbytes, flops,
                        launches=len(buckets), grid_steps=len(buckets))


def workload_shape(reqs) -> list[tuple[typing.Hashable, str, int, int]]:
    """Project a ``[(chain, points), ...]`` workload to the shape tuples
    ``grid_cost`` consumes (structure key, plan kind, dim, point count)."""
    out = []
    for chain, pts in reqs:
        n = int(pts.size // chain.dim)
        out.append((chain.structure, chain.plan_kind, chain.dim, n))
    return out


# -- paper cross-check: MorphoSys cycle model ---------------------------------

def morphosys_cycles(routine: str, n: int) -> int:
    """Closed-form cycle count for the paper's TinyRISC listings.

    Program structure (Tables 1-2, generalised to n a multiple of 8):
    frame-buffer loads of 2 + dma_wait(n) slots each, a 5-slot context
    load, the per-column compute/writeback instructions, and the 2-slot
    store; cycles = instructions - 1.  Reproduces the published Table 5
    numbers (96/21 translation, 55/14 scaling) and the emulator exactly.
    """
    if n % RC_N or n <= 0:
        raise ValueError(f"n must be a positive multiple of {RC_N}, got {n}")
    ncols = n // RC_N
    if routine == "translation":       # two operand loads; ldli+dbcdc+wfbi
        length = 2 * (2 + dma_wait(n)) + 5 + 3 * ncols + 2
    elif routine == "scaling":         # one operand load; sbcb+wfbi
        length = (2 + dma_wait(n)) + 5 + 2 * ncols + 2
    else:
        raise ValueError(f"no closed form for routine {routine!r}")
    return length - 1


def m1_chain_cycles(kind: str, n_points: int, d: int) -> int:
    """Projected M1 cycle count for one packed chain launch: the
    Tables 1-2 program skeleton generalised beyond the paper's two
    routines.  The element stream (``n_points * d`` words, padded to a
    multiple of the RC-array width) loads through the frame buffer in
    ``chain_passes(kind)`` operand passes of ``2 + dma_wait`` slots
    each, a 5-slot context load configures the array, each 8-element
    column spends one instruction slot per MAC-pair of the kind's
    per-point schedule plus the writeback, and the 2-slot store drains;
    cycles = instructions - 1, exactly the ``morphosys_cycles``
    accounting.  This is a PROJECTION (the paper only published the
    translation/scaling listings, which ``morphosys_cycles`` reproduces
    exactly) -- deterministic, monotone in the launch shape, and used
    for attribution, never for gating against the emulator."""
    base = _base_kind(kind)
    if base not in ("diag", "matrix", "projective"):
        raise ValueError(f"no M1 projection for plan kind {kind!r}")
    n = max(RC_N, _cdiv(max(1, n_points) * d, RC_N) * RC_N)
    ncols = n // RC_N
    per_col = _chain_flops_per_point(d, base) // (2 * d) + 1
    length = (_chain_passes(base) * (2 + dma_wait(n)) + 5
              + per_col * ncols + 2)
    return length - 1


def perf_rows() -> list[analysis.PerfRow]:
    """The analytic predictions in the paper's table format (source
    ``model``), for the 8- and 64-element cases the paper publishes --
    directly comparable against the emulator rows ``benchmarks.
    paper_tables`` derives with source ``emulator``."""
    rows = []
    for routine in ("translation", "scaling"):
        for n in (8, 64):
            rows.append(analysis.derive(routine, "m1", n,
                                        morphosys_cycles(routine, n),
                                        source="model"))
    return rows


# -- pruning ------------------------------------------------------------------

def prune(candidates: typing.Sequence[KernelConfig],
          cost_fn: typing.Callable[[KernelConfig], CostEstimate],
          keep: int) -> list[KernelConfig]:
    """Top-``keep`` candidates by predicted cost.  Deterministic: ties
    break on the candidate's persisted field repr, and infeasible
    candidates (VMEM) never survive."""
    scored = [(cost_fn(c).predicted_us, repr(sorted(c.key_fields().items())),
               c) for c in candidates]
    scored = [s for s in scored if s[0] != math.inf]
    scored.sort(key=lambda s: (s[0], s[1]))
    return [c for _, _, c in scored[:max(1, keep)]]
