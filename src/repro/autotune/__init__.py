"""Autotune subsystem: analytic cost models + on-device search.

Closes the paper's performance-analysis loop for the TPU mapping: instead
of hardcoded launch parameters, every tunable kernel (the chain kernels,
``matmul``, ``rmsnorm``) and the serving engine's size-bucket grid can be
driven from a persisted tuning cache:

    candidate space --analytic prune--> survivors --empirical timer-->
    winner --JSON cache--> consulted at plan-build / trace time

Three layers (matching the subsystem design):

  * ``costmodel``  -- closed-form HBM-byte / FLOP / launch-overhead models
    sharing the ``kernels.opcount`` accounting, printable in the paper's
    table format and cross-checked against the MorphoSys cycle emulator;
  * ``search`` + ``cache`` -- candidate generation, pruning, the
    best-of-iters timer, and the JSON winners cache keyed by
    (kernel, backend, dtype, size-class);
  * integration -- ``core.transform_chain`` plans, the serving engine's
    batch plans and size grid, and ``ops.matmul``/``ops.rmsnorm`` consult
    ``config_for`` when tuning is enabled.

Tuning is OFF by default: ``config_for`` then returns the deterministic
``DEFAULTS`` (the historical hardcoded values), so nothing changes until
``repro.autotune.set_enabled(True)`` (or ``REPRO_AUTOTUNE=1``).  A
committed ref-backend winners file (``default_cache.json``) means enabling
tuning never requires a tuning run.  CLI::

    python -m repro.autotune --smoke            # pruned search, 2 shapes
    python -m repro.autotune --smoke --check    # CI: regression vs cache
"""
from __future__ import annotations

from repro.autotune.cache import (DEFAULT_CACHE_PATH, DEFAULTS, KernelConfig,
                                  TuningCache, cache_key, config_for, enabled,
                                  set_cache, set_cache_path, size_class,
                                  the_cache)

__all__ = [
    "DEFAULT_CACHE_PATH", "DEFAULTS", "KernelConfig", "TuningCache",
    "cache_key", "config_for", "enabled", "set_cache", "set_cache_path",
    "set_enabled", "size_class", "the_cache", "smoke_search", "tune_chain",
    "tune_serving_grid", "tune_matmul", "tune_rmsnorm",
]


def set_enabled(on: bool | None) -> None:
    """Enable/disable cache consultation process-wide AND drop the chain /
    serving plan caches: compiled plans capture their kernel config at
    trace time, so a stale plan would keep the old config alive."""
    from repro.autotune import cache as _cache
    _cache.set_enabled(on)
    from repro.core import transform_chain
    from repro.serving import engine
    transform_chain.clear_plan_cache()
    engine.clear_plan_cache()


def __getattr__(name: str):
    # search (and through it jax/kernels) loads lazily so that importing
    # repro.autotune.cache from kernel ops modules stays cycle-free
    if name in ("smoke_search", "tune_chain", "tune_serving_grid",
                "tune_matmul", "tune_rmsnorm"):
        from repro.autotune import search
        return getattr(search, name)
    if name in ("costmodel", "search"):
        import importlib
        return importlib.import_module(f"repro.autotune.{name}")
    raise AttributeError(f"module 'repro.autotune' has no attribute {name!r}")
