"""On-device search: candidate generation -> analytic prune -> timer -> cache.

The tuner's discipline, for every tunable kernel:

  1. enumerate a small closed candidate space (block shapes, lane widths,
     matmul tiles, serving-grid knobs);
  2. prune it with the analytic cost model (``costmodel``) -- infeasible
     candidates (VMEM) die here, and only the ``keep`` cheapest survive to
     be timed;
  3. time the survivors empirically (min-of-iters after a warmup call,
     through the SAME public entry points production uses);
  4. keep the default configuration unless a candidate beats it by more
     than the noise floor, and persist the winner to the tuning cache.

Every ``tune_*`` entry accepts ``measure=`` -- a ``cfg -> seconds``
callable replacing the wall-clock timer -- which is how the determinism
tests make "same inputs -> same winners file" a hard property (and how a
cost-model-only tuning mode works: pass the prediction as the measure).
"""
from __future__ import annotations

import dataclasses
import time
import typing

from repro.autotune import costmodel
from repro.autotune.cache import DEFAULTS, KernelConfig, TuningCache

#: a candidate must beat the default by this fraction to replace it --
#: below the floor, timer noise would make winners flap run to run.
NOISE_FLOOR = 0.03


# -- candidate spaces ---------------------------------------------------------

def chain_candidates(kernel: str) -> list[KernelConfig]:
    """Single-chain kernels: grid row block x lane-packing width."""
    return [KernelConfig(kernel, block_rows=bm, lane_target=w,
                         source="candidate")
            for bm in (64, 128, 256, 512)
            for w in (256, 512, 1024)]


def chain_batch_candidates(kernel: str) -> list[KernelConfig]:
    """Batched chain kernels: batch-axis block rows (None keeps the
    stager's VMEM-budget heuristic)."""
    return [KernelConfig(kernel, source="candidate")] + \
        [KernelConfig(kernel, block_rows=bm, source="candidate")
         for bm in (8, 16, 32, 64, 128)]


def matmul_candidates() -> list[KernelConfig]:
    return [KernelConfig("matmul", bm=bm, bn=bn, bk=bk, source="candidate")
            for bm in (128, 256) for bn in (128, 256)
            for bk in (256, 512, 1024)]


def rmsnorm_candidates() -> list[KernelConfig]:
    return [KernelConfig("rmsnorm", block_rows=bm, source="candidate")
            for bm in (64, 128, 256, 512)]


def grid_candidates() -> list[KernelConfig]:
    """Serving size grid: floor x waste cap.  Coarser floors merge small
    size classes (fewer launches, more padding); tighter caps refine the
    grid (more launches, less padded traffic)."""
    return [KernelConfig("serving_grid", grid_min_len=m, grid_waste_cap=c,
                         source="candidate")
            for m in (4, 8, 16, 32, 64)
            for c in (0.125, 0.25, 0.5)]


def candidates_for(kernel: str) -> list[KernelConfig]:
    if kernel in ("chain_diag", "chain_apply", "chain_project",
                  "chain_diag_q", "chain_apply_q"):
        return chain_candidates(kernel)
    if kernel in ("chain_diag_batch", "chain_apply_batch",
                  "chain_project_batch", "chain_diag_batch_q",
                  "chain_apply_batch_q"):
        return chain_batch_candidates(kernel)
    if kernel == "matmul":
        return matmul_candidates()
    if kernel == "rmsnorm":
        return rmsnorm_candidates()
    if kernel == "serving_grid":
        return grid_candidates()
    raise ValueError(f"no candidate space for kernel {kernel!r}")


# -- the timer ----------------------------------------------------------------

def _time_best(fn: typing.Callable[[], typing.Any], iters: int) -> float:
    """Best-of-``iters`` seconds for ``fn()`` after one warmup call
    (compile + staging), blocking on every jax leaf in the result."""
    import jax
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


@dataclasses.dataclass(frozen=True)
class TrialResult:
    config: KernelConfig
    seconds: float
    predicted_us: float


@dataclasses.dataclass(frozen=True)
class TuneReport:
    """One tuning decision: the winner plus every timed trial (the CLI
    prints these; benchmarks record tuned-vs-default from them)."""
    kernel: str
    backend: str
    dtype: str
    n: int
    winner: KernelConfig
    trials: tuple[TrialResult, ...]

    @property
    def default_seconds(self) -> float:
        return self.trials[0].seconds      # default is always trial 0

    @property
    def winner_seconds(self) -> float:
        key = self.winner.key_fields()
        return min(t.seconds for t in self.trials
                   if t.config.key_fields() == key)


def _is_default(kernel: str, cfg: KernelConfig) -> bool:
    return cfg.key_fields() == DEFAULTS[kernel].key_fields()


def _run_trials(kernel: str, backend: str, dtype: str, n: int,
                candidates: list[KernelConfig],
                cost_fn: typing.Callable[[KernelConfig], typing.Any],
                measure: typing.Callable[[KernelConfig], float],
                *, keep: int, cache: TuningCache | None) -> TuneReport:
    """Prune -> time (default always first) -> pick -> cache."""
    survivors = costmodel.prune(candidates, cost_fn, keep)
    default = DEFAULTS[kernel]
    trials_cfgs = [default] + [c for c in survivors
                               if not _is_default(kernel, c)]
    trials = tuple(TrialResult(c, measure(c), cost_fn(c).predicted_us)
                   for c in trials_cfgs)
    # incumbent scan: a candidate must beat the current best by the noise
    # floor to take over, so the default survives timer noise and ties
    # resolve to the deterministically-first (cheapest-predicted) survivor
    best = trials[0]
    for t in trials[1:]:
        if t.seconds < best.seconds * (1.0 - NOISE_FLOOR):
            best = t
    # a default that merely kept its seat stays labelled "default" -- only
    # a candidate that actually beat it earns "tuned"
    winner = dataclasses.replace(
        best.config, source="tuned" if best is not trials[0] else "default")
    if cache is not None:
        cache.put(kernel, backend, dtype, n, winner)
    return TuneReport(kernel, backend, dtype, n, winner, trials)


# -- per-kernel tuners --------------------------------------------------------

def _ref_ignores_launch_knobs(kernel: str, backend: str, measure) -> bool:
    """True when searching would time identical code: the ``ref`` backend
    is the pure-jnp oracle and never reads the launch knobs, so on it a
    wall-clock search over kernel configs caches nothing but timer noise
    -- the winner is pinned to the default instead.  An injected
    ``measure`` (tests, cost-model-only tuning) overrides this."""
    return measure is None and backend == "ref" and kernel != "serving_grid"


def tune_chain(kernel: str, backend: str, *, n_points: int, d: int = 2,
               dtype: str = "float32", cache: TuningCache | None = None,
               measure: typing.Callable[[KernelConfig], float] | None = None,
               keep: int = 4, iters: int = 3) -> TuneReport:
    """Tune a single-chain kernel (``chain_diag`` / ``chain_apply`` /
    ``chain_project`` or their ``_q`` fixed-point twins) at one
    (points, dim) shape through the public op entry.  Fixed-point kernels
    cache under the Qm.n format name as the dtype (pass e.g.
    ``dtype="q8.7"``); their timing inputs are the float inputs quantised
    through ``repro.quantize``, so the tuner measures the lane it
    ships."""
    kind = {"chain_diag": "diag", "chain_apply": "matrix",
            "chain_project": "projective", "chain_diag_q": "diag_q",
            "chain_apply_q": "matrix_q"}[kernel]
    candidates = [] if _ref_ignores_launch_knobs(kernel, backend, measure) \
        else candidates_for(kernel)
    if measure is None:
        import numpy as np
        import jax.numpy as jnp
        from repro import kernels
        rng = np.random.default_rng(0)
        pts = jnp.asarray(rng.standard_normal((n_points, d)), jnp.float32)
        if kind in ("diag_q", "matrix_q"):
            from repro import quantize
            fmt = quantize.as_qformat(dtype)
            pq = jnp.asarray(fmt.quantize(np.asarray(pts)))
            if kind == "diag_q":
                s = jnp.asarray(fmt.quantize(rng.uniform(0.5, 2.0, d)))
                t = jnp.asarray(fmt.quantize(rng.uniform(-1, 1, d)))
                entry = lambda cfg: kernels.chain_diag_q(
                    pq, s, t, n_frac=fmt.n, backend=backend, config=cfg)
            else:
                a = jnp.asarray(fmt.quantize(rng.standard_normal((d, d))))
                t = jnp.asarray(fmt.quantize(rng.uniform(-1, 1, d)))
                entry = lambda cfg: kernels.chain_apply_q(
                    pq, a, t, n_frac=fmt.n, backend=backend, config=cfg)
        elif kind == "diag":
            s = jnp.asarray(rng.uniform(0.5, 2.0, d), jnp.float32)
            t = jnp.asarray(rng.uniform(-1, 1, d), jnp.float32)
            entry = lambda cfg: kernels.chain_diag(
                pts, s, t, backend=backend, config=cfg)
        elif kind == "matrix":
            a = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
            t = jnp.asarray(rng.uniform(-1, 1, d), jnp.float32)
            entry = lambda cfg: kernels.chain_apply(
                pts, a, t, backend=backend, config=cfg)
        else:
            from repro.serving import workload
            # time on the SAME matrix distribution the served traffic
            # draws (workload.random_projective is the one recipe)
            hj = jnp.asarray(workload.random_projective(rng, d))
            entry = lambda cfg: kernels.chain_project(
                pts, hj, -4.0, 4.0, backend=backend, config=cfg)
        measure = lambda cfg: _time_best(lambda: entry(cfg), iters)
    cost = lambda cfg: costmodel.chain_cost(n_points, d, kind, cfg)
    return _run_trials(kernel, backend, dtype, n_points, candidates, cost,
                       measure, keep=keep, cache=cache)


def tune_serving_grid(reqs, backend: str, *,
                      cache: TuningCache | None = None,
                      measure: typing.Callable[[KernelConfig], float] | None
                      = None, keep: int = 4, iters: int = 2) -> TuneReport:
    """Tune the serving size grid (floor + waste cap) on one workload:
    ``reqs`` is the ``[(chain, points), ...]`` list the GeometryServer
    serves.  The analytic prune replays the engine's bucketing per
    candidate; the timer serves the real workload under each survivor.
    The winner is cached at the workload's largest request length (the
    size-class convention grid consumers look up by), so grids tuned at
    different traffic scales coexist in one cache."""
    shape = costmodel.workload_shape(reqs)
    n = workload_size_class_n(reqs)
    if measure is None:
        from repro import serving

        def measure(cfg: KernelConfig) -> float:
            srv = serving.GeometryServer(backend=backend,
                                         min_len=cfg.grid_min_len,
                                         waste_cap=cfg.grid_waste_cap)
            return _time_best(lambda: srv.serve(reqs), iters)
    default = DEFAULTS["serving_grid"]
    cost = lambda cfg: costmodel.grid_cost(
        shape,
        cfg.grid_min_len if cfg.grid_min_len is not None
        else default.grid_min_len,
        cfg.grid_waste_cap if cfg.grid_waste_cap is not None
        else default.grid_waste_cap)
    return _run_trials("serving_grid", backend, "float32", n,
                       candidates_for("serving_grid"), cost, measure,
                       keep=keep, cache=cache)


def tune_matmul(backend: str, *, m: int, k: int, n: int,
                dtype: str = "bfloat16", cache: TuningCache | None = None,
                measure: typing.Callable[[KernelConfig], float] | None = None,
                keep: int = 4, iters: int = 3) -> TuneReport:
    candidates = [] if _ref_ignores_launch_knobs("matmul", backend, measure) \
        else candidates_for("matmul")
    if measure is None:
        import numpy as np
        import jax.numpy as jnp
        from repro import kernels
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((m, k)), dtype)
        y = jnp.asarray(rng.standard_normal((k, n)), dtype)
        measure = lambda cfg: _time_best(
            lambda: kernels.matmul(x, y, backend=backend, bm=cfg.bm,
                                   bn=cfg.bn, bk=cfg.bk), iters)
    itemsize = 2 if dtype == "bfloat16" else 4
    cost = lambda cfg: costmodel.matmul_cost(m, k, n, cfg, itemsize=itemsize)
    return _run_trials("matmul", backend, dtype, m * n, candidates, cost,
                       measure, keep=keep, cache=cache)


def tune_rmsnorm(backend: str, *, m: int, n: int, dtype: str = "float32",
                 cache: TuningCache | None = None,
                 measure: typing.Callable[[KernelConfig], float] | None = None,
                 keep: int = 3, iters: int = 3) -> TuneReport:
    candidates = [] if _ref_ignores_launch_knobs("rmsnorm", backend,
                                                 measure) \
        else candidates_for("rmsnorm")
    if measure is None:
        import numpy as np
        import jax.numpy as jnp
        from repro import kernels
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((m, n)), dtype)
        g = jnp.ones((n,), dtype)
        measure = lambda cfg: _time_best(
            lambda: kernels.rmsnorm(x, g, backend=backend, config=cfg), iters)
    cost = lambda cfg: costmodel.rmsnorm_cost(m, n, cfg)
    return _run_trials("rmsnorm", backend, dtype, m * n, candidates, cost,
                       measure, keep=keep, cache=cache)


# -- the smoke search (CI; two small shapes + the serving grid) ---------------

SMOKE_SEED = 1234             #: workload seed shared with --check re-runs
SMOKE_REQUESTS = 24
SMOKE_MAX_POINTS = 96
#: the benchmark-scale workload (shared with benchmarks/autotune_bench.py:
#: tune where you serve -- a grid tuned on small traffic does not
#: transfer to large traffic, so both scales get their own cache entry)
BENCH_SEED = 1904
BENCH_REQUESTS = 64
BENCH_MAX_POINTS = 1024


def workload_size_class_n(reqs) -> int:
    """The n a workload's grid entry is cached/looked up under: the
    largest request length (point count) in the mix."""
    return max((int(p.size // c.dim) for c, p in reqs), default=0)


def smoke_workload():
    from repro.serving import workload
    return workload.random_workload(seed=SMOKE_SEED,
                                    n_requests=SMOKE_REQUESTS,
                                    max_points=SMOKE_MAX_POINTS,
                                    templates=workload.TEMPLATES[:4])


def bench_workload():
    from repro.serving import workload
    return workload.random_workload(seed=BENCH_SEED,
                                    n_requests=BENCH_REQUESTS,
                                    max_points=BENCH_MAX_POINTS)


def smoke_search(backend: str = "ref", *,
                 cache: TuningCache | None = None,
                 measure: typing.Callable[[KernelConfig], float] | None = None,
                 iters: int = 3) -> tuple[TuningCache, list[TuneReport]]:
    """The pruned search CI runs: three small chain shapes (diagonal 3D,
    general 2D, projective 3D), the fixed-point twins of the affine two
    (int16 q8.7 -- cached under the format name as the dtype), plus the
    serving grid on BOTH seeded workloads (the tiny smoke mix and the
    benchmark-scale 64-request mix -- each caches at its own size
    class).  Returns the populated cache and the per-kernel reports."""
    cache = cache if cache is not None else TuningCache()
    reports = [
        tune_chain("chain_diag", backend, n_points=2048, d=3, cache=cache,
                   measure=measure, iters=iters),
        tune_chain("chain_apply", backend, n_points=2048, d=2, cache=cache,
                   measure=measure, iters=iters),
        tune_chain("chain_project", backend, n_points=2048, d=3,
                   cache=cache, measure=measure, iters=iters),
        tune_chain("chain_diag_q", backend, n_points=2048, d=3,
                   dtype="q8.7", cache=cache, measure=measure, iters=iters),
        tune_chain("chain_apply_q", backend, n_points=2048, d=2,
                   dtype="q8.7", cache=cache, measure=measure, iters=iters),
        tune_serving_grid(smoke_workload(), backend, cache=cache,
                          measure=measure, iters=max(1, iters - 1)),
        tune_serving_grid(bench_workload(), backend, cache=cache,
                          measure=measure, iters=max(2, iters - 1)),
    ]
    return cache, reports
