"""Fault-tolerant checkpointing: atomic npz shards + manifest.

Protocol (restart-safe by construction):
  1. arrays written to ``step_<k>.tmp/`` as one npz per top-level group,
  2. ``manifest.json`` (tree signature, shapes, step, wall time) written last,
  3. directory atomically renamed to ``step_<k>/`` -- a checkpoint without a
     completed rename never existed.

``latest_step`` only returns fully-renamed checkpoints, so a job killed
mid-save restarts from the previous good step.  Restoration is
template-based: the caller supplies a pytree of the right structure (from
``model.init`` under ``jax.eval_shape`` -- no real init cost) and arrays are
matched by tree path, which also validates structure drift.  Async saves run
on a daemon thread (device->host copy happens on the caller's thread so the
step's arrays are snapshotted before the optimizer mutates donated buffers).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _tree_items(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _signature(tree) -> str:
    items = [(k, tuple(np.shape(v)), str(np.asarray(v).dtype) if not hasattr(v, "dtype") else str(v.dtype))
             for k, v in _tree_items(tree)]
    return hashlib.sha256(json.dumps(items, sort_keys=True).encode()).hexdigest()


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3,
                    extra: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step:09d}.tmp")
    final = os.path.join(directory, f"step_{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    items = _tree_items(tree)

    def savable(v):
        a = np.asarray(jax.device_get(v))
        if a.dtype.kind == "V":      # ml_dtypes (bf16/fp8): npz-unsafe
            a = a.astype(np.float32)  # lossless upcast; template restores
        return a

    arrays = {f"a{i:05d}": savable(v) for i, (_, v) in enumerate(items)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": [k for k, _ in items],
        "signature": _signature(tree),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)            # atomic commit
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(_list_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)


def _list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return out


def latest_step(directory: str) -> int | None:
    steps = _list_steps(directory)
    return max(steps) if steps else None


def load_checkpoint(directory: str, template, step: int | None = None):
    """Restore ``template``-structured tree.  Returns (tree, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    items = _tree_items(template)
    if manifest["keys"] != [k for k, _ in items]:
        raise ValueError("checkpoint tree structure does not match template")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = [data[f"a{i:05d}"] for i in range(len(items))]
    flat_t, treedef = jax.tree_util.tree_flatten(template)
    restored = [np.asarray(a, dtype=t.dtype).reshape(t.shape)
                for a, t in zip(leaves, flat_t)]
    return treedef.unflatten([jax.numpy.asarray(a) for a in restored]), step


class CheckpointManager:
    """Periodic + async checkpointing with bounded retention."""

    def __init__(self, directory: str, *, interval: int = 100, keep: int = 3):
        self.directory = directory
        self.interval = interval
        self.keep = keep
        self._thread: threading.Thread | None = None

    def maybe_save(self, step: int, tree, *, blocking: bool = False,
                   extra: dict | None = None) -> bool:
        if step % self.interval:
            return False
        self.wait()
        # snapshot on caller thread (donated buffers may be reused next step)
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        if blocking:
            save_checkpoint(self.directory, step, host_tree, keep=self.keep,
                            extra=extra)
            return True
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.directory, step, host_tree),
            kwargs=dict(keep=self.keep, extra=extra), daemon=True)
        self._thread.start()
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
