"""Loop-aware analysis of compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` on the CPU backend counts while-loop bodies
ONCE, which under-reports scanned-layer models by ~n_layers x.  This module
re-derives the roofline inputs from the compiled artifact itself:

  1. parse the module into computations/instructions,
  2. walk the call graph propagating loop multipliers taken from each while
     op's ``known_trip_count`` backend_config (XLA annotates these for
     counted loops; a missing annotation falls back to 1 and is reported),
  3. FLOPs   = sum over dot/convolution ops of 2*prod(out)*prod(contract)
               x the enclosing multiplier,
  4. HBM     = sum over non-fused instruction operand+output bytes x
               multiplier (fusion internals touch VMEM/registers only;
               gather/dynamic-slice operands counted at output size),
  5. collective bytes = same walk filtered to all-gather / all-reduce /
               reduce-scatter / all-to-all / collective-permute.

Shapes in the partitioned module are per-device, so all results are
per-device quantities (see repro.roofline).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# computation headers start at column 0: "%name (args) -> ... {" / "ENTRY ..."
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.+?)\s+([a-z0-9\-]+)\(")
_TRIP_RE = re.compile(r'known_trip_count[\\":{]+n[\\":]+(\d+)')
_CALLREF_SINGLE = re.compile(r"(?:body|to_apply|calls|condition)=%?([\w.\-]+)")
_CALLREF_LIST = re.compile(r"(?:branch_computations|called_computations)=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_list_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _out_elems_dims(shape_text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_shape_text: str
    line: str

    @property
    def out_bytes(self) -> int:
        return _shape_list_bytes(self.out_shape_text)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_fusion: bool = False


class Module:
    def __init__(self, text: str):
        self.computations: dict[str, Computation] = {}
        self.def_shape: dict[str, str] = {}        # instr name -> shape text
        self.entry: str | None = None
        cur: Computation | None = None
        for raw in text.splitlines():
            if raw and not raw[0].isspace() and "(" in raw:
                hdr = _COMP_HDR.match(raw)
                if hdr:
                    cur = Computation(hdr.group(2), [])
                    cur.is_fusion = "fused_computation" in cur.name
                    self.computations[cur.name] = cur
                    if hdr.group(1):
                        self.entry = cur.name
                    continue
            m = _INSTR_RE.match(raw)
            if m and cur is not None:
                inst = Instr(m.group(1), m.group(3), m.group(2), raw)
                cur.instrs.append(inst)
                self.def_shape[inst.name] = inst.out_shape_text
            # parameters also define shapes:  %p = f32[..] parameter(0)
        # multipliers
        self.mult = self._multipliers()

    # -- call-graph walk with trip counts -------------------------------------
    def _multipliers(self) -> dict[str, float]:
        mult = {name: 0.0 for name in self.computations}
        if self.entry is None:
            # fall back: treat first computation as entry
            self.entry = next(iter(self.computations), None)
        if self.entry is None:
            return mult
        mult[self.entry] = 1.0
        # iterate to fixpoint (call graph is a DAG; few passes suffice)
        for _ in range(len(self.computations)):
            changed = False
            for comp in self.computations.values():
                base = mult.get(comp.name, 0.0)
                if base == 0.0:
                    continue
                for inst in comp.instrs:
                    refs = _CALLREF_SINGLE.findall(inst.line)
                    for group in _CALLREF_LIST.findall(inst.line):
                        refs.extend(t.strip().lstrip("%")
                                    for t in group.split(",") if t.strip())
                    if not refs:
                        continue
                    trips = 1.0
                    if inst.opcode == "while":
                        t = _TRIP_RE.search(inst.line)
                        trips = float(t.group(1)) if t else 1.0
                    for target in refs:
                        if target not in mult:
                            continue
                        val = base * trips
                        if val > mult[target]:
                            mult[target] = val
                            changed = True
            if not changed:
                break
        return mult

    # -- analyses -----------------------------------------------------------
    def flops(self) -> float:
        total = 0.0
        for comp in self.computations.values():
            m = self.mult.get(comp.name, 0.0)
            if m == 0.0:
                continue
            for inst in comp.instrs:
                if inst.opcode not in ("dot", "convolution"):
                    continue
                shapes = _out_elems_dims(inst.out_shape_text)
                out_elems = 0
                for _, dims in shapes:
                    n = 1
                    for d in dims:
                        n *= d
                    out_elems += n
                k = self._contraction_size(inst)
                total += 2.0 * out_elems * k * m
        return total

    def _operand_section(self, inst: Instr) -> str:
        start = inst.line.find(inst.opcode + "(") + len(inst.opcode) + 1
        end = inst.line.find(")", start)
        return inst.line[start:end if end > 0 else None]

    def _contraction_size(self, inst: Instr) -> float:
        mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.line)
        if not mm:
            return 1.0
        dims = [int(d) for d in mm.group(1).split(",") if d]
        operand_text = self._operand_section(inst)
        shapes = _out_elems_dims(operand_text)
        if not shapes:   # operands printed without types: symbol table
            names = _OPERAND_RE.findall(operand_text)
            if not names:
                return 1.0
            shapes = _out_elems_dims(self.def_shape.get(names[0], ""))
            if not shapes:
                return 1.0
        lhs_dims = shapes[0][1]
        k = 1.0
        for d in dims:
            if d < len(lhs_dims):
                k *= lhs_dims[d]
        return k

    _SKIP_MEM = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "copy-start", "copy-done", "after-all",
                 "partition-id", "replica-id", "iota", "while", "call",
                 "conditional", "custom-call"}

    def _operand_bytes(self, inst: Instr) -> int:
        operand_text = self._operand_section(inst)
        # shapes if printed inline, else resolve %names via the symbol table
        inline = _shape_list_bytes(operand_text)
        if inline:
            return inline
        total = 0
        for name in _OPERAND_RE.findall(operand_text):
            total += _shape_list_bytes(self.def_shape.get(name, ""))
        return total

    def hbm_bytes(self) -> float:
        """Materialisation traffic: every top-level (unfused) result is one
        HBM write + one later read (2x output bytes).  Operand sizes are NOT
        summed -- a fusion that reads a dynamic slice of a stacked scan
        parameter would otherwise be charged the whole stack per iteration.
        dynamic-update-slice is charged at update size (in-place semantics);
        gather/dynamic-slice at output size."""
        total = 0.0
        for comp in self.computations.values():
            if comp.is_fusion:
                continue
            m = self.mult.get(comp.name, 0.0)
            if m == 0.0:
                continue
            for inst in comp.instrs:
                if inst.opcode in self._SKIP_MEM or "-done" in inst.opcode:
                    continue
                out_b = self._effective_out_bytes(inst)
                total += 2.0 * out_b * m
        return total

    def _dus_update_bytes(self, inst: Instr) -> int:
        ops = _OPERAND_RE.findall(self._operand_section(inst))
        if len(ops) > 1:
            return _shape_list_bytes(self.def_shape.get(ops[1], ""))
        return 0

    _UNARY_PASSTHROUGH = ("convert", "bitcast", "copy", "reshape",
                          "transpose")

    def _chase(self, by_name: dict, name: str, depth: int = 6):
        """Follow unary value chains (convert/bitcast/...) to the source."""
        it = by_name.get(name)
        while it is not None and depth > 0 and \
                it.opcode in self._UNARY_PASSTHROUGH:
            ops = _OPERAND_RE.findall(self._operand_section(it))
            it = by_name.get(ops[0]) if ops else None
            depth -= 1
        return it

    def _effective_out_bytes(self, inst: Instr) -> int:
        """Output bytes, with in-place dynamic-update-slice charged at
        update size -- including fusions whose root (possibly behind
        convert/bitcast chains) is a DUS: scan residual buffers are written
        one slice per iteration, not whole."""
        if inst.opcode == "dynamic-update-slice":
            return self._dus_update_bytes(inst) or inst.out_bytes
        if inst.opcode != "fusion":
            return inst.out_bytes
        mm = re.search(r"calls=%?([\w.\-]+)", inst.line)
        comp = self.computations.get(mm.group(1)) if mm else None
        if not comp or not comp.instrs:
            return inst.out_bytes
        by_name = {i.name: i for i in comp.instrs}
        root = comp.instrs[-1]
        if root.opcode == "tuple":
            total = 0
            for nm in _OPERAND_RE.findall(self._operand_section(root)):
                src = self._chase(by_name, nm)
                if src is not None and src.opcode == "dynamic-update-slice":
                    total += self._dus_update_bytes(src) or src.out_bytes
                else:
                    total += _shape_list_bytes(self.def_shape.get(nm, ""))
            return total or inst.out_bytes
        src = self._chase(by_name, root.name)
        if src is not None and src.opcode == "dynamic-update-slice":
            return self._dus_update_bytes(src) or inst.out_bytes
        return inst.out_bytes

    def collective_bytes(self) -> tuple[dict, dict]:
        by_bytes = {k: 0.0 for k in COLLECTIVES}
        by_count = {k: 0.0 for k in COLLECTIVES}
        for comp in self.computations.values():
            m = self.mult.get(comp.name, 0.0)
            if m == 0.0:
                continue
            for inst in comp.instrs:
                op = inst.opcode
                if op.endswith("-start"):
                    op = op[:-6]
                elif op.endswith("-done"):
                    continue
                if op not in COLLECTIVES:
                    continue
                b = self._operand_bytes(inst) or inst.out_bytes
                by_bytes[op] += b * m
                by_count[op] += m
        return by_bytes, by_count


def analyze(text: str) -> dict:
    mod = Module(text)
    coll_bytes, coll_counts = mod.collective_bytes()
    return {
        "flops": mod.flops(),
        "hbm_bytes": mod.hbm_bytes(),
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "n_computations": len(mod.computations),
    }
