"""Camera models for the projective viewing pipeline (row-vector form).

The graphics companion paper (*2D and 3D Computer Graphics Algorithms
under MorphoSys*) maps full viewing chains -- world transform, camera,
projection -- onto the same RC array as the source paper's affine
primitives.  This module provides those stages as plain numpy matrices in
the repo's row-vector homogeneous convention (q_h = [p, 1] @ H), ready to
drop into a ``TransformChain`` via ``matrix`` (affine camera) and
``projective`` (projection): the chain compiler folds the whole pipeline
into one (H, lo, hi) plan executed as a single fused kernel launch.

Conventions (right-handed, OpenGL-style clip space):

  * the camera looks down its local -z axis; ``up`` seeds local +y;
  * a perspective projection maps the frustum between ``near`` and
    ``far`` (both positive distances in front of the eye) to NDC
    [-1, 1]^3 with w = +(distance in front of the eye), so the in-kernel
    w > 0 test culls everything behind the eye;
  * orthographic projections are affine (w stays 1) but still route
    through the projective plan so the frustum cull mask applies.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _unit(v: np.ndarray, name: str) -> np.ndarray:
    n = float(np.linalg.norm(v))
    if n < 1e-12:
        raise ValueError(f"{name} is degenerate (zero length)")
    return v / n


def look_at(eye, target, up=(0.0, 1.0, 0.0)) -> np.ndarray:
    """World -> camera affine as a (4, 4) row-vector homogeneous matrix.

    The camera sits at ``eye`` looking toward ``target``; ``up`` seeds the
    local +y axis.  ``[p, 1] @ H`` yields camera-space coordinates with
    the view direction along -z."""
    eye = np.asarray(eye, np.float32)
    z = _unit(eye - np.asarray(target, np.float32), "eye - target")
    x = _unit(np.cross(np.asarray(up, np.float32), z), "up x view")
    y = np.cross(z, x)
    a = np.stack([x, y, z], axis=1).astype(np.float32)   # columns = axes
    h = np.eye(4, dtype=np.float32)
    h[:3, :3] = a
    h[3, :3] = -eye @ a
    return h


def perspective(fov_y: float, aspect: float, near: float,
                far: float) -> np.ndarray:
    """Perspective projection as a (4, 4) row-vector projective matrix.

    ``fov_y`` is the full vertical field of view in radians; ``near`` /
    ``far`` are positive distances in front of the eye.  Camera-space
    z = -near / -far map to NDC z = -1 / +1, and w = -z_cam > 0 exactly
    for points in front of the eye."""
    if not 0.0 < fov_y < np.pi:
        raise ValueError(f"fov_y must be in (0, pi), got {fov_y}")
    if not 0.0 < near < far:
        raise ValueError(f"need 0 < near < far, got {near}, {far}")
    f = 1.0 / np.tan(fov_y / 2.0)
    h = np.zeros((4, 4), np.float32)
    h[0, 0] = f / aspect
    h[1, 1] = f
    h[2, 2] = (near + far) / (near - far)
    h[2, 3] = -1.0
    h[3, 2] = 2.0 * near * far / (near - far)
    return h


def orthographic(left: float, right: float, bottom: float, top: float,
                 near: float, far: float) -> np.ndarray:
    """Orthographic projection as a (4, 4) row-vector matrix (affine --
    w stays 1, so nothing is culled by the w > 0 test; the NDC frustum
    cull still applies)."""
    h = np.eye(4, dtype=np.float32)
    h[0, 0] = 2.0 / (right - left)
    h[1, 1] = 2.0 / (top - bottom)
    h[2, 2] = -2.0 / (far - near)
    h[3, 0] = -(right + left) / (right - left)
    h[3, 1] = -(top + bottom) / (top - bottom)
    h[3, 2] = -(far + near) / (far - near)
    return h


@dataclasses.dataclass(frozen=True)
class Camera:
    """A look-at camera with an optional intrinsic projection.

        cam = Camera(eye=(3, 2, 6), target=(0, 0, 0),
                     fov_y=np.pi / 3, near=0.5, far=50.0)
        cam.view_matrix()        # (4, 4) affine (world -> camera)
        cam.projection_matrix()  # (4, 4) perspective (camera -> clip)

    ``fov_y=None`` makes ``projection_matrix`` orthographic over
    [-ortho_half, ortho_half]^2 at the same near/far range."""
    eye: tuple = (0.0, 0.0, 5.0)
    target: tuple = (0.0, 0.0, 0.0)
    up: tuple = (0.0, 1.0, 0.0)
    fov_y: float | None = np.pi / 3
    aspect: float = 1.0
    near: float = 0.1
    far: float = 100.0
    ortho_half: float = 1.0

    def view_matrix(self) -> np.ndarray:
        return look_at(self.eye, self.target, self.up)

    def projection_matrix(self) -> np.ndarray:
        if self.fov_y is None:
            s = self.ortho_half
            return orthographic(-s * self.aspect, s * self.aspect,
                                -s, s, self.near, self.far)
        return perspective(self.fov_y, self.aspect, self.near, self.far)
