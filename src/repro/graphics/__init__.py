"""Projective graphics pipeline: homogeneous viewing chains fused into
single launches.

The source paper's geometrical transformations are the affine half of a
viewing pipeline; its graphics companion (*2D and 3D Computer Graphics
Algorithms under MorphoSys*, Damaj, Majzoub & Diab) maps the rest --
rotation, projection, full 2D/3D viewing chains -- onto the same RC
array.  This package is that companion mapped onto the chain compiler:

  * ``Camera`` / ``look_at`` / ``perspective`` / ``orthographic`` -- the
    view and projection stages as row-vector homogeneous matrices;
  * ``Viewport`` -- the NDC -> screen diagonal affine (the one stage that
    may follow the frustum cull);
  * ``viewing_chain`` -- assembles model -> camera -> projection -> cull
    -> viewport as ONE projective ``TransformChain``, which the compiler
    folds to a single (H, lo, hi) plan and executes as a single fused
    kernel launch (in-kernel perspective divide + cull mask; see
    ``repro.kernels.projective``).

Serve many viewing chains through ``repro.serving.GeometryServer`` --
projective structures bucket like any other chain structure, so mixed
affine + projective traffic batches into few launches.
"""
from repro.graphics.camera import (Camera, look_at, orthographic,
                                   perspective)
from repro.graphics.pipeline import viewing_chain
from repro.graphics.viewport import Viewport

__all__ = ["Camera", "Viewport", "look_at", "orthographic", "perspective",
           "viewing_chain"]
