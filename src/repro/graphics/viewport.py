"""Viewport mapping: NDC [-1, 1]^d to screen/depth coordinates.

The viewport map is the diagonal-affine tail of a viewing pipeline -- the
one stage allowed to FOLLOW the frustum cull, because axis-aligned cull
bounds fold exactly through a per-coordinate affine (the chain compiler
pushes the recorded [-1, 1] bounds forward into output space, so the
in-kernel cull tests final screen coordinates against screen-space
bounds: one comparison, no second pass).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Viewport:
    """A screen rectangle (plus depth range in 3D).

    NDC x in [-1, 1] maps to [x, x + width], y to [y, y + height], and --
    for 3D chains -- NDC z to ``depth`` (the z-buffer range)."""
    x: float = 0.0
    y: float = 0.0
    width: float = 1.0
    height: float = 1.0
    depth: tuple = (0.0, 1.0)

    def scale_offset(self, dim: int) -> tuple[tuple, tuple]:
        """The per-coordinate affine (s, t) with screen = ndc * s + t."""
        if dim not in (2, 3):
            raise ValueError(f"dim must be 2 or 3, got {dim}")
        s = [self.width / 2.0, self.height / 2.0]
        t = [self.x + self.width / 2.0, self.y + self.height / 2.0]
        if dim == 3:
            d0, d1 = self.depth
            s.append((d1 - d0) / 2.0)
            t.append((d0 + d1) / 2.0)
        return tuple(np.float32(v) for v in s), \
            tuple(np.float32(v) for v in t)
