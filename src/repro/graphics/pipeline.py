"""Viewing-chain assembly: camera + projection + cull + viewport as ONE
projective ``TransformChain``.

``viewing_chain`` is the subsystem's front door: it strings the pipeline
stages (model/world affines, look-at camera, perspective or orthographic
projection, NDC frustum cull, viewport map) onto the chain IR, and the
chain compiler folds the whole thing to a single (H, lo, hi) plan --
every point makes ONE trip through HBM, the perspective divide and the
cull mask never leave the kernel, and ``repro.serving.GeometryServer``
buckets many such chains into single launches (the structure is hashable
like any other chain structure).
"""
from __future__ import annotations

import numpy as np

from repro.core.transform_chain import TransformChain
from repro.graphics.camera import Camera
from repro.graphics.viewport import Viewport


def viewing_chain(dim: int = 3, *, model: TransformChain | None = None,
                  camera: Camera | None = None, projection=None,
                  viewport: Viewport | None = None,
                  cull: bool = True) -> TransformChain:
    """Assemble a full viewing pipeline as one projective chain.

    Stages, in order (all optional):

      * ``model``   -- an existing ``TransformChain`` of world/model
        affines (its primitives are reused verbatim);
      * ``camera``  -- a ``Camera``; appends its look-at view affine, and
        its intrinsic projection when ``projection`` is not given;
      * ``projection`` -- an explicit (d+1, d+1) projective matrix
        (overrides the camera intrinsics), or ``False`` to suppress the
        camera intrinsics entirely -- with ``cull=False`` the pipeline
        then stays AFFINE (one matrix plan, fixed-point eligible);
      * ``cull``    -- the NDC frustum cull against [-1, 1]^d (emitted as
        the chain's in-kernel mask; on by default);
      * ``viewport`` -- a ``Viewport``; appends the NDC -> screen
        diagonal affine (the cull bounds fold through it).

    The result folds to ONE (H, lo, hi) plan: a single fused kernel
    launch however many stages were stacked.

    Execution lanes: a chain with a projection or cull is *projective*
    and runs float32 only -- ``apply``/``project`` with a fixed-point
    ``dtype=`` reject it loudly (the in-kernel perspective divide has no
    single-shift Qm.n form).  An AFFINE viewing chain (model + camera +
    viewport with ``projection=None, cull=False`` -- e.g. orthographic
    staging without a frustum test) folds to a plain matrix plan and
    quantises like any other affine chain:
    ``viewing_chain(..., projection=False, cull=False)
    .apply(pts, dtype="q8.7")`` runs the M1-faithful int16 lane at half
    the HBM bytes (see docs/architecture.md section 5).
    """
    chain = model if model is not None else TransformChain.identity(dim)
    if model is not None and model.dim != dim:
        raise ValueError(f"model chain is {model.dim}D, pipeline is {dim}D")
    if camera is not None:
        if dim != 3:
            raise ValueError("Camera is 3D; build 2D pipelines from "
                             "explicit matrices")
        chain = chain.matrix(camera.view_matrix())
        if projection is None:
            projection = camera.projection_matrix()
    if projection is not None and projection is not False:
        chain = chain.projective(np.asarray(projection, np.float32))
    if cull:
        chain = chain.cull(-1.0, 1.0)
    if viewport is not None:
        s, t = viewport.scale_offset(dim)
        chain = chain.affine(s, t)
    return chain
