"""Deterministic synthetic data pipeline, stateless-seekable for exact
restart.

Batches are a pure function of (seed, step), so a job restarted from a
step-k checkpoint regenerates byte-identical batches from step k with no
pipeline state to persist -- the fault-tolerance contract of
launch/train.py.  The generator is a Zipf-ish token sampler with a
next-token structure (labels are tokens shifted by one over a Markov-noised
stream) so small models show a real, decreasing loss.

Host sharding: ``local_batch(step, host_id, n_hosts)`` returns only this
host's rows, so multi-host launches feed per-host shards that concatenate
to the same global batch (jax.make_array_from_process_local_data pattern).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend: str | None = None     # vision|audio -> extra stub inputs
    n_frontend_tokens: int = 0
    d_model: int = 0                # for stub embeddings


class SyntheticLMData:
    """Stateless step->batch derivation (numpy on host, like a real loader)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step]))

    def global_batch(self, step: int) -> dict:
        c = self.cfg
        rng = self._rng(step)
        # Zipf-distributed stream with Markov continuation: token t+1 is a
        # deterministic function of the *visible* token t half the time ->
        # genuinely learnable next-token structure.
        base = rng.zipf(1.3, size=(c.global_batch, c.seq_len + 1))
        base = (base - 1) % c.vocab_size
        coin = rng.random((c.global_batch, c.seq_len)) < 0.5
        tokens = np.empty_like(base)
        tokens[:, 0] = base[:, 0]
        for t in range(c.seq_len):
            tokens[:, t + 1] = np.where(
                coin[:, t], (tokens[:, t] * 31 + 7) % c.vocab_size,
                base[:, t + 1])
        batch = {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }
        if c.frontend == "vision":
            batch["patches"] = rng.standard_normal(
                (c.global_batch, c.n_frontend_tokens, c.d_model),
                dtype=np.float32)
        elif c.frontend == "audio":
            batch["frames"] = rng.standard_normal(
                (c.global_batch, c.seq_len, c.d_model), dtype=np.float32)
        return batch

    def local_batch(self, step: int, host_id: int, n_hosts: int) -> dict:
        g = self.global_batch(step)
        per = self.cfg.global_batch // n_hosts
        return {k: v[host_id * per:(host_id + 1) * per] for k, v in g.items()}


def make_batch_specs(cfg: DataConfig):
    """ShapeDtypeStructs for one global batch (dry-run input stand-ins)."""
    import jax
    c = cfg
    specs = {
        "tokens": jax.ShapeDtypeStruct((c.global_batch, c.seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((c.global_batch, c.seq_len), jnp.int32),
    }
    if c.frontend == "vision":
        specs["patches"] = jax.ShapeDtypeStruct(
            (c.global_batch, c.n_frontend_tokens, c.d_model), jnp.float32)
    elif c.frontend == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (c.global_batch, c.seq_len, c.d_model), jnp.float32)
    return specs
