"""Batched transform-serving engine (plan-bucketed scheduling).

Layered on the PR 1 fused chain compiler: heterogeneous transform requests
bucket by chain structure + backend (+ dtype + padded size class), every
bucket executes as ONE batched fused-kernel launch against one cached
plan, and bucket k+1's host->device staging overlaps bucket k's compute
(the paper's frame-buffer set-0/set-1 discipline).  See
``docs/architecture.md`` for the dataflow diagram and
``repro.serving.engine`` for the mechanics.

Fault tolerance (PR 6): ``submit`` rejects malformed requests with the
typed ``serving.errors`` taxonomy; ``flush`` contains per-bucket launch
failures behind a retry / backend-degradation / bisection ladder so no
request is ever silently lost; ``serving.faults`` is the seeded
fault-injection harness (``run_chaos_soak``) the chaos CI lane gates on.

Continuous batching (PR 7): ``AsyncGeometryServer`` is the async
front-end over the same engine -- ``submit_async`` returns awaitable
``Ticket`` objects, admission control (``serving.admission``: bounded
queue depth, per-tenant fair share + token buckets) sheds load at the
intake boundary with typed rejections, and a flush policy coupling the
``SLOConfig`` max-wait deadline to bucket fill decides when each plan
bucket launches.  All timing flows through the injectable
``serving.clock.Clock`` (``VirtualClock`` = deterministic tests and the
seeded soak benchmark; ``MonotonicClock`` = real traffic).
"""
from repro.serving import errors
from repro.serving.admission import (AdmissionConfig, AdmissionController,
                                     QueueFullError, RateLimitError,
                                     TokenBucket)
from repro.serving.async_engine import (AsyncGeometryServer, SLOConfig,
                                        Ticket)
from repro.serving.bucketing import padded_length, waste_fraction
from repro.serving.clock import (Clock, MonotonicClock, VirtualClock,
                                 percentile)
from repro.serving.engine import (BatchPlan, BucketReport, FaultConfig,
                                  GeometryServer, Projected,
                                  clear_plan_cache, get_batch_plan,
                                  reset_stats, stats)
from repro.serving.errors import (CorruptionError, InjectedFault, LaunchError,
                                  RequestError, is_error)
from repro.serving.faults import (ChaosReport, FaultInjector, malform,
                                  run_chaos_soak)
from repro.serving.workload import (chain_for, mixed_lane_workload,
                                    random_workload)

__all__ = [
    "AdmissionConfig", "AdmissionController", "AsyncGeometryServer",
    "BatchPlan", "BucketReport", "ChaosReport", "Clock", "CorruptionError",
    "FaultConfig", "FaultInjector", "GeometryServer", "InjectedFault",
    "LaunchError", "MonotonicClock", "Projected", "QueueFullError",
    "RateLimitError", "RequestError", "SLOConfig", "Ticket", "TokenBucket",
    "VirtualClock", "chain_for", "clear_plan_cache", "errors",
    "get_batch_plan", "is_error", "malform", "mixed_lane_workload",
    "padded_length", "percentile", "random_workload", "reset_stats",
    "run_chaos_soak", "stats", "waste_fraction",
]
