"""Batched transform-serving engine (plan-bucketed scheduling).

Layered on the PR 1 fused chain compiler: heterogeneous transform requests
bucket by chain structure + backend (+ dtype + padded size class), every
bucket executes as ONE batched fused-kernel launch against one cached
plan, and bucket k+1's host->device staging overlaps bucket k's compute
(the paper's frame-buffer set-0/set-1 discipline).  See
``docs/architecture.md`` for the dataflow diagram and
``repro.serving.engine`` for the mechanics.
"""
from repro.serving.bucketing import padded_length, waste_fraction
from repro.serving.engine import (BatchPlan, BucketReport, GeometryServer,
                                  Projected, clear_plan_cache,
                                  get_batch_plan, reset_stats, stats)
from repro.serving.workload import chain_for, random_workload

__all__ = [
    "BatchPlan", "BucketReport", "GeometryServer", "Projected", "chain_for",
    "clear_plan_cache", "get_batch_plan", "padded_length", "random_workload",
    "reset_stats", "stats", "waste_fraction",
]
