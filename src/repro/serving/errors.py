"""The serving fault taxonomy: typed request errors + serving-only faults.

The validation layer of the taxonomy (``RequestError`` and its intake
subclasses) lives in ``repro.errors`` so the chain compiler can raise
the same types without a core -> serving dependency; this module is the
serving-side spelling of the whole family plus the members only the
engine produces:

  * ``LaunchError``       -- terminal per-request resolution after the
    recovery ladder (retry -> backend degradation -> bisection) is
    exhausted; occupies the request's result slot in ``flush``.
  * ``InjectedFault``     -- raised by the seeded fault-injection
    harness (``serving.faults``) to stand in for a real launch failure;
    deliberately NOT a ``RequestError``: it models the infrastructure
    failing, not the request being malformed.
  * ``CorruptionError``   -- the engine detected non-finite values in a
    launch's output whose inputs validated finite (staging/DMA
    corruption in the fault model); treated as a failed launch and
    retried from the pristine host copy.

``is_error`` is the one-line test drivers use on ``flush`` results.
"""
from __future__ import annotations

import typing

from repro.errors import (DtypeError, EmptyPointsError, LaunchError,
                          NonFiniteError, QRangeError, RequestError,
                          ShapeError)


class InjectedFault(RuntimeError):
    """A deterministic, injector-scheduled launch failure (see
    ``serving.faults.FaultInjector``).  The engine's recovery path makes
    no distinction between this and a real kernel-launch exception --
    that indistinguishability is what makes the harness a test of the
    real recovery machinery."""


class CorruptionError(RuntimeError):
    """Non-finite values detected in a launch's output although every
    input validated finite at submit: the staged operand buffer (or the
    launch itself) corrupted in flight.  The launch result is discarded
    wholesale and the bucket retried from the pristine host copy."""


def is_error(result: typing.Any) -> bool:
    """True when a ``flush`` result slot resolved to a typed error
    instead of a transformed point set."""
    return isinstance(result, RequestError)


__all__ = [
    "RequestError", "ShapeError", "DtypeError", "EmptyPointsError",
    "NonFiniteError", "QRangeError", "LaunchError", "InjectedFault",
    "CorruptionError", "is_error",
]
