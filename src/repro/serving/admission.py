"""Admission control and backpressure for the continuous-batching front-end.

A server that accepts every request under overload does not serve more
traffic -- it serves the same traffic later, with every request's
latency inflated by the queue it had to wait behind.  The admission
layer moves that failure to the intake boundary, exactly like the
malformed-request taxonomy did for bad payloads: an inadmissible request
is refused *at submit* with a typed, machine-readable rejection code,
never silently queued into an SLO violation.

Three gates, all clock-driven through the injectable ``serving.clock``
interface (so every decision is deterministic under a ``VirtualClock``):

  * **bounded queue depth** -- at most ``max_queue_depth`` admitted
    requests may be waiting; past that, ``QueueFullError``
    (code ``"queue-full"``).  Backpressure, not buffering: the caller
    learns *now* that it must slow down.
  * **per-tenant fair share** -- no single tenant may hold more than
    ``ceil(max_queue_depth * tenant_share)`` of the queue.  A flooding
    tenant hits ITS cap while the queue still has room, so a light
    tenant is never starved by a heavy one (the starvation test in
    ``tests/test_clock.py`` pins this).
  * **per-tenant token bucket** -- sustained rate ``tenant_rate``
    requests/s with burst capacity ``tenant_burst``; an empty bucket
    rejects with ``RateLimitError`` (code ``"rate-limit"``).  Buckets
    refill continuously in clock time, so a rejected tenant's next
    admissible instant is computable (and, under a virtual clock,
    exact).

Both rejection classes subclass ``repro.errors.RequestError``: callers
already catching the typed taxonomy at submit handle backpressure with
zero new code paths, and the stable ``code`` strings are what telemetry
and tests group by.
"""
from __future__ import annotations

import dataclasses
import math

from repro import errors
from repro.obs import metrics as obsm
from repro.obs import trace as obst
from repro.serving.clock import Clock


class QueueFullError(errors.RequestError):
    """The bounded admission queue (global depth, or this tenant's fair
    share of it) has no room: backpressure -- retry after a flush, or
    slow down.  Rejected at submit so the request never waits out an
    SLO it has already lost."""
    code = "queue-full"


class RateLimitError(errors.RequestError):
    """This tenant's token bucket is empty: its sustained submission
    rate exceeds the configured requests/s.  The message names the
    earliest admissible instant."""
    code = "rate-limit"


@dataclasses.dataclass
class TokenBucket:
    """A continuously-refilling token bucket on an injected timeline.

    Holds at most ``burst`` tokens, refills at ``rate`` tokens/s of
    *clock* time (virtual or monotonic -- the bucket never reads a wall
    clock itself), and ``take`` spends one token per admitted request.
    Pure arithmetic on ``now`` values: two buckets fed the same take
    timestamps make identical decisions, which is what lets the soak
    benchmark gate rejection counts exactly."""
    rate: float                    # tokens per second of clock time
    burst: float                   # bucket capacity (initial fill)
    tokens: float = None           # type: ignore[assignment]
    stamp: float = 0.0             # clock time of the last refill

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(f"token rate must be > 0, got {self.rate}")
        if self.burst < 1:
            raise ValueError(f"burst must be >= 1, got {self.burst}")
        if self.tokens is None:
            self.tokens = float(self.burst)

    def _refill(self, now: float) -> None:
        if now > self.stamp:
            self.tokens = min(float(self.burst),
                              self.tokens + (now - self.stamp) * self.rate)
        self.stamp = max(self.stamp, now)

    def take(self, now: float) -> bool:
        """Spend one token if available; False = rate-limited."""
        self._refill(now)
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False

    def next_admissible_in(self, now: float) -> float:
        """Seconds until a token will be available (0 if one is now)."""
        self._refill(now)
        if self.tokens >= 1.0:
            return 0.0
        return (1.0 - self.tokens) / self.rate


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Backpressure policy knobs for one ``AsyncGeometryServer``.

    ``tenant_rate=None`` disables rate limiting (the queue-depth gates
    still apply); ``tenant_share=1.0`` disables the fair-share cap
    (a single tenant may then fill the whole queue)."""
    max_queue_depth: int = 1024
    tenant_share: float = 0.5      # max fraction of the queue per tenant
    tenant_rate: float | None = None   # sustained requests/s per tenant
    tenant_burst: float = 32.0

    def __post_init__(self):
        if self.max_queue_depth < 1:
            raise ValueError("max_queue_depth must be >= 1, got "
                             f"{self.max_queue_depth}")
        if not 0.0 < self.tenant_share <= 1.0:
            raise ValueError("tenant_share must be in (0, 1], got "
                             f"{self.tenant_share}")

    @property
    def tenant_cap(self) -> int:
        """Queued requests one tenant may hold: its fair share of the
        bounded queue, never below 1 (a tenant must always be able to
        make progress when the queue itself has room)."""
        return max(1, math.ceil(self.max_queue_depth * self.tenant_share))


class AdmissionController:
    """Tracks queue occupancy per tenant and arbitrates admission.

    The engine calls ``admit`` at submit (raises the typed rejection) and
    ``release`` when a request leaves the queue for a launch.  Counters
    (``admitted`` / ``queue_full_rejections`` / ``rate_limit_rejections``)
    are registry-backed per controller (read them as plain ints exactly
    as before -- they are properties over ``repro.obs.metrics`` counters,
    with per-tenant rejection labels on the side); the engine mirrors
    them into ``serving.stats`` by delta.
    """

    def __init__(self, config: AdmissionConfig, clock: Clock,
                 metrics: obsm.MetricsRegistry | None = None):
        self.config = config
        self.clock = clock
        self.depth = 0                               # total queued
        self.tenant_depth: dict[str, int] = {}       # queued per tenant
        self._buckets: dict[str, TokenBucket] = {}
        self.metrics = metrics if metrics is not None \
            else obsm.MetricsRegistry("admission")
        self._c_admitted = self.metrics.counter("admitted")
        self._c_queue_full = self.metrics.counter("queue_full_rejections")
        self._c_rate_limit = self.metrics.counter("rate_limit_rejections")
        self._rejections = self.metrics.counter(
            "rejections", labels=("tenant", "code"))

    # back-compat integer views over the registry counters ------------------

    @property
    def admitted(self) -> int:
        return self._c_admitted.value

    @property
    def queue_full_rejections(self) -> int:
        return self._c_queue_full.value

    @property
    def rate_limit_rejections(self) -> int:
        return self._c_rate_limit.value

    def _reject(self, counter: obsm.Counter, tenant: str,
                code: str, gate: str) -> None:
        counter.inc()
        self._rejections.labels(tenant=tenant, code=code).inc()
        trc = obst.active()
        if trc.enabled:
            trc.instant("admission.reject", tenant=tenant, code=code,
                        gate=gate)

    def _bucket(self, tenant: str) -> TokenBucket | None:
        if self.config.tenant_rate is None:
            return None
        b = self._buckets.get(tenant)
        if b is None:
            b = TokenBucket(rate=self.config.tenant_rate,
                            burst=self.config.tenant_burst,
                            stamp=self.clock.now())
            self._buckets[tenant] = b
        return b

    def admit(self, tenant: str) -> None:
        """Admit one request for ``tenant`` or raise the typed rejection.

        Gate order: queue depth (cheapest, protects the server), then
        the tenant's fair share, then the tenant's token bucket -- a
        request rejected for depth does NOT spend a rate token, so
        backpressure never doubles as a rate penalty."""
        cfg = self.config
        if self.depth >= cfg.max_queue_depth:
            self._reject(self._c_queue_full, tenant, QueueFullError.code,
                         "depth")
            raise QueueFullError(
                f"queue full ({self.depth}/{cfg.max_queue_depth} waiting); "
                f"retry after the next flush")
        held = self.tenant_depth.get(tenant, 0)
        if held >= cfg.tenant_cap:
            self._reject(self._c_queue_full, tenant, QueueFullError.code,
                         "fair-share")
            raise QueueFullError(
                f"tenant {tenant!r} holds its fair share of the queue "
                f"({held}/{cfg.tenant_cap} of {cfg.max_queue_depth})")
        bucket = self._bucket(tenant)
        if bucket is not None and not bucket.take(self.clock.now()):
            self._reject(self._c_rate_limit, tenant, RateLimitError.code,
                         "token-bucket")
            wait = bucket.next_admissible_in(self.clock.now())
            raise RateLimitError(
                f"tenant {tenant!r} over {cfg.tenant_rate:g} req/s "
                f"(burst {cfg.tenant_burst:g}); admissible in {wait:.6f} s")
        self._c_admitted.inc()
        self.depth += 1
        self.tenant_depth[tenant] = held + 1

    def unadmit(self, tenant: str) -> None:
        """Roll back an ``admit`` whose request never reached the queue
        (validation refused it): the slot and the admitted count go
        back, but not any spent rate token -- the tenant did submit."""
        self.release(tenant)
        self._c_admitted.inc(-1)

    def release(self, tenant: str) -> None:
        """One queued request of ``tenant`` left the queue for a launch."""
        self.depth -= 1
        self.tenant_depth[tenant] -= 1
        assert self.depth >= 0 and self.tenant_depth[tenant] >= 0, \
            "admission release without a matching admit"
