"""GeometryServer: plan-bucketed batched serving of transform chains.

The ROADMAP north-star is heavy traffic: millions of small "apply this
composite transform to these points" requests.  Dispatching each one
through ``TransformChain.apply`` pays one kernel launch per request and
leaves the plan cache as the only amortisation.  This engine is the
missing server loop, built from the paper's M1 execution discipline:

  1. **Bucket** -- pending requests group by
     ``(TransformChain.structure, backend, dtype, padded_length)``.
     Structure + backend pick the compiled plan (every request in a bucket
     hits ONE cached batch plan -- the context-memory discipline: load a
     context once, stream many operands through it); the size-bucketing
     policy (``bucketing.padded_length``: power-of-two grid refined under
     a waste cap) picks the padded length so padding waste per request
     stays below the cap.
  2. **Pack** -- each bucket's variable-length point sets pad/stack into
     one lane-dense (B, L, d) batch, and each request folds host-side
     through the SAME numpy fold ``apply`` uses
     (``TransformChain.fold``); the folded (A, t) pairs stack into the
     batch the kernels consume.
  3. **Launch** -- the whole bucket executes as a single fused kernel
     launch (``kernels.chain_diag_batch`` / ``chain_apply_batch`` /
     ``chain_project_batch`` -- the last for projective viewing-chain
     buckets, whose per-request results carry the in-kernel frustum-cull
     mask as ``Projected.mask``), the batched ``apply_many`` form of
     PR 1's one-HBM-pass chain kernels.
     Buckets whose packed batch exceeds the launch cap split into shards
     along the batch axis (and the packed buffer is placed through the
     ``distributed.sharding`` helpers when a device mesh is ambient).
  4. **Overlap** -- bucket k+1's host->device staging is dispatched while
     bucket k computes, the frame-buffer set-0/set-1 overlap of the paper:
     set 0 is the bucket the RC array (device) is computing on, set 1 is
     the bucket the DMA (host staging) is filling.

Equality contract vs. per-request ``apply`` (asserted by
``tests/test_serving.py``): the fold is bit-identical by construction (one
shared host code path); the fused application runs the same per-request
arithmetic, but XLA:CPU reserves per-program freedom in contracting float
multiply-adds, so across *different batch shapes* the last ULP may differ
-- packed results are exact on diagonal plans in practice and within 1 ULP
on matrix plans, deterministic for a fixed bucket shape, and padded rows
never contaminate payload rows (points are row-independent).

Fixed-point serving: ``submit(..., qformat="q8.7")`` routes a request
through the int16 Qm.n lane -- it buckets under the FORMAT (the dtype
slot of the bucket key), packs as int16 words through the same
``quantize.quantize_fold`` the chain compiler's q lane uses, and
launches the ``chain_*_batch_q`` kernels.  Integer arithmetic is exact
and order-independent, so the q lane's packed-vs-apply equality is
BITWISE on every plan kind (``tests/test_fixedpoint.py``) -- and each
packed launch moves 2-byte words, half the float32 HBM volume.
"""
from __future__ import annotations

import dataclasses
import math
import typing

import jax
import numpy as np

from repro import quantize
from repro.autotune import cache as tuning
from repro.core import transform_chain as tc
from repro.distributed import sharding
from repro.kernels import (chain_apply_batch, chain_apply_batch_q,
                           chain_diag_batch, chain_diag_batch_q,
                           chain_project_batch, dispatch, opcount)
from repro.serving import bucketing

#: serving statistics (observable by tests, benchmarks and the driver):
#:   plan_compiles -- batched plans built (one per distinct structure+backend)
#:   plan_hits     -- plans served from the cache
#:   traces        -- jit traces of plan bodies (new (B, L) shapes retrace;
#:                    a seen shape must not)
#:   launches      -- batched kernel launches issued (shards included)
#:   requests      -- requests served through flush()
#:   buckets       -- plan buckets executed
#:   shards        -- extra launches from splitting oversized buckets
#:   payload_points / padded_points -- real vs padded points moved
stats = {"plan_compiles": 0, "plan_hits": 0, "traces": 0, "launches": 0,
         "requests": 0, "buckets": 0, "shards": 0,
         "payload_points": 0, "padded_points": 0}

_BATCH_PLANS: dict[tuple, "BatchPlan"] = {}


def reset_stats() -> None:
    for k in stats:
        stats[k] = 0


def clear_plan_cache() -> None:
    """Drop all compiled batch plans (benchmarks use this for cold timings)."""
    _BATCH_PLANS.clear()


class Projected(np.ndarray):
    """A projective request's serving result: the projected points as a
    plain ndarray (shape-compatible with ``TransformChain.apply``
    everywhere), with the per-point frustum-cull mask attached as
    ``.mask`` (bool, the request's leading shape; True = inside).  The
    mask rides along so existing consumers that treat results as arrays
    keep working unchanged.  ``.mask`` describes EXACTLY the array
    ``flush`` returned: derived arrays (slices, transposes, sorts, any
    indexing -- same-shaped or not) read ``.mask`` as ``None`` rather
    than inheriting a mask whose rows may no longer line up with
    theirs.  Slice the mask alongside the points instead:
    ``pts[sel], res.mask[sel]``."""

    def __array_finalize__(self, obj):
        # derived arrays NEVER inherit: a shape check cannot detect
        # same-shape reorderings (r[::-1], fancy indexing), so the only
        # honest mask is the one _projected() attaches explicitly
        self._mask = None

    @property
    def mask(self) -> np.ndarray | None:
        return self._mask

    @mask.setter
    def mask(self, value: np.ndarray | None) -> None:
        self._mask = value


def _projected(points: np.ndarray, mask: np.ndarray) -> Projected:
    out = np.ascontiguousarray(points).view(Projected)
    out.mask = mask
    return out


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """A compiled bucket executor: ``fn(folded_batch, pts3) -> out``
    (jitted), where ``folded_batch`` stacks the bucket's host-folded
    per-request parameters -- (s (B,d), t (B,d)), (A (B,d,d), t (B,d)),
    or (H (B,d+1,d+1), lo (B,d), hi (B,d)).  Projective plans return
    ``(projected (B,L,d), inside (B,L))``.  Fixed-point plans
    (``qformat`` set) take int16 Qm.n words -- each request's fold
    quantised by ``quantize.quantize_fold`` at pack time -- and return
    int16."""
    kind: str                      # "diag" | "matrix" | "projective"
    dim: int
    backend: str
    fn: typing.Callable
    qformat: str | None = None     # Qm.n name for fixed-point plans


def _compile_batch_q(structure: tuple, backend: str,
                     qname: str) -> BatchPlan:
    """Compile a fixed-point bucket executor: the same trace-time tuning
    consult as the float bodies, lowering to the int16 batch kernels with
    the format's fraction count as the requantising shift.  Projective
    structures never get here (``submit`` rejects chain + qformat)."""
    dim, _ = structure
    kind = tc.plan_kind_of(structure)
    fmt = quantize.as_qformat(qname)

    if kind == "diag":
        def body(folded, pts3):
            stats["traces"] += 1
            s, t = folded
            cfg = tuning.config_for("chain_diag_batch_q", backend, fmt.name,
                                    pts3.shape[0] * pts3.shape[1])
            return chain_diag_batch_q(pts3, s, t, n_frac=fmt.n,
                                      backend=backend, config=cfg)
    else:
        def body(folded, pts3):
            stats["traces"] += 1
            a, t = folded
            cfg = tuning.config_for("chain_apply_batch_q", backend, fmt.name,
                                    pts3.shape[0] * pts3.shape[1])
            return chain_apply_batch_q(pts3, a, t, n_frac=fmt.n,
                                       backend=backend, config=cfg)

    return BatchPlan(kind=kind, dim=dim, backend=backend, fn=jax.jit(body),
                     qformat=fmt.name)


def _compile_batch(structure: tuple, backend: str) -> BatchPlan:
    dim, _ = structure
    kind = tc.plan_kind_of(structure)

    # Tuning-cache consult at trace time, mirroring the chain compiler:
    # the packed (B, L) shape is concrete under the jit trace, so the
    # lookup keys on the bucket's real size class; staging-only knobs keep
    # every config bit-identical (see core.transform_chain._compile).
    if kind == "diag":
        def body(folded, pts3):
            stats["traces"] += 1
            s, t = folded
            cfg = tuning.config_for("chain_diag_batch", backend,
                                    str(pts3.dtype),
                                    pts3.shape[0] * pts3.shape[1])
            return chain_diag_batch(pts3, s, t, backend=backend, config=cfg)
    elif kind == "matrix":
        def body(folded, pts3):
            stats["traces"] += 1
            a, t = folded
            cfg = tuning.config_for("chain_apply_batch", backend,
                                    str(pts3.dtype),
                                    pts3.shape[0] * pts3.shape[1])
            return chain_apply_batch(pts3, a, t, backend=backend, config=cfg)
    else:
        def body(folded, pts3):
            stats["traces"] += 1
            h, lo, hi = folded
            cfg = tuning.config_for("chain_project_batch", backend,
                                    str(pts3.dtype),
                                    pts3.shape[0] * pts3.shape[1])
            return chain_project_batch(pts3, h, lo, hi, backend=backend,
                                       config=cfg)

    return BatchPlan(kind=kind, dim=dim, backend=backend, fn=jax.jit(body))


def get_batch_plan(structure: tuple, backend: str,
                   qname: str | None = None) -> BatchPlan:
    """Mirrors ``transform_chain._get_plan`` deliberately: the two caches
    stay separate because they count into different stats domains (chain
    compiler vs serving engine) and compile different bodies (single
    folded pair vs stacked batch); keep their discipline in sync.
    ``qname`` selects the fixed-point lane (a distinct cached plan, as a
    distinct dtype would be)."""
    key = (structure, backend, qname)
    plan = _BATCH_PLANS.get(key)
    if plan is None:
        stats["plan_compiles"] += 1
        plan = _compile_batch_q(structure, backend, qname) \
            if qname is not None else _compile_batch(structure, backend)
        _BATCH_PLANS[key] = plan
    else:
        stats["plan_hits"] += 1
    return plan


# -- the server --------------------------------------------------------------

@dataclasses.dataclass
class _Pending:
    ticket: int
    chain: tc.TransformChain
    points: np.ndarray             # original-shape host copy
    n: int                         # flattened point count
    qformat: quantize.QFormat | None = None   # fixed-point lane request
    dequantize: bool = False       # float submitted -> float32 back


@dataclasses.dataclass
class BucketReport:
    """Per-bucket accounting for one flush (the driver prints these)."""
    structure: str                 # e.g. "2D:TSRT"
    kind: str                      # plan kind: diag | matrix | projective
    lpad: int                      # padded points per request
    requests: int
    launches: int                  # 1 unless the bucket sharded
    payload_points: int
    padded_points: int

    @property
    def waste(self) -> float:
        return 1.0 - self.payload_points / max(1, self.padded_points)

    @property
    def launches_saved(self) -> int:
        return self.requests - self.launches


def _structure_tag(structure: tuple) -> str:
    dim, kinds = structure
    return f"{dim}D:" + "".join(k for k, _ in kinds)


class GeometryServer:
    """Batched transform-serving engine over the PR 1 chain compiler.

        server = GeometryServer(backend="ref")
        tickets = [server.submit(chain_i, points_i) for ...]
        results = server.flush()        # one launch per plan bucket

    ``submit`` only records the request (host side, allocation-light);
    ``flush`` buckets, packs, and double-buffers the launches.  Results
    come back in submission order as host numpy arrays (serving results
    leave the device; per-request jax slicing would re-pay the dispatch
    overhead the batching removed), each with its request's original
    leading shape, matching ``chain_i.apply(points_i)`` under the module
    equality contract.
    """

    def __init__(self, *, backend: str | None = None,
                 min_len: int | None = None,
                 waste_cap: float | None = None,
                 max_points_per_launch: int | None = None):
        self.backend = backend
        # size-grid knobs: explicit args win; unset knobs come from the
        # tuning cache when autotuning is enabled, else the historical
        # defaults (bucketing.MIN_LEN / WASTE_CAP) -- see bucketing.grid_for.
        # The explicit args are kept and re-resolved at every flush, so
        # toggling repro.autotune.set_enabled mid-life moves a server's
        # grid too (its plan caches are cleared by the same call).
        self._grid_args = (min_len, waste_cap)
        self.min_len, self.waste_cap, self.grid_source = bucketing.grid_for(
            dispatch.resolve(backend), min_len=min_len, waste_cap=waste_cap)
        #: shard cap: a bucket whose packed B*L exceeds this splits into
        #: multiple launches along the batch axis
        self.max_points_per_launch = max_points_per_launch
        self._pending: list[_Pending] = []
        self._ticket = 0
        self.last_report: list[BucketReport] = []

    # -- request intake ------------------------------------------------------

    def submit(self, chain: tc.TransformChain, points, *,
               qformat=None) -> int:
        """Queue one request; returns its ticket.  The next flush() returns
        results ordered by submission, one per queued request.

        ``qformat`` (a Qm.n name like "q8.7") routes the request through
        the fixed-point lane: it buckets under the format (not the
        submitted dtype), packs as int16 words (float points are
        quantised at pack time, int16 points are taken as already-Qm.n),
        and the result comes back dequantised float32 for float
        submissions, int16 for int16 ones.  Affine chains only --
        projective chains are rejected here, exactly as in
        ``TransformChain.apply``."""
        # a real copy, not a view: the queue must be immune to callers
        # mutating their buffer between submit and flush
        pts = np.array(points, copy=True)
        if pts.ndim < 1 or pts.shape[-1] != chain.dim:
            raise ValueError(f"chain is {chain.dim}D, points are "
                             f"{pts.shape}")
        fmt = None
        dequant = False
        if qformat is not None:
            fmt = quantize.as_qformat(qformat)
            quantize.reject_projective(chain.is_projective)
            dequant = quantize.points_need_quantize(pts.dtype)
        ticket = self._ticket
        self._ticket += 1
        self._pending.append(_Pending(ticket, chain, pts,
                                      pts.size // chain.dim,
                                      qformat=fmt, dequantize=dequant))
        return ticket

    def serve(self, items, *, qformat=None) -> list:
        """Convenience: submit an iterable of (chain, points), then flush."""
        for chain, points in items:
            self.submit(chain, points, qformat=qformat)
        return self.flush()

    @property
    def pending(self) -> int:
        return len(self._pending)

    # -- execution -----------------------------------------------------------

    def _bucket_key(self, p: _Pending, backend: str) -> tuple:
        lpad = bucketing.padded_length(p.n, min_len=self.min_len,
                                       waste_cap=self.waste_cap)
        # fixed-point requests bucket under the FORMAT, not the submitted
        # dtype: a float-submitted and an int16-submitted q8.7 request
        # pack into the same int16 batch (only unpack differs)
        dt = p.qformat.name if p.qformat is not None \
            else np.dtype(p.points.dtype).str
        return (p.chain.structure, backend, dt, lpad)

    def _pack(self, reqs: list[_Pending], lpad: int, plan: BatchPlan):
        """Pack a bucket: (B, lpad, d) zero-padded points + the stack of
        each request's host-folded parameters (the same numpy fold
        ``TransformChain.apply`` runs, so the folds are bit-identical).
        Fixed-point buckets pack int16 Qm.n words -- float submissions
        quantise here, and each fold quantises through the same
        ``quantize.quantize_fold`` the chain compiler's q lane uses."""
        dim = plan.dim
        if plan.qformat is not None:
            fmt = quantize.as_qformat(plan.qformat)
            packed = np.zeros((len(reqs), lpad, dim), np.int16)
            for i, r in enumerate(reqs):
                pts = r.points.reshape(-1, dim)
                packed[i, :r.n] = fmt.quantize(pts) if r.dequantize else pts
            folds = [quantize.quantize_fold(r.chain.fold(), plan.kind, fmt)
                     for r in reqs]
        else:
            dtype = reqs[0].points.dtype
            packed = np.zeros((len(reqs), lpad, dim), dtype)
            for i, r in enumerate(reqs):
                packed[i, :r.n] = r.points.reshape(-1, dim)
            folds = [r.chain.fold() for r in reqs]
        stacked = tuple(np.stack(part) for part in zip(*folds))
        return stacked, packed

    def _chunks(self, n_reqs: int, lpad: int) -> list[slice]:
        """Shard an oversized bucket along the batch axis."""
        cap = self.max_points_per_launch
        if cap is None or n_reqs * lpad <= cap:
            return [slice(0, n_reqs)]
        rows = max(1, cap // lpad)
        return [slice(i, min(i + rows, n_reqs))
                for i in range(0, n_reqs, rows)]

    @staticmethod
    def _stage(stacked, packed):
        """Host->device staging for one launch (the set-1 DMA).  When a
        device mesh is ambient the packed batch is placed sharded over the
        mesh's fsdp axes via the distributed.sharding helpers, so one
        launch spans the mesh (SPMD).  On a single device the arrays pass
        straight to the jitted plan, whose C++ argument path does the
        transfer -- an explicit ``device_put`` there is measurably pure
        python dispatch overhead (it dominated the flush profile)."""
        mesh = sharding.ambient_mesh()
        if mesh is not None and getattr(mesh, "axis_names", ()) \
                and math.prod(mesh.shape.values()) > 1:
            spec = sharding.batch_specs(packed, mesh, accum_dim=False)
            shard = sharding.to_shardings(spec, mesh, packed)
            return (jax.device_put(stacked), jax.device_put(packed, shard))
        return (stacked, packed)

    def flush(self) -> list:
        """Execute all pending requests; results in submission order."""
        pending, self._pending = self._pending, []
        backend = dispatch.resolve(self.backend)
        # grid lookup keyed by this flush's traffic scale (largest request
        # length): grids are tuned per scale, so the lookup must say which
        # scale is being served
        self.min_len, self.waste_cap, self.grid_source = bucketing.grid_for(
            backend, min_len=self._grid_args[0],
            waste_cap=self._grid_args[1],
            n=max((p.n for p in pending), default=0))
        results: dict[int, typing.Any] = {}
        buckets: dict[tuple, list[_Pending]] = {}
        for p in pending:
            if len(p.chain) == 0 or p.n == 0:
                res = p.points                             # identity / empty
                if p.chain.is_projective:                  # (only n == 0
                    res = _projected(                      #  can be here)
                        res, np.ones(res.shape[:-1], bool))
                results[p.ticket] = res
            else:
                buckets.setdefault(self._bucket_key(p, backend), []).append(p)

        # Build the launch list: (plan, stacked, packed, reqs) per shard.
        launches = []
        self.last_report = []
        for (structure, bk, _dt, lpad), reqs in buckets.items():
            qname = reqs[0].qformat.name if reqs[0].qformat is not None \
                else None
            plan = get_batch_plan(structure, bk, qname)
            stacked, packed = self._pack(reqs, lpad, plan)
            chunks = self._chunks(len(reqs), lpad)
            for sl in chunks:
                launches.append((plan, lpad,
                                 jax.tree.map(lambda x: x[sl], stacked),
                                 packed[sl], reqs[sl]))
            payload = sum(r.n for r in reqs)
            self.last_report.append(BucketReport(
                structure=_structure_tag(structure), kind=plan.kind,
                lpad=lpad, requests=len(reqs), launches=len(chunks),
                payload_points=payload, padded_points=len(reqs) * lpad))
            stats["buckets"] += 1
            stats["shards"] += len(chunks) - 1 if len(chunks) > 1 else 0
            stats["payload_points"] += payload
            stats["padded_points"] += len(reqs) * lpad

        # Double-buffered dispatch (frame-buffer set 0 / set 1): stage the
        # first launch, then keep one launch computing (set 0) while the
        # next launch's host->device transfer streams (set 1).  Nothing
        # blocks until unpack -- jax's async dispatch provides the overlap;
        # this loop just orders the work so it CAN overlap.
        outs = []
        staged = self._stage(launches[0][2], launches[0][3]) if launches \
            else None
        for k, (plan, lpad, _st, packed, reqs) in enumerate(launches):
            dev_params, dev_points = staged
            # the _q suffix keeps the lanes separately countable, same
            # discipline as TransformChain._record_fused
            opcount.record(
                f"serve_bucket_{plan.kind}{'_q' if plan.qformat else ''}",
                opcount.packed_chain_bytes(
                    len(reqs), lpad, plan.dim,
                    itemsize=packed.dtype.itemsize, kind=plan.kind))
            outs.append(plan.fn(dev_params, dev_points))   # async: set 0
            stats["launches"] += 1
            if k + 1 < len(launches):
                staged = self._stage(launches[k + 1][2],
                                     launches[k + 1][3])   # async: set 1

        # Unpack: one device->host sync per launch, then numpy slicing --
        # per-request unpack must not become per-request dispatch again
        # (a jax slice per request would re-pay the launch overhead the
        # batching just removed).  Each result is a payload-sized COPY:
        # a view would be read-only and would pin the whole padded batch
        # buffer for as long as the caller keeps any one result.
        # Projective launches return (points, mask); their results carry
        # the per-point cull mask as ``Projected.mask``.
        for (plan, lpad, _st, _pk, reqs), out in zip(launches, outs):
            if plan.kind == "projective":
                host, mask = np.asarray(out[0]), np.asarray(out[1])
                for i, r in enumerate(reqs):
                    results[r.ticket] = _projected(
                        np.array(host[i, :r.n].reshape(r.points.shape)),
                        np.array(mask[i, :r.n]
                                 .reshape(r.points.shape[:-1])))
            else:
                host = np.asarray(out)
                fmt = quantize.as_qformat(plan.qformat) \
                    if plan.qformat is not None else None
                for i, r in enumerate(reqs):
                    res = np.array(host[i, :r.n].reshape(r.points.shape))
                    if fmt is not None and r.dequantize:
                        res = fmt.dequantize(res)
                    results[r.ticket] = res
        stats["requests"] += len(pending)
        return [results[p.ticket] for p in pending]
