"""GeometryServer: plan-bucketed batched serving of transform chains.

The ROADMAP north-star is heavy traffic: millions of small "apply this
composite transform to these points" requests.  Dispatching each one
through ``TransformChain.apply`` pays one kernel launch per request and
leaves the plan cache as the only amortisation.  This engine is the
missing server loop, built from the paper's M1 execution discipline:

  1. **Bucket** -- pending requests group by
     ``(TransformChain.structure, backend, dtype, padded_length)``.
     Structure + backend pick the compiled plan (every request in a bucket
     hits ONE cached batch plan -- the context-memory discipline: load a
     context once, stream many operands through it); the size-bucketing
     policy (``bucketing.padded_length``: power-of-two grid refined under
     a waste cap) picks the padded length so padding waste per request
     stays below the cap.
  2. **Pack** -- each bucket's variable-length point sets pad/stack into
     one lane-dense (B, L, d) batch, and each request folds host-side
     through the SAME numpy fold ``apply`` uses
     (``TransformChain.fold``); the folded (A, t) pairs stack into the
     batch the kernels consume.
  3. **Launch** -- the whole bucket executes as a single fused kernel
     launch (``kernels.chain_diag_batch`` / ``chain_apply_batch`` /
     ``chain_project_batch`` -- the last for projective viewing-chain
     buckets, whose per-request results carry the in-kernel frustum-cull
     mask as ``Projected.mask``), the batched ``apply_many`` form of
     PR 1's one-HBM-pass chain kernels.
     Buckets whose packed batch exceeds the launch cap split into shards
     along the batch axis (and the packed buffer is placed through the
     ``distributed.sharding`` helpers when a device mesh is ambient).
  4. **Overlap** -- bucket k+1's host->device staging is dispatched while
     bucket k computes, the frame-buffer set-0/set-1 overlap of the paper:
     set 0 is the bucket the RC array (device) is computing on, set 1 is
     the bucket the DMA (host staging) is filling.

Equality contract vs. per-request ``apply`` (asserted by
``tests/test_serving.py``): the fold is bit-identical by construction (one
shared host code path); the fused application runs the same per-request
arithmetic, but XLA:CPU reserves per-program freedom in contracting float
multiply-adds, so across *different batch shapes* the last ULP may differ
-- packed results are exact on diagonal plans in practice and within 1 ULP
on matrix plans, deterministic for a fixed bucket shape, and padded rows
never contaminate payload rows (points are row-independent).

Fixed-point serving: ``submit(..., qformat="q8.7")`` routes a request
through the int16 Qm.n lane -- it buckets under the FORMAT (the dtype
slot of the bucket key), packs as int16 words through the same
``quantize.quantize_fold`` the chain compiler's q lane uses, and
launches the ``chain_*_batch_q`` kernels.  Integer arithmetic is exact
and order-independent, so the q lane's packed-vs-apply equality is
BITWISE on every plan kind (``tests/test_fixedpoint.py``) -- and each
packed launch moves 2-byte words, half the float32 HBM volume.

Fault tolerance (see ``docs/architecture.md`` section 6): ``submit`` is
the validation boundary -- malformed requests (bad shape, empty set,
float64, NaN/Inf points or folds, a q-format the error bound says would
wrap) raise the typed ``repro.errors`` taxonomy at intake instead of
detonating later inside a packed bucket.  ``flush`` contains failures
per LAUNCH: a bucket whose kernel launch fails (or whose output fails
the corruption check) never takes the other buckets down -- it walks a
recovery ladder of (1) bounded-exponential-backoff retries, (2) backend
degradation (``dispatch.fallback_ladder``: pallas -> interpret -> ref),
and (3) bisection -- split the bucket in half and recover each half
independently -- which quarantines a poison request in O(log B)
launches instead of losing B-1 good ones.  A request whose singleton
launch still fails resolves to a typed ``LaunchError`` in its result
slot: every submitted request resolves to a result or a typed error,
never silence.  Every step is counted (``stats``/``BucketReport``) so
recovery is CI-gateable on exact numbers; ``serving.faults`` injects
deterministic faults to drive this machinery in tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
import math
import time
import typing

import jax
import numpy as np

from repro import errors, quantize
from repro.autotune import cache as tuning
from repro.core import transform_chain as tc
from repro.distributed import sharding
from repro.kernels import (chain_apply_batch, chain_apply_batch_q,
                           chain_diag_batch, chain_diag_batch_q,
                           chain_project_batch, dispatch, opcount)
from repro.obs import metrics as obsm
from repro.obs import trace as obst
from repro.serving import bucketing
from repro.serving import errors as serrors

#: serving statistics (observable by tests, benchmarks and the driver):
#:   plan_compiles -- batched plans built (one per distinct structure+backend)
#:   plan_hits     -- plans served from the cache
#:   traces        -- jit traces of plan bodies (new (B, L) shapes retrace;
#:                    a seen shape must not)
#:   launches      -- batched kernel launches DISPATCHED (shards, retries and
#:                    recovery launches included; injector-blocked attempts
#:                    are not -- they never reached the device)
#:   requests      -- requests served through flush()
#:   buckets       -- plan buckets executed
#:   shards        -- extra launches from splitting oversized buckets
#:   payload_points / padded_points -- real vs padded points moved
#: fault-tolerance counters (all deterministic under a seeded injector;
#: the chaos CI lane gates on them exactly):
#:   rejected_requests  -- submissions refused with a typed RequestError
#:   q_fallbacks        -- q-lane requests rerouted to float32 because the
#:                         error bound predicted int16 wrap
#:   launch_failures    -- launch attempts that failed (injected or real)
#:   retries            -- re-attempts of a failing launch on the same rung
#:   backend_fallbacks  -- launches that succeeded on a degraded backend
#:   bisections         -- failing groups split in half to isolate poison
#:   recovered_requests -- requests that resolved OK after >= 1 failure
#:   failed_requests    -- requests resolved to a typed LaunchError
#: continuous-batching counters (incremented by serving.async_engine;
#: always 0 on the synchronous path):
#:   admitted_requests      -- requests past the admission gates
#:   queue_full_rejections  -- typed QueueFullError backpressure refusals
#:   rate_limit_rejections  -- typed RateLimitError token-bucket refusals
_STAT_KEYS = ("plan_compiles", "plan_hits", "traces", "launches",
              "requests", "buckets", "shards",
              "payload_points", "padded_points",
              "rejected_requests", "q_fallbacks", "launch_failures",
              "retries", "backend_fallbacks", "bisections",
              "recovered_requests", "failed_requests",
              "admitted_requests", "queue_full_rejections",
              "rate_limit_rejections")

#: the keys above that count SERVER activity (everything except the plan
#: cache, which is module-global like the cache it counts): each
#: GeometryServer keeps its own registry of these, and the module view
#: is their explicit cross-server aggregate
_SERVER_KEYS = tuple(k for k in _STAT_KEYS
                     if k not in ("plan_compiles", "plan_hits", "traces"))

#: the process-wide aggregate registry behind the module ``stats`` view
#: (obs.export.prometheus_text(REGISTRY) is the exposition entry point)
REGISTRY = obsm.MetricsRegistry("serving")

#: back-compat module view: a MutableMapping over REGISTRY counters with
#: the exact dict semantics the pre-obs ``stats`` dict had -- every
#: existing ``stats["launches"]`` read, ``+=`` and reset works unchanged
stats = obsm.StatsView(REGISTRY, _STAT_KEYS)

_BATCH_PLANS: dict[tuple, "BatchPlan"] = {}


def reset_stats() -> None:
    """Zero the module counters.  The counters are GLOBAL (shared by
    every server in the process); the documented invariant

        stats["launches"] == sum(r.launches for r in server.reports)

    therefore holds only for a single server whose lifetime starts at
    the reset -- use ``GeometryServer.reset_stats()``, which resets the
    module counters AND the server's accumulated report history in one
    step, when asserting it."""
    for k in stats:
        stats[k] = 0


def clear_plan_cache() -> None:
    """Drop all compiled batch plans (benchmarks use this for cold timings)."""
    _BATCH_PLANS.clear()


def _count_trace(kernel: str, backend: str, dtype: str, n: int) -> None:
    """Plan-body bookkeeping at jit-trace time (python side effects in a
    body run only under tracing): the traces counter, plus a plan.trace
    instant when the obs tracer is on -- retrace events are exactly the
    shape-cache misses the compiles/hits/traces discipline pins."""
    stats["traces"] += 1
    trc = obst.active()
    if trc.enabled:
        trc.instant("plan.trace", cache="serving", kernel=kernel,
                    backend=backend, dtype=dtype, n=n)


class Projected(np.ndarray):
    """A projective request's serving result: the projected points as a
    plain ndarray (shape-compatible with ``TransformChain.apply``
    everywhere), with the per-point frustum-cull mask attached as
    ``.mask`` (bool, the request's leading shape; True = inside).  The
    mask rides along so existing consumers that treat results as arrays
    keep working unchanged.  ``.mask`` describes EXACTLY the array
    ``flush`` returned: derived arrays (slices, transposes, sorts, any
    indexing -- same-shaped or not) read ``.mask`` as ``None`` rather
    than inheriting a mask whose rows may no longer line up with
    theirs.  Slice the mask alongside the points instead:
    ``pts[sel], res.mask[sel]``."""

    def __array_finalize__(self, obj):
        # derived arrays NEVER inherit: a shape check cannot detect
        # same-shape reorderings (r[::-1], fancy indexing), so the only
        # honest mask is the one _projected() attaches explicitly
        self._mask = None

    @property
    def mask(self) -> np.ndarray | None:
        """The cull mask ``_projected()`` attached, or None on a view."""
        return self._mask

    @mask.setter
    def mask(self, value: np.ndarray | None) -> None:
        """Attach a cull mask (only ``_projected()`` should set this)."""
        self._mask = value


def _projected(points: np.ndarray, mask: np.ndarray) -> Projected:
    out = np.ascontiguousarray(points).view(Projected)
    out.mask = mask
    return out


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """A compiled bucket executor: ``fn(folded_batch, pts3) -> out``
    (jitted), where ``folded_batch`` stacks the bucket's host-folded
    per-request parameters -- (s (B,d), t (B,d)), (A (B,d,d), t (B,d)),
    or (H (B,d+1,d+1), lo (B,d), hi (B,d)).  Projective plans return
    ``(projected (B,L,d), inside (B,L))``.  Fixed-point plans
    (``qformat`` set) take int16 Qm.n words -- each request's fold
    quantised by ``quantize.quantize_fold`` at pack time -- and return
    int16."""
    kind: str                      # "diag" | "matrix" | "projective"
    dim: int
    backend: str
    fn: typing.Callable
    qformat: str | None = None     # Qm.n name for fixed-point plans


def _compile_batch_q(structure: tuple, backend: str,
                     qname: str) -> BatchPlan:
    """Compile a fixed-point bucket executor: the same trace-time tuning
    consult as the float bodies, lowering to the int16 batch kernels with
    the format's fraction count as the requantising shift.  Projective
    structures never get here (``submit`` rejects chain + qformat)."""
    dim, _ = structure
    kind = tc.plan_kind_of(structure)
    fmt = quantize.as_qformat(qname)

    if kind == "diag":
        def body(folded, pts3):
            """Jitted q-format diagonal transform over a (B, L) bucket."""
            _count_trace("chain_diag_batch_q", backend, fmt.name,
                         pts3.shape[0] * pts3.shape[1])
            s, t = folded
            cfg = tuning.config_for("chain_diag_batch_q", backend, fmt.name,
                                    pts3.shape[0] * pts3.shape[1])
            return chain_diag_batch_q(pts3, s, t, n_frac=fmt.n,
                                      backend=backend, config=cfg)
    else:
        def body(folded, pts3):
            """Jitted q-format matmul transform over a (B, L) bucket."""
            _count_trace("chain_apply_batch_q", backend, fmt.name,
                         pts3.shape[0] * pts3.shape[1])
            a, t = folded
            cfg = tuning.config_for("chain_apply_batch_q", backend, fmt.name,
                                    pts3.shape[0] * pts3.shape[1])
            return chain_apply_batch_q(pts3, a, t, n_frac=fmt.n,
                                       backend=backend, config=cfg)

    return BatchPlan(kind=kind, dim=dim, backend=backend, fn=jax.jit(body),
                     qformat=fmt.name)


def _compile_batch(structure: tuple, backend: str) -> BatchPlan:
    dim, _ = structure
    kind = tc.plan_kind_of(structure)

    # Tuning-cache consult at trace time, mirroring the chain compiler:
    # the packed (B, L) shape is concrete under the jit trace, so the
    # lookup keys on the bucket's real size class; staging-only knobs keep
    # every config bit-identical (see core.transform_chain._compile).
    if kind == "diag":
        def body(folded, pts3):
            """Jitted diagonal transform over a (B, L) bucket."""
            _count_trace("chain_diag_batch", backend, str(pts3.dtype),
                         pts3.shape[0] * pts3.shape[1])
            s, t = folded
            cfg = tuning.config_for("chain_diag_batch", backend,
                                    str(pts3.dtype),
                                    pts3.shape[0] * pts3.shape[1])
            return chain_diag_batch(pts3, s, t, backend=backend, config=cfg)
    elif kind == "matrix":
        def body(folded, pts3):
            """Jitted matmul transform over a (B, L) bucket."""
            _count_trace("chain_apply_batch", backend, str(pts3.dtype),
                         pts3.shape[0] * pts3.shape[1])
            a, t = folded
            cfg = tuning.config_for("chain_apply_batch", backend,
                                    str(pts3.dtype),
                                    pts3.shape[0] * pts3.shape[1])
            return chain_apply_batch(pts3, a, t, backend=backend, config=cfg)
    else:
        def body(folded, pts3):
            """Jitted projective transform + cull over a (B, L) bucket."""
            _count_trace("chain_project_batch", backend, str(pts3.dtype),
                         pts3.shape[0] * pts3.shape[1])
            h, lo, hi = folded
            cfg = tuning.config_for("chain_project_batch", backend,
                                    str(pts3.dtype),
                                    pts3.shape[0] * pts3.shape[1])
            return chain_project_batch(pts3, h, lo, hi, backend=backend,
                                       config=cfg)

    return BatchPlan(kind=kind, dim=dim, backend=backend, fn=jax.jit(body))


def get_batch_plan(structure: tuple, backend: str,
                   qname: str | None = None) -> BatchPlan:
    """Mirrors ``transform_chain._get_plan`` deliberately: the two caches
    stay separate because they count into different stats domains (chain
    compiler vs serving engine) and compile different bodies (single
    folded pair vs stacked batch); keep their discipline in sync.
    ``qname`` selects the fixed-point lane (a distinct cached plan, as a
    distinct dtype would be)."""
    key = (structure, backend, qname)
    plan = _BATCH_PLANS.get(key)
    trc = obst.active()
    if plan is None:
        stats["plan_compiles"] += 1
        if trc.enabled:
            trc.instant("plan.compile", cache="serving",
                        structure=_structure_tag(structure),
                        backend=backend, q=qname)
        plan = _compile_batch_q(structure, backend, qname) \
            if qname is not None else _compile_batch(structure, backend)
        _BATCH_PLANS[key] = plan
    else:
        stats["plan_hits"] += 1
        if trc.enabled:
            trc.instant("plan.hit", cache="serving",
                        structure=_structure_tag(structure),
                        backend=backend, q=qname)
    return plan


# -- the server --------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Recovery policy knobs for one ``GeometryServer``.

    ``on_q_overflow`` decides what happens when ``quantize.error_bound``
    predicts a q-lane request would wrap int16:

      * ``"fallback"`` (default) -- serve the request through the float32
        lane instead (int16 submissions come back requantised int16, so
        the caller's contract holds); counted in ``stats["q_fallbacks"]``.
      * ``"reject"``  -- raise ``QRangeError`` at submit.
      * ``"wrap"``    -- legacy M1 semantics: no check, arithmetic wraps.
    """
    max_launch_attempts: int = 3   # per ladder rung, first attempt included
    backoff_base_s: float = 0.002  # sleep before retry k: base * factor**k
    backoff_factor: float = 2.0
    backoff_cap_s: float = 0.25
    validate_finite: bool = True   # reject NaN/Inf points/folds at submit
    validate_outputs: bool = True  # non-finite launch output => corruption
    on_q_overflow: str = "fallback"

    def __post_init__(self):
        if self.on_q_overflow not in ("fallback", "reject", "wrap"):
            raise ValueError(f"on_q_overflow must be fallback|reject|wrap, "
                             f"got {self.on_q_overflow!r}")
        if self.max_launch_attempts < 1:
            raise ValueError("max_launch_attempts must be >= 1")


@dataclasses.dataclass
class _Pending:
    ticket: int
    chain: tc.TransformChain
    points: np.ndarray             # original-shape host copy
    n: int                         # flattened point count
    fold: tuple | None = None      # host fold, computed once at submit
    qformat: quantize.QFormat | None = None   # fixed-point lane request
    dequantize: bool = False       # float submitted -> float32 back
    q_fallback: bool = False       # q request rerouted to the float lane
    requant: quantize.QFormat | None = None   # int16 caller: requantise out


class _FailedLaunch:
    """Marker in the outs list: this launch raised instead of returning."""

    def __init__(self, err: Exception):
        self.err = err


@dataclasses.dataclass
class _Launch:
    """One scheduled launch (a whole bucket, or one shard of it), with
    everything recovery needs to re-pack and re-dispatch its requests."""
    structure: tuple
    qname: str | None
    backend: str                   # the rung this flush started on
    lpad: int
    plan: BatchPlan
    stacked: tuple
    packed: np.ndarray
    reqs: list
    report: "BucketReport"
    track: str = ""                # trace track: the bucket signature


@dataclasses.dataclass
class BucketReport:
    """Per-bucket accounting for one flush (the driver prints these)."""
    structure: str                 # e.g. "2D:TSRT"
    kind: str                      # plan kind: diag | matrix | projective
    lpad: int                      # padded points per request
    requests: int
    payload_points: int
    padded_points: int
    launches: int = 0              # dispatched: 1 unless sharded/recovered
    backend: str = ""              # the rung the bucket started on
    final_backend: str = ""        # the rung its last success landed on
    retries: int = 0
    bisections: int = 0
    backend_fallbacks: int = 0
    recovered_requests: int = 0
    failed_requests: int = 0       # resolved to a typed LaunchError
    q_fallback_requests: int = 0   # q requests served through this float
    #                                bucket because the bound predicted wrap

    @property
    def waste(self) -> float:
        """Fraction of padded points that carried no payload."""
        return 1.0 - self.payload_points / max(1, self.padded_points)

    @property
    def launches_saved(self) -> int:
        """Kernel launches avoided by batching (requests - launches)."""
        return self.requests - self.launches


def _structure_tag(structure: tuple) -> str:
    dim, kinds = structure
    return f"{dim}D:" + "".join(k for k, _ in kinds)


def _bucket_track(structure: tuple, backend: str, dt: str,
                  lpad: int) -> str:
    """The trace track (Perfetto timeline) name of one plan bucket."""
    return f"{_structure_tag(structure)}|{backend}|{dt}|{lpad}"


#: plan kind -> the batch kernel whose tuning-cache entry a launch
#: consults (the launch span's ``config`` annotation names its source)
_KERNEL_BY_KIND = {"diag": "chain_diag_batch", "matrix": "chain_apply_batch",
                   "projective": "chain_project_batch"}


class GeometryServer:
    """Batched transform-serving engine over the PR 1 chain compiler.

        server = GeometryServer(backend="ref")
        tickets = [server.submit(chain_i, points_i) for ...]
        results = server.flush()        # one launch per plan bucket

    ``submit`` only records the request (host side, allocation-light);
    ``flush`` buckets, packs, and double-buffers the launches.  Results
    come back in submission order as host numpy arrays (serving results
    leave the device; per-request jax slicing would re-pay the dispatch
    overhead the batching removed), each with its request's original
    leading shape, matching ``chain_i.apply(points_i)`` under the module
    equality contract.
    """

    def __init__(self, *, backend: str | None = None,
                 min_len: int | None = None,
                 waste_cap: float | None = None,
                 max_points_per_launch: int | None = None,
                 fault_config: FaultConfig | None = None,
                 injector=None):
        self.backend = backend
        #: recovery policy (retry/backoff/ladder/q-overflow) -- see FaultConfig
        self.fault_config = fault_config or FaultConfig()
        #: optional seeded fault injector (serving.faults.FaultInjector);
        #: None in production -- the hooks below are no-ops without it
        self.injector = injector
        # size-grid knobs: explicit args win; unset knobs come from the
        # tuning cache when autotuning is enabled, else the historical
        # defaults (bucketing.MIN_LEN / WASTE_CAP) -- see bucketing.grid_for.
        # The explicit args are kept and re-resolved at every flush, so
        # toggling repro.autotune.set_enabled mid-life moves a server's
        # grid too (its plan caches are cleared by the same call).
        self._grid_args = (min_len, waste_cap)
        self.min_len, self.waste_cap, self.grid_source = bucketing.grid_for(
            dispatch.resolve(backend), min_len=min_len, waste_cap=waste_cap)
        #: shard cap: a bucket whose packed B*L exceeds this splits into
        #: multiple launches along the batch axis
        self.max_points_per_launch = max_points_per_launch
        #: this server's own typed registry: every server-scoped counter
        #: below is dual-written here and into the module aggregate
        #: (``stats``), so two servers in one process stop drifting into
        #: each other's numbers -- per-server truth lives here, and the
        #: module view is the EXPLICIT aggregate
        #: (``tests/test_obs.py::test_two_server_stats``); labeled
        #: bucket dimensions (plan kind, backend, dtype/qformat, size
        #: class) live here too
        self.metrics = obsm.MetricsRegistry("server")
        for k in _SERVER_KEYS:
            self.metrics.counter(k)
        self._pending: list[_Pending] = []
        self._ticket = 0
        self.last_report: list[BucketReport] = []
        #: every BucketReport this server ever produced (last_report is
        #: the latest flush's slice of it).  This is what makes the
        #: launch-accounting invariant hold ACROSS flush cycles --
        #: ``stats["launches"] == sum(r.launches for r in reports)`` for
        #: a single server whose lifetime starts at a stats reset
        #: (recovery launches included: recovery counts into the same
        #: BucketReport objects).  Cleared by ``reset_stats()``.
        self.reports: list[BucketReport] = []

    def _bump(self, name: str, n: int = 1) -> None:
        """Count one server-scoped event: this server's registry AND the
        module aggregate move together (dual-write keeps the historical
        reset semantics -- ``reset_stats()`` zeroes the aggregate without
        erasing any live server's own history)."""
        stats[name] += n
        self.metrics.counter(name).inc(n)

    # -- request intake ------------------------------------------------------

    def submit(self, chain: tc.TransformChain, points, *,
               qformat=None) -> int:
        """Queue one request; returns its ticket.  The next flush() returns
        results ordered by submission, one per queued request.

        ``qformat`` (a Qm.n name like "q8.7") routes the request through
        the fixed-point lane: it buckets under the format (not the
        submitted dtype), packs as int16 words (float points are
        quantised at pack time, int16 points are taken as already-Qm.n),
        and the result comes back dequantised float32 for float
        submissions, int16 for int16 ones.  Affine chains only --
        projective chains are rejected here, exactly as in
        ``TransformChain.apply``.

        Submit is the isolation boundary: a malformed request (bad
        shape, empty point set, float64, NaN/Inf points or parameters, a
        q-format the error bound predicts would wrap under
        ``on_q_overflow="reject"``) raises a typed ``RequestError``
        carrying this request's ticket id HERE, before the request can
        reach a packed bucket and take its neighbours down with it."""
        return self.enqueue(self.validate(chain, points, qformat=qformat))

    def submit_scene(self, scene, name: str, points, *,
                     qformat=None) -> int:
        """Queue one request against a scene node: the chain is the
        node's world chain (``SceneGraph.world_chain``) and the fold is
        the scene's CACHED world fold, resolved through the shared
        ``FoldCache`` instead of refolded here -- thousands of requests
        attached under a common prefix fold that prefix once, not once
        per request.

        Everything downstream is the ordinary serving lane: the same
        (structure, backend, dtype, size-class) bucket key, the same
        packed kernels, the same typed validation boundary, the same
        ``qformat=`` fixed-point routing (the cached fold quantises
        through ``quantize.quantize_fold`` at pack time exactly like a
        per-request fold).  The cached fold is bit-identical to
        ``chain.fold()`` by the carry-fold construction
        (``transform_chain.fold_carry_extend``), so results are bitwise
        equal to submitting ``scene.world_chain(name)`` through
        ``submit`` -- and to the per-request ``apply`` oracle under the
        engine's usual equality contract."""
        chain = scene.world_chain(name)
        fold = scene.world_fold(name) if len(chain) else None
        return self.enqueue(self.validate(chain, points, qformat=qformat,
                                          fold=fold))

    def validate(self, chain: tc.TransformChain, points, *,
                 qformat=None, fold=None) -> "_Pending":
        """The intake half of ``submit``: assign a ticket id, run the
        full validation boundary, and return the queue entry WITHOUT
        queueing it.  The continuous-batching front-end
        (``serving.async_engine``) uses this split -- it validates at
        arrival time but hands entries to ``enqueue`` only when its
        flush policy schedules them, so the two paths share one
        validation boundary and one ticket sequence.  Rejected
        submissions burn their id: the id in a typed error is never
        reused.

        ``fold`` injects precomputed folded parameters (the scene
        graph's cached world fold) in place of the ``chain.fold()`` this
        method would otherwise run; the injected fold MUST be
        bit-identical to ``chain.fold()`` -- the scene cache guarantees
        that by construction -- and passes through the same finiteness /
        q-overflow validation either way."""
        ticket = self._ticket
        self._ticket += 1
        trc = obst.active()
        sid = trc.begin("request.validate", ticket=ticket) \
            if trc.enabled else None
        try:
            p = self._validate(chain, points, qformat, ticket, fold=fold)
        except errors.RequestError as e:
            self._bump("rejected_requests")
            if sid is not None:
                trc.end(sid, outcome="rejected",
                        code=getattr(e, "code", type(e).__name__))
            raise
        if sid is not None:
            trc.end(sid, outcome="admitted",
                    kind=tc.plan_kind_of(chain.structure) if len(chain)
                    else "identity",
                    q=p.qformat.name if p.qformat is not None else None,
                    points=p.n)
        return p

    def enqueue(self, p: "_Pending") -> int:
        """Queue a ``validate``d entry for the next flush; returns its
        ticket.  ``submit`` is exactly ``enqueue(validate(...))``."""
        self._pending.append(p)
        return p.ticket

    def reset_stats(self) -> None:
        """Zero the module counters AND this server's accumulated report
        history together, so the cross-flush launch-accounting invariant
        (``stats["launches"] == sum(r.launches for r in self.reports)``,
        recovery launches included) restarts from a consistent origin.
        The module-level ``reset_stats`` alone cannot give that: it
        zeroes the global counters but leaves every server's report
        history counting launches from before the reset.  This server's
        own registry resets too (other servers' registries are theirs
        and stay untouched -- which is exactly why the aggregate and the
        per-server registries are separate objects)."""
        reset_stats()
        self.metrics.reset()
        self.reports = []
        self.last_report = []

    def _validate(self, chain: tc.TransformChain, points, qformat,
                  ticket: int, fold=None) -> _Pending:
        """Build the queue entry, raising the typed taxonomy on anything
        the packed lane could choke on later.  ``fold`` skips the
        ``chain.fold()`` recompute (scene-cached folds); every check
        downstream of the fold runs on the injected value unchanged."""
        cfg = self.fault_config
        # a real copy, not a view: the queue must be immune to callers
        # mutating their buffer between submit and flush
        pts = np.array(points, copy=True)
        errors.check_points(pts, chain.dim, ticket=ticket)
        fmt = None
        dequant = False
        if qformat is not None:
            fmt = quantize.as_qformat(qformat)
            quantize.reject_projective(chain.is_projective)
            try:
                dequant = quantize.points_need_quantize(pts.dtype)
            except TypeError as e:
                raise errors.DtypeError(str(e), ticket=ticket) from None
        elif np.dtype(pts.dtype) != np.float32:
            raise errors.DtypeError(
                f"serving float lane is float32, got {np.dtype(pts.dtype)}; "
                f"cast before submit (or pass qformat= for int16)",
                ticket=ticket)
        if cfg.validate_finite and np.issubdtype(pts.dtype, np.floating) \
                and not np.isfinite(pts).all():
            raise errors.NonFiniteError(
                "points contain NaN/Inf", ticket=ticket)
        if not len(chain):
            fold = None
        else:
            if fold is None:
                fold = chain.fold()
            if cfg.validate_finite:
                # projective folds legitimately carry +/-inf cull bounds
                parts = fold[:1] if chain.is_projective else fold
                if not all(np.isfinite(np.asarray(f)).all() for f in parts):
                    raise errors.NonFiniteError(
                        "chain parameters fold to NaN/Inf", ticket=ticket)
        q_fallback = False
        requant = None
        if fmt is not None and fold is not None \
                and cfg.on_q_overflow != "wrap":
            kind = tc.plan_kind_of(chain.structure)
            x_vals = fmt.dequantize(pts) if not dequant else pts
            x_max = float(np.abs(x_vals).max())
            if cfg.on_q_overflow == "reject":
                quantize.ensure_fits(fold, kind, fmt, x_max, ticket=ticket)
            elif not quantize.fits(fold, kind, fmt, x_max):
                # degrade, don't wrap: reroute through the float32 lane.
                # int16 callers still get int16 back (requantised), so the
                # submit contract holds; only the arithmetic substrate
                # changed -- the same trade the backend ladder makes.
                self._bump("q_fallbacks")
                q_fallback = True
                if not dequant:
                    pts = fmt.dequantize(pts)
                    requant = fmt
                fmt = None
                dequant = False
        return _Pending(ticket, chain, pts, pts.size // chain.dim,
                        fold=fold, qformat=fmt, dequantize=dequant,
                        q_fallback=q_fallback, requant=requant)

    def serve(self, items, *, qformat=None) -> list:
        """Convenience: submit an iterable of (chain, points), then flush."""
        for chain, points in items:
            self.submit(chain, points, qformat=qformat)
        return self.flush()

    @property
    def pending(self) -> int:
        """Requests submitted but not yet flushed."""
        return len(self._pending)

    # -- execution -----------------------------------------------------------

    def _bucket_key(self, p: _Pending, backend: str) -> tuple:
        lpad = bucketing.padded_length(p.n, min_len=self.min_len,
                                       waste_cap=self.waste_cap)
        # fixed-point requests bucket under the FORMAT, not the submitted
        # dtype: a float-submitted and an int16-submitted q8.7 request
        # pack into the same int16 batch (only unpack differs)
        dt = p.qformat.name if p.qformat is not None \
            else np.dtype(p.points.dtype).str
        return (p.chain.structure, backend, dt, lpad)

    def _pack(self, reqs: list[_Pending], lpad: int, plan: BatchPlan):
        """Pack a bucket: (B, lpad, d) zero-padded points + the stack of
        each request's host-folded parameters (the same numpy fold
        ``TransformChain.apply`` runs, so the folds are bit-identical).
        Fixed-point buckets pack int16 Qm.n words -- float submissions
        quantise here, and each fold quantises through the same
        ``quantize.quantize_fold`` the chain compiler's q lane uses.
        Folds come precomputed from submit (``_Pending.fold``), so a
        recovery re-pack is bit-identical to the original pack."""
        dim = plan.dim
        if plan.qformat is not None:
            fmt = quantize.as_qformat(plan.qformat)
            packed = np.zeros((len(reqs), lpad, dim), np.int16)
            for i, r in enumerate(reqs):
                pts = r.points.reshape(-1, dim)
                packed[i, :r.n] = fmt.quantize(pts) if r.dequantize else pts
            folds = [quantize.quantize_fold(r.fold, plan.kind, fmt)
                     for r in reqs]
        else:
            dtype = reqs[0].points.dtype
            packed = np.zeros((len(reqs), lpad, dim), dtype)
            for i, r in enumerate(reqs):
                packed[i, :r.n] = r.points.reshape(-1, dim)
            folds = [r.fold for r in reqs]
        stacked = tuple(np.stack(part) for part in zip(*folds))
        return stacked, packed

    def _chunks(self, n_reqs: int, lpad: int) -> list[slice]:
        """Shard an oversized bucket along the batch axis."""
        cap = self.max_points_per_launch
        if cap is None or n_reqs * lpad <= cap:
            return [slice(0, n_reqs)]
        rows = max(1, cap // lpad)
        return [slice(i, min(i + rows, n_reqs))
                for i in range(0, n_reqs, rows)]

    @staticmethod
    def _stage(stacked, packed):
        """Host->device staging for one launch (the set-1 DMA).  When a
        device mesh is ambient the packed batch is placed sharded over the
        mesh's fsdp axes via the distributed.sharding helpers, so one
        launch spans the mesh (SPMD).  On a single device the arrays pass
        straight to the jitted plan, whose C++ argument path does the
        transfer -- an explicit ``device_put`` there is measurably pure
        python dispatch overhead (it dominated the flush profile)."""
        mesh = sharding.ambient_mesh()
        if mesh is not None and getattr(mesh, "axis_names", ()) \
                and math.prod(mesh.shape.values()) > 1:
            spec = sharding.batch_specs(packed, mesh, accum_dim=False)
            shard = sharding.to_shardings(spec, mesh, packed)
            return (jax.device_put(stacked), jax.device_put(packed, shard))
        return (stacked, packed)

    # -- fault-injection hooks (no-ops without an injector) ------------------

    def _check_injected(self, reqs: list, rung_index: int,
                        attempt: int) -> None:
        """Raise ``InjectedFault`` when the seeded injector scheduled a
        launch failure for this (request group, rung, attempt)."""
        if self.injector is not None:
            self.injector.before_launch(
                tuple(r.ticket for r in reqs), rung_index, attempt)

    def _stage_attempt(self, plan: BatchPlan, stacked, packed, reqs: list,
                       rung_index: int, attempt: int):
        """Staging with the corruption hook: the injector may flip words
        in the packed operand buffer on its way to the device.  Only
        float affine buckets are corruptible -- their outputs are
        finite-validatable; projective guarded divides and int16 words
        have no such invariant to check against."""
        inj = self.injector
        if inj is not None and plan.qformat is None \
                and plan.kind != "projective":
            packed = inj.corrupt_staging(
                packed, tuple(r.ticket for r in reqs), rung_index, attempt)
        return self._stage(stacked, packed)

    def _count_launch(self, plan: BatchPlan, lpad: int, reqs: list,
                      packed: np.ndarray, report: BucketReport,
                      rung: int = 0, attempt: int = 0,
                      track: str | None = None) -> None:
        """Bookkeeping for one DISPATCHED launch (called after the
        injector gate: a blocked attempt never reached the device).
        This is the ONE place ``stats["launches"]`` moves, and the one
        place launch trace events come from, so the span-count invariant
        ``count("launch") == stats["launches"]`` holds by construction
        (``tests/test_obs.py`` pins it)."""
        # the _q suffix keeps the lanes separately countable, same
        # discipline as TransformChain._record_fused
        nbytes = opcount.packed_chain_bytes(
            len(reqs), lpad, plan.dim,
            itemsize=packed.dtype.itemsize, kind=plan.kind)
        opcount.record(
            f"serve_bucket_{plan.kind}{'_q' if plan.qformat else ''}",
            nbytes)
        self._bump("launches")
        report.launches += 1
        trc = obst.active()
        if trc.enabled:
            # per-attempt annotation: backend rung, plan kind, autotune
            # config source, the opcount HBM bytes this launch moves, and
            # the cost model's per-launch prediction (bytes / FLOPs / M1
            # cycle projection) -- attached at dispatch time so the
            # profiler can fold predicted-vs-observed ratios out of the
            # span stream without re-deriving launch shapes
            from repro.autotune import costmodel  # late: traced path only
            dtype = plan.qformat if plan.qformat is not None \
                else str(packed.dtype)
            kernel = _KERNEL_BY_KIND[plan.kind] \
                + ("_q" if plan.qformat else "")
            cfg = tuning.config_for(kernel, plan.backend, dtype,
                                    len(reqs) * lpad)
            pred = costmodel.predict_launch(
                plan.kind, len(reqs), lpad, plan.dim,
                qformat=plan.qformat, itemsize=packed.dtype.itemsize)
            trc.instant(
                "launch", tickets=tuple(r.ticket for r in reqs),
                track=track, backend=plan.backend, kind=plan.kind,
                q=plan.qformat, rung=rung, attempt=attempt,
                rows=len(reqs), lpad=lpad, kernel=pred.kernel,
                hbm_bytes=nbytes, pred_hbm_bytes=pred.hbm_bytes,
                pred_flops=pred.flops, pred_m1_cycles=pred.m1_cycles,
                config=cfg.source)

    # -- flush: dispatch, unpack, recover ------------------------------------

    def flush(self) -> list:
        """Execute all pending requests; results in submission order.

        Failure containment: a launch that raises (at dispatch or at
        materialisation -- jax's async dispatch can surface device errors
        either place) or whose output fails the corruption check is set
        aside; every OTHER launch completes normally, then the failed
        groups walk the recovery ladder (``_recover``).  A request whose
        recovery exhausts resolves to a typed ``LaunchError`` in its
        result slot -- callers check with ``serving.is_error`` -- so the
        returned list always lines up 1:1 with submissions."""
        pending, self._pending = self._pending, []
        backend = dispatch.resolve(self.backend)
        trc = obst.active()
        fsid = trc.begin("flush", requests=len(pending)) \
            if trc.enabled else None
        # grid lookup keyed by this flush's traffic scale (largest request
        # length): grids are tuned per scale, so the lookup must say which
        # scale is being served
        self.min_len, self.waste_cap, self.grid_source = bucketing.grid_for(
            backend, min_len=self._grid_args[0],
            waste_cap=self._grid_args[1],
            n=max((p.n for p in pending), default=0))
        results: dict[int, typing.Any] = {}
        buckets: dict[tuple, list[_Pending]] = {}
        for p in pending:
            if len(p.chain) == 0:
                results[p.ticket] = p.points   # identity passthrough
                if trc.enabled:
                    trc.instant("request.resolve", ticket=p.ticket,
                                outcome="identity")
            else:                              # (empty sets reject at submit)
                buckets.setdefault(self._bucket_key(p, backend), []).append(p)

        # Build the launch list: one _Launch per shard.
        launches: list[_Launch] = []
        self.last_report = []
        for (structure, bk, _dt, lpad), reqs in buckets.items():
            qname = reqs[0].qformat.name if reqs[0].qformat is not None \
                else None
            track = _bucket_track(structure, bk, _dt, lpad)
            bsid = trc.begin("bucket.assemble", track=track,
                             tickets=tuple(r.ticket for r in reqs),
                             rows=len(reqs), lpad=lpad) \
                if trc.enabled else None
            plan = get_batch_plan(structure, bk, qname)
            if trc.enabled:
                psid = trc.begin("bucket.pack", track=track,
                                 rows=len(reqs), lpad=lpad,
                                 q=plan.qformat)
                stacked, packed = self._pack(reqs, lpad, plan)
                trc.end(psid)
            else:
                stacked, packed = self._pack(reqs, lpad, plan)
            chunks = self._chunks(len(reqs), lpad)
            payload = sum(r.n for r in reqs)
            report = BucketReport(
                structure=_structure_tag(structure), kind=plan.kind,
                lpad=lpad, requests=len(reqs), payload_points=payload,
                padded_points=len(reqs) * lpad, backend=bk,
                final_backend=bk,
                q_fallback_requests=sum(r.q_fallback for r in reqs))
            for sl in chunks:
                launches.append(_Launch(
                    structure=structure, qname=qname, backend=bk, lpad=lpad,
                    plan=plan,
                    stacked=jax.tree.map(lambda x: x[sl], stacked),
                    packed=packed[sl], reqs=reqs[sl], report=report,
                    track=track))
            self.last_report.append(report)
            self.reports.append(report)
            self._bump("buckets")
            self._bump("shards",
                       len(chunks) - 1 if len(chunks) > 1 else 0)
            self._bump("payload_points", payload)
            self._bump("padded_points", len(reqs) * lpad)
            # the labeled serving dimensions (plan kind, backend,
            # dtype/qformat, padded size class) -- per-server only: the
            # aggregate view stays the flat counter set it always was
            self.metrics.counter(
                "bucket_requests",
                labels=("kind", "backend", "dtype", "size_class"),
            ).labels(kind=plan.kind, backend=bk, dtype=_dt,
                     size_class=lpad).inc(len(reqs))
            if bsid is not None:
                trc.end(bsid, kind=plan.kind, shards=len(chunks),
                        payload_points=payload)

        # Phase 1 -- optimistic double-buffered dispatch (frame-buffer
        # set 0 / set 1): stage the first launch, then keep one launch
        # computing (set 0) while the next launch's host->device transfer
        # streams (set 1).  Nothing blocks until unpack -- jax's async
        # dispatch provides the overlap; this loop just orders the work so
        # it CAN overlap.  A launch that raises is recorded and skipped,
        # never aborting its siblings.
        def _stage_first(L: _Launch):
            try:
                return self._stage_attempt(L.plan, L.stacked, L.packed,
                                           L.reqs, 0, 0)
            except Exception as e:       # staging failure is a launch failure
                return _FailedLaunch(e)

        dsid = trc.begin("flush.dispatch", launches=len(launches)) \
            if trc.enabled else None
        outs: list = []
        staged = _stage_first(launches[0]) if launches else None
        for k, L in enumerate(launches):
            try:
                if isinstance(staged, _FailedLaunch):
                    raise staged.err
                dev_params, dev_points = staged
                self._check_injected(L.reqs, 0, 0)
                self._count_launch(L.plan, L.lpad, L.reqs, L.packed, L.report,
                                   rung=0, attempt=0, track=L.track)
                outs.append(L.plan.fn(dev_params, dev_points))  # async: set 0
            except Exception as e:
                outs.append(_FailedLaunch(e))
            if k + 1 < len(launches):
                staged = _stage_first(launches[k + 1])          # async: set 1
        if dsid is not None:
            trc.end(dsid)

        # Phase 2 -- unpack with capture: materialisation is where async
        # device errors (and injected corruption) actually surface, so
        # each launch unpacks under its own try.
        usid = trc.begin("flush.unpack") if trc.enabled else None
        failed: list[tuple[_Launch, Exception]] = []
        for L, out in zip(launches, outs):
            lsid = trc.begin("unpack", track=L.track,
                             tickets=tuple(r.ticket for r in L.reqs)) \
                if trc.enabled else None
            if isinstance(out, _FailedLaunch):
                self._bump("launch_failures")
                failed.append((L, out.err))
                if lsid is not None:
                    trc.end(lsid, outcome="failed",
                            error=type(out.err).__name__)
                continue
            try:
                self._unpack(L.plan, L.reqs, out, results)
            except Exception as e:
                self._bump("launch_failures")
                failed.append((L, e))
                if lsid is not None:
                    trc.end(lsid, outcome="failed", error=type(e).__name__)
            else:
                if lsid is not None:
                    trc.end(lsid, outcome="ok")
        if usid is not None:
            trc.end(usid, failed=len(failed))

        # Phase 3 -- sequential recovery of the failed groups (the rare
        # path; overlap no longer matters, determinism and containment do).
        if failed:
            rsid = trc.begin("flush.recover", groups=len(failed)) \
                if trc.enabled else None
            for L, err in failed:
                self._recover(L, list(L.reqs), err, results)
            if rsid is not None:
                trc.end(rsid)

        self._bump("requests", len(pending))
        if fsid is not None:
            trc.end(fsid, buckets=len(buckets), launches=len(launches))
        return [results[p.ticket] for p in pending]

    def _unpack(self, plan: BatchPlan, reqs: list, out,
                results: dict) -> None:
        """Unpack one launch: one device->host sync, then numpy slicing --
        per-request unpack must not become per-request dispatch again (a
        jax slice per request would re-pay the launch overhead the
        batching just removed).  Each result is a payload-sized COPY: a
        view would be read-only and would pin the whole padded batch
        buffer for as long as the caller keeps any one result.
        Projective launches return (points, mask); their results carry
        the per-point cull mask as ``Projected.mask``."""
        trc = obst.active()
        if plan.kind == "projective":
            host, mask = np.asarray(out[0]), np.asarray(out[1])
            for i, r in enumerate(reqs):
                results[r.ticket] = _projected(
                    np.array(host[i, :r.n].reshape(r.points.shape)),
                    np.array(mask[i, :r.n]
                             .reshape(r.points.shape[:-1])))
                if trc.enabled:
                    trc.instant("request.resolve", ticket=r.ticket,
                                outcome="ok")
            return
        host = np.asarray(out)
        if self.fault_config.validate_outputs and plan.qformat is None \
                and not np.isfinite(host).all():
            # inputs validated finite at submit, so a non-finite output
            # means the staged buffer (or the launch) corrupted in flight;
            # discard wholesale and let recovery re-pack from the pristine
            # host copies
            raise serrors.CorruptionError(
                f"non-finite values in {plan.kind} launch output "
                f"(B={len(reqs)})")
        fmt = quantize.as_qformat(plan.qformat) \
            if plan.qformat is not None else None
        for i, r in enumerate(reqs):
            res = np.array(host[i, :r.n].reshape(r.points.shape))
            if fmt is not None and r.dequantize:
                res = fmt.dequantize(res)
            elif r.requant is not None:
                # q->float fallback for an int16 caller: requantise so the
                # submit contract (int16 in -> int16 out) holds
                res = r.requant.quantize(res)
            results[r.ticket] = res
            if trc.enabled:
                trc.instant("request.resolve", ticket=r.ticket,
                            outcome="ok")

    def _recover(self, L: _Launch, reqs: list, err: Exception,
                 results: dict, depth: int = 0) -> None:
        """Walk the recovery ladder for one failed launch group:

          1. retry the same rung, bounded exponential backoff between
             attempts (transient faults);
          2. degrade the backend along ``dispatch.fallback_ladder``
             (substrate faults: each rung computes the same function);
          3. bisect -- split the group in half and recover each half with
             a fresh ladder (poison isolation in O(log B) launches).

        A singleton that exhausts every rung resolves to a typed
        ``LaunchError`` carrying its ticket: the request fails alone,
        with a name, and nothing is silently dropped."""
        cfg = self.fault_config
        rungs = dispatch.fallback_ladder(L.backend)
        trc = obst.active()
        rtrack = f"recovery:{L.track}" if L.track else "recovery"
        gsid = trc.begin("recover", track=rtrack,
                         tickets=tuple(r.ticket for r in reqs),
                         depth=depth, rows=len(reqs),
                         error=type(err).__name__) \
            if trc.enabled else None
        # at depth 0 the optimistic dispatch already burned attempt 0 of
        # rung 0; bisected halves start their ladder fresh
        n_failures = 1 if depth == 0 else 0
        for ri, rung in enumerate(rungs):
            plan = L.plan if ri == 0 \
                else get_batch_plan(L.structure, rung, L.qname)
            start = n_failures if ri == 0 and depth == 0 else 0
            for attempt in range(start, cfg.max_launch_attempts):
                if n_failures:
                    time.sleep(min(cfg.backoff_cap_s, cfg.backoff_base_s *
                                   cfg.backoff_factor ** (n_failures - 1)))
                if attempt > 0:
                    self._bump("retries")
                    L.report.retries += 1
                asid = trc.begin("recover.attempt", track=rtrack,
                                 rung=rung, attempt=attempt) \
                    if trc.enabled else None
                try:
                    stacked, packed = self._pack(reqs, L.lpad, plan)
                    dev = self._stage_attempt(plan, stacked, packed, reqs,
                                              ri, attempt)
                    self._check_injected(reqs, ri, attempt)
                    self._count_launch(plan, L.lpad, reqs, packed, L.report,
                                       rung=ri, attempt=attempt, track=rtrack)
                    out = plan.fn(*dev)
                    self._unpack(plan, reqs, out, results)
                except Exception as e:
                    self._bump("launch_failures")
                    err = e
                    n_failures += 1
                    if asid is not None:
                        trc.end(asid, outcome="failed",
                                error=type(e).__name__)
                    continue
                if asid is not None:
                    trc.end(asid, outcome="ok")
                if ri > 0:
                    self._bump("backend_fallbacks")
                    L.report.backend_fallbacks += 1
                    L.report.final_backend = rung
                self._bump("recovered_requests", len(reqs))
                L.report.recovered_requests += len(reqs)
                if gsid is not None:
                    trc.end(gsid, outcome="recovered", rung=rung)
                return
        if len(reqs) > 1:
            self._bump("bisections")
            L.report.bisections += 1
            if trc.enabled:
                trc.instant("recover.bisect", track=rtrack,
                            tickets=tuple(r.ticket for r in reqs),
                            depth=depth, rows=len(reqs))
            if gsid is not None:
                trc.end(gsid, outcome="bisected")
            mid = len(reqs) // 2
            self._recover(L, reqs[:mid], err, results, depth + 1)
            self._recover(L, reqs[mid:], err, results, depth + 1)
            return
        r = reqs[0]
        resolution = errors.LaunchError(
            f"launch failed on every rung of {rungs} "
            f"(x{cfg.max_launch_attempts} attempts each): {err}",
            ticket=r.ticket)
        if trc.enabled and trc.recorder is not None:
            # the event window that led here rides on the resolution --
            # a chaos failure is debuggable from the error object alone
            resolution.flight = trc.recorder.snapshot()
        results[r.ticket] = resolution
        self._bump("failed_requests")
        L.report.failed_requests += 1
        if trc.enabled:
            trc.instant("request.resolve", ticket=r.ticket,
                        outcome="launch-error")
        if gsid is not None:
            trc.end(gsid, outcome="failed")
