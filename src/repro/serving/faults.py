"""Seeded fault injection + the chaos soak harness for GeometryServer.

The fault model (``docs/architecture.md`` section 6) has three injection
points, each mapped to a hook the engine already calls on the REAL
execution path -- the injector never gets a private code path to make
itself pass:

  * **launch faults** -- ``FaultInjector.before_launch`` raises
    ``InjectedFault`` exactly where a Mosaic compile error or device
    abort would surface; the engine's retry / backend-ladder / bisection
    machinery cannot tell the difference.
  * **staging corruption** -- ``corrupt_staging`` flips words in the
    packed operand buffer on its way to the device (the DMA-corruption
    failure mode); the engine detects it downstream through the output
    finiteness check and re-packs from the pristine host copies.
  * **malformed requests** -- ``malform`` produces the intake garbage
    (wrong dim, empty set, float64, NaN) that ``submit`` must reject
    with a typed error before it can poison a packed bucket.

Every decision is a pure function of ``(seed, ticket)`` -- roles come
from ``np.random.default_rng([SALT, seed, ticket])`` -- so a soak run
is bit-reproducible: the chaos CI lane gates on EXACT counter values,
not "some faults happened".

``run_chaos_soak`` is the harness: a seeded mixed-lane workload (all
three plan kinds, float + q dtype lanes) served under injection, every
result verified against per-request ``TransformChain.apply`` oracles,
and the full counter set returned as a ``ChaosReport``.  Its invariant
is the PR's headline contract: zero lost requests -- every submission
resolves to a verified result or a typed, ticket-named error.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import quantize
from repro.core import transform_chain as tc
from repro.obs import recorder as obsrec
from repro.obs import trace as obst
from repro.serving import engine, workload
from repro.serving.clock import VirtualClock
from repro.serving.errors import InjectedFault, LaunchError, RequestError

#: role-draw salt: keeps the injector's stream disjoint from every other
#: seeded stream in the repo (workloads use their own salts)
_SALT = 0xFA17

#: what a ticket can be scheduled to do, and which recovery mechanism it
#: exercises:
#:   flaky   -- launch fails while attempt < flaky_attempts (same rung):
#:              recovered by RETRY with backoff
#:   backend -- launch fails on ladder rung 0, any attempt: recovered by
#:              BACKEND DEGRADATION (pallas -> interpret -> ref)
#:   corrupt -- staged words NaN out at (rung 0, attempt 0): detected by
#:              the output finiteness check, recovered by a pristine
#:              re-pack RETRY
#:   poison  -- launch fails at every rung and attempt: isolated by
#:              BISECTION, resolves to a typed LaunchError; its bucket
#:              neighbours all recover
ROLES = ("flaky", "backend", "corrupt", "poison")


@dataclasses.dataclass
class FaultInjector:
    """Deterministic per-ticket fault scheduler.

    Roles are assigned per TICKET (not per bucket): a launch group fails
    when any member request's role says so at this (rung, attempt), which
    is exactly how a real poison request takes a packed bucket down.
    Explicit ``*_tickets`` overrides win over the seeded rate draw --
    tests pin scenarios with them; the soak uses rates."""
    seed: int = 0
    flaky_rate: float = 0.0
    backend_rate: float = 0.0
    corrupt_rate: float = 0.0
    poison_rate: float = 0.0
    flaky_attempts: int = 2        # flaky launches fail attempts < this
    flaky_tickets: frozenset = frozenset()
    backend_tickets: frozenset = frozenset()
    corrupt_tickets: frozenset = frozenset()
    poison_tickets: frozenset = frozenset()

    def __post_init__(self):
        self.injected_launch_faults = 0
        self.injected_corruptions = 0
        self._roles: dict[int, str | None] = {}

    def role(self, ticket: int) -> str | None:
        """This ticket's scheduled role (None = clean), memoised; the
        draw itself depends only on (seed, ticket)."""
        if ticket not in self._roles:
            for name in ROLES:
                if ticket in getattr(self, f"{name}_tickets"):
                    self._roles[ticket] = name
                    break
            else:
                u = np.random.default_rng([_SALT, self.seed, ticket]).random()
                edge = 0.0
                self._roles[ticket] = None
                for name, rate in (("poison", self.poison_rate),
                                   ("backend", self.backend_rate),
                                   ("flaky", self.flaky_rate),
                                   ("corrupt", self.corrupt_rate)):
                    edge += rate
                    if u < edge:
                        self._roles[ticket] = name
                        break
        return self._roles[ticket]

    # -- engine hooks --------------------------------------------------------

    def before_launch(self, tickets: tuple, rung_index: int,
                      attempt: int) -> None:
        """Called by the engine immediately before dispatching a launch
        (initial, retry, degraded, or bisected); raises to fail it."""
        for t in tickets:
            r = self.role(t)
            fail = (r == "poison"
                    or (r == "backend" and rung_index == 0)
                    or (r == "flaky" and rung_index == 0
                        and attempt < self.flaky_attempts))
            if fail:
                self.injected_launch_faults += 1
                raise InjectedFault(
                    f"injected {r} fault (ticket {t}, rung {rung_index}, "
                    f"attempt {attempt})")

    def corrupt_staging(self, packed: np.ndarray, tickets: tuple,
                        rung_index: int, attempt: int) -> np.ndarray:
        """Called by the engine while staging a float affine bucket; may
        return a corrupted COPY of the packed operand buffer (the host
        copies in the queue stay pristine -- that is what recovery
        re-packs from)."""
        if rung_index != 0 or attempt != 0:
            return packed
        rows = [i for i, t in enumerate(tickets)
                if self.role(t) == "corrupt"]
        if not rows:
            return packed
        out = np.array(packed, copy=True)
        out[rows, 0, 0] = np.nan
        self.injected_corruptions += len(rows)
        return out


#: malformed-submission modes and how ``submit`` must answer each --
#: (mode, expected error code from the repro.errors taxonomy)
MALFORM_MODES = (("empty", "empty"), ("shape", "shape"),
                 ("float64", "dtype"), ("nan", "nonfinite"))


def malform(points: np.ndarray, mode: str) -> np.ndarray:
    """Turn a valid point set into intake garbage of the given mode."""
    if mode == "empty":
        return np.zeros((0, points.shape[-1]), np.float32)
    if mode == "shape":
        return np.asarray(points)[..., :-1] if points.shape[-1] > 1 \
            else np.repeat(np.asarray(points), 2, axis=-1)
    if mode == "float64":
        return np.asarray(points, dtype=np.float64)
    if mode == "nan":
        bad = np.array(points, copy=True)
        bad.reshape(-1)[0] = np.nan
        return bad
    raise ValueError(f"unknown malform mode {mode!r}")


@dataclasses.dataclass
class ChaosReport:
    """One soak run's full accounting.  Everything except ``elapsed_s``
    (and the rates derived from it) is deterministic for a fixed (seed,
    n_requests, rates, backend) -- the chaos CI lane gates on these
    exact values via tools/check_bench.py."""
    seed: int
    backend: str
    requests: int                  # well-formed submissions
    malformed: int                 # deliberately-garbage submissions
    rejected_at_submit: int        # typed RequestErrors raised at intake
    resolved: int                  # result slots holding verified points
    failed_requests: int           # result slots holding a LaunchError
    lost: int                      # submissions with NO resolution (must be 0)
    mismatches: int                # resolved results that failed the oracle
    faulted_buckets: int           # buckets that needed any recovery
    launches: int
    launch_failures: int
    retries: int
    backend_fallbacks: int
    bisections: int
    recovered_requests: int
    q_fallbacks: int
    injected_launch_faults: int
    injected_corruptions: int
    elapsed_s: float
    #: per-recovery-ladder flight-recorder post-mortems: one entry per
    #: recovery track, each the span/event dicts of that ladder's walk
    #: (deterministic under the soak's auto-installed virtual-clock
    #: tracer) -- a chaos failure in CI is debuggable from the report
    postmortems: list = dataclasses.field(default_factory=list)

    @property
    def recovered_rps(self) -> float:
        """Recovered requests per second of soak wall time."""
        return self.recovered_requests / max(self.elapsed_s, 1e-9)

    def counters(self) -> dict:
        """The deterministic counter subset, name -> value (the shape
        benchmark rows and CI gates consume)."""
        d = dataclasses.asdict(self)
        d.pop("elapsed_s")
        d.pop("backend")
        d.pop("postmortems")
        return d


def _expected_lane(chain: tc.TransformChain, pts: np.ndarray,
                   fmt: quantize.QFormat, cfg: engine.FaultConfig) -> str:
    """Which lane a q-tagged request lands in under the server's
    overflow policy -- the same fits() the engine consults at submit."""
    if cfg.on_q_overflow == "wrap" or not len(chain):
        return "q"
    kind = tc.plan_kind_of(chain.structure)
    return "q" if quantize.fits(chain.fold(), kind, fmt,
                                float(np.abs(pts).max())) else "float"


def _verify_one(chain: tc.TransformChain, pts: np.ndarray,
                qname: str | None, res,
                cfg: engine.FaultConfig) -> bool:
    """One request's oracle check against per-request apply on the ref
    backend: bitwise for the q lane (integer arithmetic is exact),
    tolerance-based for float lanes (packed vs single-request float
    contraction differs in the last ULPs), mask equality + tolerance for
    projective results."""
    if qname is not None:
        fmt = quantize.as_qformat(qname)
        if _expected_lane(chain, pts, fmt, cfg) == "q":
            ref = chain.apply(pts, dtype=qname, backend="ref")
            return np.array_equal(np.asarray(res), np.asarray(ref))
        # q->float fallback: served through the float32 lane
        ref = chain.apply(pts, backend="ref")
        return np.allclose(res, np.asarray(ref), rtol=2e-4, atol=2e-4)
    if chain.is_projective:
        ref, ref_mask = chain.project(pts, backend="ref")
        ok = np.allclose(res, np.asarray(ref), rtol=1e-4, atol=1e-4)
        if getattr(res, "mask", None) is not None:
            ok = ok and np.array_equal(np.asarray(res.mask),
                                       np.asarray(ref_mask))
        return bool(ok)
    ref = chain.apply(pts, backend="ref")
    return np.allclose(res, np.asarray(ref), rtol=2e-4, atol=2e-4)


def run_chaos_soak(seed: int = 0, n_requests: int = 64, *,
                   backend: str = "interpret", q_fraction: float = 0.25,
                   qformat: str = "q8.7", malformed_every: int = 9,
                   flaky_rate: float = 0.06, backend_rate: float = 0.05,
                   corrupt_rate: float = 0.05, poison_rate: float = 0.03,
                   fault_config: engine.FaultConfig | None = None,
                   verify: bool = True) -> ChaosReport:
    """Serve a seeded mixed-lane workload under seeded fault injection
    and account for every request.

    The workload mixes diagonal / matrix / projective structures and the
    float + fixed-point lanes; every ``malformed_every``-th submission is
    deliberately garbage (cycling ``MALFORM_MODES``).  The injector's
    default rates put a fault in roughly 20% of buckets.  ``backend``
    defaults to "interpret" so the degradation ladder has a live rung
    below it ("ref") in every environment, including CPU CI.

    With ``verify=True`` (the default -- benchmarks may disable it to
    time the serving path alone) every resolved result is checked
    against its per-request ``apply`` oracle and every failure slot must
    be a ``LaunchError`` naming its own ticket; ``lost`` counts
    submissions with neither, and the invariant is ``lost == 0``.

    Runs traced: if no tracer is installed, the soak installs its own
    (virtual clock at 0, so recovery post-mortems are a pure function of
    the seed) for the duration and attaches per-ladder flight-recorder
    windows to ``ChaosReport.postmortems``."""
    if not obst.active().enabled:
        tracer = obst.Tracer(clock=VirtualClock(),
                             recorder=obsrec.FlightRecorder(512))
        with obst.installed(tracer):
            return _chaos_soak_traced(
                seed, n_requests, backend=backend, q_fraction=q_fraction,
                qformat=qformat, malformed_every=malformed_every,
                flaky_rate=flaky_rate, backend_rate=backend_rate,
                corrupt_rate=corrupt_rate, poison_rate=poison_rate,
                fault_config=fault_config, verify=verify)
    return _chaos_soak_traced(
        seed, n_requests, backend=backend, q_fraction=q_fraction,
        qformat=qformat, malformed_every=malformed_every,
        flaky_rate=flaky_rate, backend_rate=backend_rate,
        corrupt_rate=corrupt_rate, poison_rate=poison_rate,
        fault_config=fault_config, verify=verify)


def _recovery_postmortems(trc) -> list:
    """Group the trace's recovery-track events into one post-mortem per
    ladder (insertion order = first failure order, so deterministic)."""
    tracks: dict = {}
    for s in trc.spans:
        if s.track is not None and str(s.track).startswith("recovery"):
            tracks.setdefault(s.track, []).append(s.as_dict())
    return [{"track": t, "events": evs} for t, evs in tracks.items()]


def _chaos_soak_traced(seed, n_requests, *, backend, q_fraction, qformat,
                       malformed_every, flaky_rate, backend_rate,
                       corrupt_rate, poison_rate, fault_config, verify):
    cfg = fault_config or engine.FaultConfig()
    srv = engine.GeometryServer(
        backend=backend, fault_config=cfg,
        injector=FaultInjector(seed=seed, flaky_rate=flaky_rate,
                               backend_rate=backend_rate,
                               corrupt_rate=corrupt_rate,
                               poison_rate=poison_rate))
    triples = workload.mixed_lane_workload(seed, n_requests,
                                           q_fraction=q_fraction,
                                           qformat=qformat)
    base = {k: engine.stats[k] for k in engine.stats}
    t0 = time.perf_counter()
    rejected = malformed = 0
    submitted = []                 # (ticket, chain, pts, qname)
    for i, (chain, pts, qname) in enumerate(triples):
        if malformed_every and i % malformed_every == malformed_every - 1:
            mode, _code = MALFORM_MODES[(i // malformed_every)
                                        % len(MALFORM_MODES)]
            malformed += 1
            try:
                srv.submit(chain, malform(pts, mode))
            except RequestError:
                rejected += 1      # the only acceptable outcome
        try:
            ticket = srv.submit(chain, pts, qformat=qname)
        except RequestError:
            # default rates + workload never reject a well-formed
            # request; count it rather than crash if a config does
            rejected += 1
            continue
        submitted.append((ticket, chain, pts, qname))
    if q_fraction > 0:
        # one guaranteed-overflow q request: q8.7 spans [-256, 256), so a
        # x1000 scale must trip the wrap prediction (reject or float32
        # reroute, per policy) -- exercised, and gateable, in every soak
        probe = tc.TransformChain(dim=2).scale(1000.0).translate([1.0, -1.0])
        probe_pts = np.linspace(-1, 1, 16, dtype=np.float32).reshape(8, 2)
        try:
            t = srv.submit(probe, probe_pts, qformat=qformat)
            submitted.append((t, probe, probe_pts, qformat))
        except RequestError:
            rejected += 1          # the "reject" overflow policy
    results = srv.flush()
    elapsed = time.perf_counter() - t0

    by_ticket = {}
    for (ticket, *_), res in zip(submitted, results):
        by_ticket[ticket] = res
    resolved = failed = lost = mismatches = 0
    for ticket, chain, pts, qname in submitted:
        res = by_ticket.get(ticket)
        if isinstance(res, LaunchError):
            failed += 1
            if res.ticket != ticket:
                mismatches += 1    # a mis-addressed error is a lost result
        elif res is None:
            lost += 1
        else:
            resolved += 1
            if verify and not _verify_one(chain, pts, qname, res, cfg):
                mismatches += 1
    lost += len(submitted) - len(results) if len(results) < len(submitted) \
        else 0

    delta = {k: engine.stats[k] - base[k] for k in engine.stats}
    faulted = sum(1 for r in srv.last_report
                  if r.retries or r.bisections or r.backend_fallbacks
                  or r.failed_requests or r.recovered_requests)
    return ChaosReport(
        seed=seed, backend=backend, requests=len(submitted),
        malformed=malformed, rejected_at_submit=rejected,
        resolved=resolved, failed_requests=failed, lost=lost,
        mismatches=mismatches, faulted_buckets=faulted,
        launches=delta["launches"],
        launch_failures=delta["launch_failures"],
        retries=delta["retries"],
        backend_fallbacks=delta["backend_fallbacks"],
        bisections=delta["bisections"],
        recovered_requests=delta["recovered_requests"],
        q_fallbacks=delta["q_fallbacks"],
        injected_launch_faults=srv.injector.injected_launch_faults,
        injected_corruptions=srv.injector.injected_corruptions,
        elapsed_s=elapsed,
        postmortems=_recovery_postmortems(obst.active()))
