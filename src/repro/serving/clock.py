"""Injectable clocks: every timing decision in the serving stack flows
through a ``Clock`` so schedulers are testable as pure functions of time.

Schedulers are where correctness quietly dies: a flush policy that reads
``time.monotonic()`` directly can only be tested statistically, and its
latency telemetry is noise on a loaded CI host.  The continuous-batching
front-end (``serving.async_engine``) therefore never touches the wall
clock -- it asks an injected ``Clock`` instead:

  * ``MonotonicClock`` -- production: ``time.monotonic`` / ``time.sleep``.
  * ``VirtualClock``   -- tests and the seeded soak benchmark: time is a
    number that moves only when the test (or the soak's arrival script)
    says so.  Every scheduling decision, deadline expiry, and latency
    sample becomes a deterministic function of the arrival script, so
    p50/p99 values can be pinned against hand-computed numbers and the
    soak's latency telemetry sits in the exact-match CI gate.

``percentile`` is the shared nearest-rank estimator -- the ONE
definition, so hand-computed test values, engine telemetry, and
benchmark rows cannot disagree about what "p99" means.  It lives in
``repro.obs.metrics`` (the metrics layer's histograms consume it too)
and is re-exported here unchanged for the serving-side callers.
"""
from __future__ import annotations

import abc
import time

from repro.obs.metrics import percentile


class Clock(abc.ABC):
    """The timing interface the serving schedulers consume."""

    @abc.abstractmethod
    def now(self) -> float:
        """Seconds on this clock's timeline (monotone, arbitrary epoch)."""

    @abc.abstractmethod
    def sleep(self, seconds: float) -> None:
        """Block (or virtually advance) for ``seconds`` (clamped >= 0)."""


class MonotonicClock(Clock):
    """Real time: ``time.monotonic`` / ``time.sleep`` (production traffic)."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class VirtualClock(Clock):
    """Deterministic simulated time: ``now`` moves only via ``advance`` /
    ``sleep``.  Never goes backwards; advancing by a negative amount is a
    caller bug and raises rather than silently rewinding history."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance(self, seconds: float) -> float:
        """Move time forward by ``seconds``; returns the new now."""
        if seconds < 0:
            raise ValueError(f"cannot advance a clock by {seconds} s")
        self._now += float(seconds)
        return self._now

    def advance_to(self, t: float) -> float:
        """Move time forward to the absolute instant ``t`` (no-op when
        ``t`` is already in the past: arrival scripts may round)."""
        if t > self._now:
            self._now = float(t)
        return self._now

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            self.advance(seconds)


__all__ = ["Clock", "MonotonicClock", "VirtualClock", "percentile"]
