"""Size-bucketing policy: pad request lengths onto a coarse geometric grid.

Packing heterogeneous requests into one lane-dense batch requires a common
padded length per launch.  Padding every request in a bucket to the bucket
maximum would let one long request blow the padding waste of every short
one, so lengths are instead snapped onto a fixed grid of *allowed* sizes
and the grid size becomes part of the bucket key: requests only share a
launch if they share a padded length.

The grid is power-of-two doubling from ``min_len`` (the paper-faithful
default: frame-buffer sets are power-of-two banks), refined with
intermediate sizes whenever a plain doubling could not honour the waste
cap: consecutive allowed sizes keep a ratio <= 1/(1 - waste_cap), which
bounds per-request padding waste (L - n)/L strictly below ``waste_cap``
for any n >= min_len.  A tighter cap therefore trades a few more distinct
padded lengths (more buckets, more jit shapes) for less padded traffic;
``waste_cap=0.5`` degenerates to pure powers of two.
"""
from __future__ import annotations

import math

from repro.autotune import cache as tuning

MIN_LEN = 8          #: default grid floor (one float32 sublane row of lanes)
WASTE_CAP = 0.5      #: default cap -- pure power-of-two grid


def grid_for(backend: str, *, min_len: int | None = None,
             waste_cap: float | None = None,
             n: int = 0) -> tuple[int, float, str]:
    """Resolve the size grid the serving engine should run: explicit
    arguments win; unset knobs come from the tuning cache when autotuning
    is enabled (kernel ``serving_grid``), else the module defaults.
    ``n`` is the workload's largest request length -- the size-class
    convention grid winners are cached under (grids are tuned per traffic
    scale, so the lookup must say which scale is being served; the engine
    passes its pending queue's maximum at flush time).  Returns
    ``(min_len, waste_cap, source)`` with ``source`` naming where the
    knobs came from: ``explicit`` (both passed), ``default`` / ``cached``
    / ``tuned`` (neither passed), or ``explicit+<that>`` when they mix."""
    if min_len is not None and waste_cap is not None:
        return min_len, waste_cap, "explicit"
    cfg = tuning.config_for("serving_grid", backend, n=n)
    resolved_min = cfg.grid_min_len if cfg.grid_min_len is not None \
        else MIN_LEN
    resolved_cap = cfg.grid_waste_cap if cfg.grid_waste_cap is not None \
        else WASTE_CAP
    source = cfg.source if min_len is None and waste_cap is None \
        else f"explicit+{cfg.source}"
    return (min_len if min_len is not None else resolved_min,
            waste_cap if waste_cap is not None else resolved_cap,
            source)


def padded_length(n: int, *, min_len: int = MIN_LEN,
                  waste_cap: float = WASTE_CAP) -> int:
    """Smallest allowed padded length >= n.

    Guarantees for n >= min_len: result >= n, and padding waste
    (result - n) / result < waste_cap.  Requests shorter than ``min_len``
    pad to the grid floor (the floor, not the cap, bounds their waste).
    """
    if not 0.0 < waste_cap < 1.0:
        raise ValueError(f"waste_cap must be in (0, 1), got {waste_cap}")
    if min_len < 1:
        raise ValueError(f"min_len must be >= 1, got {min_len}")
    ratio = 1.0 / (1.0 - waste_cap)
    size = min_len
    while size < n:
        # next rung: geometric step, but never finer than +1 and never
        # skipping past the power-of-two doubling rung
        size = min(max(size + 1, math.ceil(size * ratio)), 2 * size)
    return size


def waste_fraction(n: int, lpad: int) -> float:
    """Padding waste of serving an n-point request at padded length lpad."""
    return (lpad - n) / lpad if lpad else 0.0
