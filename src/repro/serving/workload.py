"""Synthetic mixed serving workloads (shared by tests, benchmarks, drivers).

A workload draws from a bounded pool of chain *structures* (the thing the
engine buckets by) while every request gets fresh parameter values and a
fresh variable-length point set -- the serving hot path the plan cache was
built for: many requests, few structures.  ``timed`` is the one shared
wall-clock helper, so the benchmark rows and the driver's printed numbers
cannot measure differently.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.transform_chain import TransformChain


def timed(fn) -> float:
    """Seconds for one call of ``fn()``, blocking on every jax leaf in its
    result (non-jax leaves pass through)."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0

#: structure templates: (dim, kind string).  A workload samples a subset,
#: mixing diagonal (TS/A-only), general (R/M), and projective (P/C --
#: graphics viewing pipelines) chains across 2D and 3D.  New templates
#: append at the END so seeded prefixes (``TEMPLATES[:k]``) stay
#: bit-reproducible across PRs.
TEMPLATES: tuple[tuple[int, str], ...] = (
    (2, "TSRT"),          # the paper's translate/scale/rotate composite
    (2, "TST"),           # diagonal: folds to one affine, VPU-only plan
    (2, "R"),             # bare rotation
    (2, "ASM"),           # affine + scale + custom matrix
    (3, "TRS"),           # 3D pipeline (rotation about a random axis)
    (3, "SAT"),           # 3D diagonal
    (3, "RMRT"),          # 3D general with custom matrix
    (2, "TTSS"),          # diagonal, exercises translate/scale folding
    (3, "TSRP"),          # model affines + perspective projection
    (3, "MPC"),           # camera (look-at affine) + projection + cull
    (2, "TSP"),           # 2D projective touch-up
)

#: the affine-only template subset: structures the fixed-point (Qm.n)
#: lane can execute (projective primitives P/C have no q form).  The ONE
#: filter -- the fixed-point benchmark, its tests, and the example all
#: consume this, so a new projective-like template letter cannot leak
#: unquantizable chains into any of them.
AFFINE_TEMPLATES: tuple[tuple[int, str], ...] = tuple(
    t for t in TEMPLATES if not set(t[1]) & {"P", "C"})


def random_projective(rng: np.random.Generator, dim: int) -> np.ndarray:
    """A well-conditioned random (d+1, d+1) projective matrix: a gentle
    perspective column keeps w = 1 + p.c positive for typical workload
    points (outliers get culled by the w > 0 mask, which is itself part
    of what the serving path must reproduce).  The ONE recipe -- served
    traffic (``chain_for``) and the autotuner's timing inputs
    (``autotune.search.tune_chain``) both draw from it, so tuned configs
    are measured on the distribution that is actually served."""
    m = np.eye(dim + 1, dtype=np.float32)
    m[:dim, :dim] += rng.uniform(-0.3, 0.3, (dim, dim))
    m[dim, :dim] = rng.uniform(-1, 1, dim)
    m[:dim, dim] = rng.uniform(-0.05, 0.05, dim)
    return m


def chain_for(rng: np.random.Generator, dim: int, kinds: str) -> TransformChain:
    """A chain with the given structure and fresh random parameters."""
    chain = TransformChain.identity(dim)
    for kind in kinds:
        if kind == "T":
            chain = chain.translate(*rng.uniform(-3, 3, dim).tolist())
        elif kind == "S":
            chain = chain.scale(*rng.uniform(0.2, 2.0, dim).tolist())
        elif kind == "R":
            theta = float(rng.uniform(-np.pi, np.pi))
            chain = chain.rotate(theta) if dim == 2 else \
                chain.rotate(theta, axis=int(rng.integers(3)))
        elif kind == "A":
            chain = chain.affine(rng.uniform(0.2, 2.0, dim).tolist(),
                                 rng.uniform(-2, 2, dim).tolist())
        elif kind == "M":
            m = np.eye(dim + 1, dtype=np.float32)
            m[:dim, :dim] += rng.uniform(-0.4, 0.4, (dim, dim))
            m[dim, :dim] = rng.uniform(-2, 2, dim)
            chain = chain.matrix(m)
        elif kind == "P":
            chain = chain.projective(random_projective(rng, dim))
        elif kind == "C":
            chain = chain.cull(float(rng.uniform(-6, -3)),
                               float(rng.uniform(3, 6)))
        else:
            raise ValueError(f"unknown primitive kind {kind!r}")
    return chain


def random_workload(rng: np.random.Generator | int | None = None,
                    n_requests: int | None = None, *, seed: int | None = None,
                    templates=TEMPLATES, max_points: int = 512,
                    min_points: int = 1, sigma: float = 0.7):
    """``n_requests`` (chain, points) pairs: structures cycle through the
    template pool, parameters are random per request, and point counts are
    lognormal around sqrt(min*max) -- serving traffic concentrates around
    a typical request size rather than spreading uniformly, which is what
    makes size-bucketed packing effective.

    Randomness is seedable end-to-end: pass ``seed=`` (or an int / fresh
    Generator as ``rng``) and every draw -- structure parameters, point
    counts, point coordinates -- comes from that one stream, so two calls
    with the same seed and arguments produce bit-identical request mixes.
    That is what makes tuned-vs-default benchmark comparisons apples to
    apples (``benchmarks/autotune_bench.py`` relies on it)."""
    if n_requests is None:
        raise ValueError("random_workload needs n_requests")
    if rng is None:
        if seed is None:
            raise ValueError("random_workload needs rng= or seed=")
        rng = seed
    elif seed is not None:
        raise ValueError("pass rng= or seed=, not both")
    if not isinstance(rng, np.random.Generator):
        rng = np.random.default_rng(rng)
    median = max(1.0, np.sqrt(max(1, min_points) * max_points))
    requests = []
    for i in range(n_requests):
        dim, kinds = templates[i % len(templates)]
        n = int(np.clip(rng.lognormal(np.log(median), sigma),
                        min_points, max_points))
        pts = rng.standard_normal((n, dim)).astype(np.float32)
        requests.append((chain_for(rng, dim, kinds), pts))
    return requests


def mixed_lane_workload(seed: int, n_requests: int, *,
                        q_fraction: float = 0.25, qformat: str = "q8.7",
                        max_points: int = 256):
    """``n_requests`` (chain, points, qformat-or-None) triples mixing the
    float lane (affine + projective structures) with the fixed-point lane
    (every ~1/q_fraction-th AFFINE request is tagged with ``qformat``) --
    the traffic shape the fault-model soak runs, exercising all three
    plan kinds plus both dtype lanes in one flush.  Seed-deterministic
    end-to-end, same contract as ``random_workload``."""
    rng = np.random.default_rng([0x50AC, seed])
    base = random_workload(rng, n_requests, max_points=max_points)
    out = []
    for chain, pts in base:
        use_q = (not chain.is_projective) and q_fraction > 0 \
            and rng.random() < q_fraction
        out.append((chain, pts, qformat if use_q else None))
    return out
