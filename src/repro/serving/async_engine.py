"""Continuous-batching async front-end over the plan-bucketed GeometryServer.

The synchronous engine answers "how do N pending requests execute in the
fewest launches"; this module answers the production question above it:
requests ARRIVE on a timeline, and the server must decide WHEN each
plan bucket launches -- too eager and the launch economy collapses back
to per-request dispatch, too patient and tail latency blows through the
SLO.  The design is the continuous-batching loop of production LLM
servers, mapped onto this repo's substrate:

  1. **Admit** -- ``submit_async`` runs the admission gates
     (``serving.admission``: bounded queue depth, per-tenant fair share,
     per-tenant token buckets) and then the SAME validation boundary as
     the synchronous ``submit`` (``GeometryServer.validate`` -- one
     ticket sequence, one taxonomy).  Admitted requests return an
     awaitable ``Ticket`` immediately; rejected ones raise a typed
     ``RequestError`` subclass with a stable code.
  2. **Schedule** -- admitted entries wait in per-bucket groups (keyed
     exactly like the engine's plan buckets: structure + backend +
     dtype/format + padded size class).  The flush policy couples the
     max-wait deadline to the bucket fill fraction:

         due  <=>  fill >= 1  or  age >= max_wait_s * (1 - fill)

     a full bucket launches immediately, an empty-ish one waits out the
     deadline, and everything in between interpolates -- the fuller a
     bucket, the less reason to keep its requests waiting.
  3. **Launch** -- ``poll`` hands every due group to the inner
     ``GeometryServer`` (deadline order: the group whose oldest request
     has waited longest flushes first) and resolves tickets with the
     flush results -- including typed ``LaunchError`` resolutions from
     the PR 6 recovery ladder, which runs unchanged under this front-end
     (the zero-lost-requests invariant is re-asserted through the async
     path by ``tests/test_async_serving.py`` and the soak benchmark).

**All timing flows through the injectable ``serving.clock.Clock``** --
the engine never reads a wall clock.  Under a ``VirtualClock`` every
scheduling decision, deadline expiry, latency sample, and admission
refill is a deterministic function of the arrival script, which is what
makes the scheduler *testable*: ``tests/test_clock.py`` pins flush
ordering and p50/p99 values against hand-computed numbers, and the soak
benchmark's latency telemetry sits in the exact-match CI gate.  Under
the default ``MonotonicClock`` the same code serves real traffic.

Sync/async equivalence contract (``tests/test_async_serving.py``): the
same seeded workload submitted while the clock is frozen and then
``drain``ed produces bitwise-identical per-ticket results and identical
launch/byte counters to one synchronous ``flush`` -- the front-end only
decides WHEN groups launch, never changes WHAT a launch computes, and a
drain schedules exactly the synchronous bucket composition.
"""
from __future__ import annotations

import dataclasses
import typing

from repro.kernels import dispatch
from repro.obs import metrics as obsm
from repro.obs import trace as obst
from repro.serving import engine
from repro.serving.admission import AdmissionConfig, AdmissionController
from repro.serving.clock import Clock, MonotonicClock

_UNSET = object()

#: deadline residuals below a nanosecond snap to "due now": float64
#: rounding in ``max_wait * (1 - fill) - age`` can leave a remainder
#: smaller than the clock value's own ulp, which a VirtualClock advance
#: cannot consume -- without the snap, poll/advance livelocks on it
_DUE_EPS = 1e-9


class Ticket:
    """An admitted request's handle: resolves to the transformed points
    (or a typed error object, mirroring the synchronous ``flush`` result
    slots) when the flush policy launches its bucket.

    Awaitable: ``await ticket`` inside a coroutine driven by
    ``AsyncGeometryServer.run`` suspends until resolution.  The await
    protocol is the plain generator one (it yields the pending ticket to
    the driving trampoline), deliberately independent of any asyncio
    event loop -- determinism under a ``VirtualClock`` requires the
    engine, not a wall-clock-driven loop, to decide when time moves."""

    __slots__ = ("id", "tenant", "submitted_at", "resolved_at", "_value")

    def __init__(self, ticket_id: int, tenant: str, submitted_at: float):
        self.id = ticket_id
        self.tenant = tenant
        self.submitted_at = submitted_at
        self.resolved_at: float | None = None
        self._value = _UNSET

    def done(self) -> bool:
        """Whether the ticket has resolved (value or typed error)."""
        return self._value is not _UNSET

    def result(self):
        """The resolved value: transformed points, or the typed error
        object the request resolved to (check with ``serving.is_error``,
        exactly as for synchronous ``flush`` slots)."""
        if self._value is _UNSET:
            raise RuntimeError(
                f"ticket {self.id} is still pending; drive the engine "
                "(poll/drain/gather/run) before reading results")
        return self._value

    @property
    def latency(self) -> float | None:
        """Clock seconds from admission to resolution (None if pending)."""
        if self.resolved_at is None:
            return None
        return self.resolved_at - self.submitted_at

    def _resolve(self, value, now: float) -> None:
        self._value = value
        self.resolved_at = now

    def __await__(self):
        while not self.done():
            yield self
        return self._value

    def __repr__(self):
        state = "pending" if not self.done() else \
            type(self._value).__name__
        return (f"Ticket(id={self.id}, tenant={self.tenant!r}, "
                f"{state})")


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """The flush policy's latency/throughput trade, per engine.

    ``max_wait_s`` is the scheduling-latency SLO knob: the longest any
    admitted request may wait before its bucket launches, even alone.
    ``target_rows`` defines a "full" bucket (the batch size the launch
    economy is tuned for); the effective deadline of a bucket at fill
    fraction f is ``max_wait_s * (1 - f)``, so deadline and fill are one
    coupled policy, not two racing timers."""
    max_wait_s: float = 0.005
    target_rows: int = 32

    def __post_init__(self):
        if self.max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {self.max_wait_s}")
        if self.target_rows < 1:
            raise ValueError(f"target_rows must be >= 1, got "
                             f"{self.target_rows}")


@dataclasses.dataclass
class _Waiting:
    """One admitted request parked in a flush-policy group."""
    pending: engine._Pending
    ticket: Ticket
    tenant: str
    arrival: float


@dataclasses.dataclass
class _Group:
    """Requests destined for one plan bucket, waiting to launch."""
    key: tuple
    entries: list[_Waiting] = dataclasses.field(default_factory=list)

    @property
    def oldest_arrival(self) -> float:
        """Arrival time of the head entry (appends are in arrival order)."""
        return self.entries[0].arrival

    def due_in(self, now: float, slo: SLOConfig) -> float:
        """Clock seconds until this group's coupled deadline fires
        (0 = due now).  Identity groups are always due -- there is no
        launch to amortise, so there is nothing to wait for."""
        if self.key[0] == "identity":
            return 0.0
        fill = min(1.0, len(self.entries) / slo.target_rows)
        if fill >= 1.0:
            return 0.0
        age = now - self.oldest_arrival
        rem = slo.max_wait_s * (1.0 - fill) - age
        return rem if rem > _DUE_EPS else 0.0


class AsyncGeometryServer:
    """Continuous-batching front-end: async submission, admission
    control, and a clock-driven flush policy over a ``GeometryServer``.

        clock = VirtualClock()            # or MonotonicClock() in prod
        srv = AsyncGeometryServer(backend="ref", clock=clock)
        t = srv.submit_async(chain, pts, tenant="render")
        ...
        srv.poll()        # launch whatever the policy says is due
        t.result()        # after resolution

    Driving: call ``poll`` from a serving loop at whatever cadence the
    deployment has (each call launches exactly the due groups),
    ``drain`` to launch everything (shutdown, and the sync-equivalence
    path), ``gather(tickets)`` to drive until specific tickets resolve,
    or ``run(*coros)`` to trampoline request-stream coroutines that
    ``await`` tickets.  Per-request fault tolerance is inherited
    unchanged from the inner engine: a ticket resolves to points or to a
    typed error, never silence."""

    def __init__(self, *, backend: str | None = None,
                 clock: Clock | None = None,
                 slo: SLOConfig | None = None,
                 admission: AdmissionConfig | None = None,
                 slo_monitor=None,
                 **server_kw):
        self.clock = clock if clock is not None else MonotonicClock()
        self.slo = slo or SLOConfig()
        #: optional ``obs.slo.SLOMonitor`` (any duck with
        #: observe_latency / observe_admission / observe_rejection):
        #: fed at the admission gate and at every resolution, so its
        #: burn-rate arithmetic sees exactly the events the engine's
        #: own telemetry counts.  None (the default) costs one branch
        #: per event -- monitoring, like tracing, is opt-in and must
        #: never steer the serving counters.
        self.slo_monitor = slo_monitor
        self._server = engine.GeometryServer(backend=backend, **server_kw)
        self._admission = AdmissionController(
            admission or AdmissionConfig(), self.clock)
        self._groups: dict[tuple, _Group] = {}   # insertion = first arrival
        # telemetry (per engine; deterministic under a VirtualClock):
        # registry-backed -- the ``stats`` property is a back-compat view
        # over these instruments
        self.metrics = obsm.MetricsRegistry("async")
        self._h_latency = self.metrics.histogram(
            "request_latency_s", help="admission-to-resolution seconds")
        self._c_resolved = self.metrics.counter("resolved")
        self._c_failed = self.metrics.counter("failed")
        self._g_depth = self.metrics.gauge("max_queue_depth_seen")
        self._first_arrival: float | None = None
        self._last_resolution: float | None = None
        # last-mirrored admission totals: the module aggregate is bumped
        # by DELTAS so several engines never clobber each other's counts
        self._mirrored = {"queue_full_rejections": 0,
                          "rate_limit_rejections": 0}

    # -- intake --------------------------------------------------------------

    @property
    def server(self) -> engine.GeometryServer:
        """The inner synchronous engine (reports, fault config, injector)."""
        return self._server

    @property
    def queue_depth(self) -> int:
        """Requests currently queued behind admission control."""
        return self._admission.depth

    def submit_async(self, chain, points, *, tenant: str = "default",
                     qformat=None, fold=None) -> Ticket:
        """Admit + validate one request; returns its awaitable ticket.

        Gate order: admission first (backpressure must shed load BEFORE
        paying per-request validation cost), then the shared validation
        boundary.  Raises the typed taxonomy either way --
        ``QueueFullError`` / ``RateLimitError`` with stable codes for
        backpressure, the intake family for malformed payloads -- so a
        caller's error handling is one ``except RequestError``.

        ``fold`` forwards precomputed folded parameters to the engine's
        validation boundary (see ``GeometryServer.validate``): the
        scene path uses it to serve a cached world fold, and the
        injected value must be bit-identical to ``chain.fold()`` so the
        sync/async equivalence contract is untouched."""
        trc = obst.active()
        sid = trc.begin("request.submit", tenant=tenant) \
            if trc.enabled else None
        try:
            self._admission.admit(tenant)    # raises typed rejection
        except BaseException as e:
            self._mirror_admission_stats()
            if self.slo_monitor is not None:
                self.slo_monitor.observe_rejection()
            if sid is not None:
                trc.end(sid, outcome="rejected",
                        gate="admission",
                        code=getattr(e, "code", type(e).__name__))
            raise
        try:
            p = self._server.validate(chain, points, qformat=qformat,
                                      fold=fold)
        except BaseException as e:
            # never queued: the slot (but not the spent rate token --
            # the tenant did submit) goes back
            self._admission.unadmit(tenant)
            if sid is not None:
                trc.end(sid, outcome="rejected", gate="validate",
                        code=getattr(e, "code", type(e).__name__))
            raise
        finally:
            self._mirror_admission_stats()
        now = self.clock.now()
        ticket = Ticket(p.ticket, tenant, now)
        key = self._group_key(p)
        group = self._groups.get(key)
        if group is None:
            group = self._groups[key] = _Group(key)
        group.entries.append(_Waiting(p, ticket, tenant, now))
        if self._first_arrival is None:
            self._first_arrival = now
        self._g_depth.track_max(self.queue_depth)
        if self.slo_monitor is not None:
            self.slo_monitor.observe_admission()
        self._server._bump("admitted_requests")
        self.metrics.counter("tenant_requests", labels=("tenant",)) \
            .labels(tenant=tenant).inc()
        if sid is not None:
            trc.end(sid, ticket=p.ticket, outcome="admitted")
        return ticket

    def submit_scene_async(self, scene, name: str, points, *,
                           tenant: str = "default", qformat=None) -> Ticket:
        """Scene-aware ``submit_async``: the request's chain is the
        node's world chain and its fold comes from the scene's shared
        ``FoldCache`` (``SceneGraph.world_fold``), so a burst of
        requests under one prefix folds it once.  Admission, grouping,
        the flush policy and the sync/async bitwise-equivalence
        contract are all the ordinary ``submit_async`` path -- the
        cached fold is bit-identical to ``chain.fold()`` by
        construction (``GeometryServer.submit_scene`` documents the
        equality chain)."""
        chain = scene.world_chain(name)
        fold = scene.world_fold(name) if len(chain) else None
        return self.submit_async(chain, points, tenant=tenant,
                                 qformat=qformat, fold=fold)

    def _group_key(self, p: engine._Pending) -> tuple:
        """The flush-policy grouping key: the engine's own bucket key,
        so policy groups land 1:1 on plan buckets (an identity chain has
        no bucket -- flush passes it through -- and gets its own
        always-due group)."""
        if len(p.chain) == 0:
            return ("identity", p.chain.dim)
        return self._server._bucket_key(
            p, dispatch.resolve(self._server.backend))

    def _mirror_admission_stats(self) -> None:
        """Mirror the controller's rejection counters into the module
        ``serving.stats`` aggregate and this engine's registry by DELTA.
        The old absolute-assignment mirror silently clobbered the
        aggregate when two engines served side by side (last writer
        wins); deltas compose, so the module view is now the true sum
        across engines."""
        ctrl = self._admission
        for name, total in (
                ("queue_full_rejections", ctrl.queue_full_rejections),
                ("rate_limit_rejections", ctrl.rate_limit_rejections)):
            delta = total - self._mirrored[name]
            if delta:
                self._mirrored[name] = total
                self._server._bump(name, delta)

    # -- scheduling ----------------------------------------------------------

    def next_due_in(self) -> float | None:
        """Clock seconds until the earliest group deadline fires (0 =
        something is due now; None = nothing is waiting).  ``gather``
        and the soak driver advance a virtual clock by exactly this."""
        if not self._groups:
            return None
        now = self.clock.now()
        return min(g.due_in(now, self.slo) for g in self._groups.values())

    def poll(self) -> int:
        """Launch every group whose coupled deadline has fired, oldest
        deadline first; returns the number of requests resolved.  One
        inner flush serves all due groups (each is its own plan bucket,
        so deadline order is bucket launch order)."""
        now = self.clock.now()
        due = [g for g in self._groups.values()
               if g.due_in(now, self.slo) <= 0.0]
        due.sort(key=lambda g: g.oldest_arrival)
        trc = obst.active()
        if trc.enabled:
            for g in due:
                # why this group launches NOW: the fill-vs-deadline
                # decision the flush policy just made
                if g.key[0] == "identity":
                    reason = "identity"
                elif len(g.entries) >= self.slo.target_rows:
                    reason = "fill"
                else:
                    reason = "deadline"
                trc.instant("policy.launch", reason=reason,
                            rows=len(g.entries),
                            age=now - g.oldest_arrival,
                            tickets=tuple(e.pending.ticket
                                          for e in g.entries))
        return self._flush_groups(due)

    def drain(self) -> int:
        """Launch EVERYTHING waiting, deadlines notwithstanding
        (shutdown, and the sync-equivalence path): entries are enqueued
        in ticket order -- exactly the order one synchronous flush of
        the same submissions would see -- so a drain reproduces the
        synchronous bucket composition bit for bit."""
        entries = sorted((e for g in self._groups.values()
                          for e in g.entries),
                         key=lambda e: e.pending.ticket)
        trc = obst.active()
        if trc.enabled and entries:
            trc.instant("policy.drain", groups=len(self._groups),
                        rows=len(entries))
        self._groups.clear()
        return self._flush_entries(entries)

    def _flush_groups(self, groups: list[_Group]) -> int:
        entries = [e for g in groups for e in g.entries]
        for g in groups:
            self._groups.pop(g.key, None)
        return self._flush_entries(entries)

    def _flush_entries(self, entries: list[_Waiting]) -> int:
        if not entries:
            return 0
        trc = obst.active()
        launch_at = self.clock.now()
        if trc.enabled:
            # retroactive: each entry's time parked in the policy queue,
            # closed at the instant its bucket was handed to the engine
            for e in entries:
                trc.complete("queue.wait", e.arrival, launch_at,
                             ticket=e.pending.ticket, tenant=e.tenant)
        for e in entries:
            self._server.enqueue(e.pending)
        results = self._server.flush()
        done = self.clock.now()   # monotonic: includes execution time
        for e, res in zip(entries, results):
            e.ticket._resolve(res, done)
            self._admission.release(e.tenant)
            self._h_latency.observe(done - e.arrival)
            if self.slo_monitor is not None:
                self.slo_monitor.observe_latency(done - e.arrival)
            if engine.serrors.is_error(res):
                self._c_failed.inc()
            else:
                self._c_resolved.inc()
        self._last_resolution = done
        return len(entries)

    # -- drivers -------------------------------------------------------------

    def gather(self, tickets: typing.Sequence[Ticket],
               max_steps: int = 1_000_000) -> list:
        """Drive the engine (poll, then advance/sleep to the next
        deadline) until every ticket resolves; returns their results in
        order.  Deterministic under a ``VirtualClock`` -- the clock
        jumps from deadline to deadline, never by an arbitrary tick."""
        for _ in range(max_steps):
            if all(t.done() for t in tickets):
                return [t.result() for t in tickets]
            if self.poll() == 0:
                nd = self.next_due_in()
                if nd is None:
                    raise RuntimeError(
                        "pending tickets but nothing queued: tickets from "
                        "another engine?")
                self.clock.sleep(nd)
        raise RuntimeError(f"gather did not converge in {max_steps} steps")

    def run(self, *coros, max_steps: int = 1_000_000) -> list:
        """Trampoline request-stream coroutines that ``await`` tickets:
        each round steps every live coroutine once, then -- when all of
        them are parked on pending tickets -- polls, advancing the clock
        to the next deadline when nothing is due.  Returns each
        coroutine's return value, in argument order.  This is the async
        consumption shape (``t = srv.submit_async(...); r = await t``)
        without an asyncio loop: the ENGINE owns time, which is what
        keeps a VirtualClock run bit-reproducible."""
        results: list = [None] * len(coros)
        live = {i: c for i, c in enumerate(coros)}
        for _ in range(max_steps):
            if not live:
                return results
            parked = True
            for i, coro in list(live.items()):
                try:
                    waiting_on = coro.send(None)
                except StopIteration as stop:
                    results[i] = stop.value
                    del live[i]
                    parked = False
                else:
                    if not (isinstance(waiting_on, Ticket)
                            and not waiting_on.done()):
                        parked = False   # progressed past an await
            if parked and live:
                if self.poll() == 0:
                    nd = self.next_due_in()
                    if nd is None:
                        raise RuntimeError(
                            "coroutines parked on tickets but nothing is "
                            "queued: awaiting tickets from another engine?")
                    self.clock.sleep(nd)
        raise RuntimeError(f"run did not converge in {max_steps} steps")

    # -- telemetry -----------------------------------------------------------

    @property
    def stats(self) -> dict:
        """This engine's serving telemetry (all values deterministic
        under a ``VirtualClock``): admission counters, queue depth,
        nearest-rank p50/p99 scheduling latency, and sustained
        requests/s over the clock span from first arrival to last
        resolution.  Module-wide launch counters stay in
        ``serving.stats``; this dict is PER ENGINE."""
        ctrl = self._admission
        elapsed = 0.0
        if self._first_arrival is not None \
                and self._last_resolution is not None:
            elapsed = self._last_resolution - self._first_arrival
        h = self._h_latency
        settled = self._c_resolved.value + self._c_failed.value
        return {
            "admitted": ctrl.admitted,
            "queue_full_rejections": ctrl.queue_full_rejections,
            "rate_limit_rejections": ctrl.rate_limit_rejections,
            "queue_depth": ctrl.depth,
            "max_queue_depth_seen": int(self._g_depth.value),
            "waiting_groups": len(self._groups),
            "resolved": self._c_resolved.value,
            "failed": self._c_failed.value,
            "p50_latency_s": h.percentile(50) if h.count else 0.0,
            "p99_latency_s": h.percentile(99) if h.count else 0.0,
            "max_latency_s": h.max if h.count else 0.0,
            "sustained_rps": settled / elapsed if elapsed > 0 else 0.0,
        }
