"""Fixed-point (Qm.n) execution lane: formats, converters, error bounds.

The M1-faithful int16 lane in three layers:

  * ``qformat``  -- ``QFormat`` descriptors ("q8.7"), saturating
    float->int16 quantisers (host numpy + traced jnp twins, one rounding
    story), and the single requantising shift;
  * ``chains``   -- folded-chain quantisation (``quantize_fold``: the one
    place float32 folds become Qm.n words) and the per-chain error-bound
    model generalising the Q7 rotation bound;
  * execution    -- ``repro.kernels.fixedpoint`` (int32-accumulate Pallas
    kernels + the numpy Q oracle), reached through
    ``TransformChain.apply(..., dtype="q8.7")`` and
    ``GeometryServer.submit(..., qformat="q8.7")``.
"""
from repro.quantize.chains import (QUANTIZABLE_KINDS, ensure_fits,
                                   error_bound, fits, points_need_quantize,
                                   quantize_fold, reject_projective)
from repro.quantize.qformat import (Q8_7, Q15_0, QFormat, as_qformat,
                                    is_qformat)

__all__ = [
    "QFormat", "Q8_7", "Q15_0", "as_qformat", "is_qformat",
    "quantize_fold", "error_bound", "fits", "ensure_fits",
    "QUANTIZABLE_KINDS", "points_need_quantize", "reject_projective",
]
