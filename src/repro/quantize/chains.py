"""Folded-chain quantisation + the per-chain quantisation error bound.

The chain compiler folds in float32 (one shared host fold -- see
``core.transform_chain``), and THIS module is where a folded parameter
set crosses into the fixed-point lane: ``quantize_fold`` turns the
float32 ``(s, t)`` / ``(A, t)`` into int16 Qm.n words once per request,
and ``error_bound`` predicts how far the lane's int16 result may sit
from the exact float chain -- the generalisation of the Q7 rotation
bound in ``tests/test_morphosys.py`` (0.5 * (|x| + |y|) / 127: that is
exactly this bound's matrix form at d = 2, n = 7, unit rotation rows).

Derivation (matrix plan; diag is the 1-term special case).  Writing
``e = 2**-(n+1)`` (a half ulp -- the worst case of round-to-nearest for
inputs and parameters, and of the add-then-shift requantise), hatted
values for dequantised quantities, and ``x_max`` for a bound on |x_m|:

    y_c      = sum_m x_m A[m, c] + t_c                 (exact)
    z_c      = requant(sum_m x^_m A^[m, c] + t^_c)     (the lane; the
                                                        int32 MAC is exact)
    |z_c - y_c| <= sum_m (|A^[m, c]| |x^_m - x_m| + |x_m| |A^[m, c] - A[m, c]|)
                   + |t^_c - t_c| + e_requant
                <= e * (sum_m |A^[m, c]| + d * x_max + 2)

valid whenever nothing wraps: every intermediate magnitude must stay
inside the format (``fits`` checks that, with the same e inflation).
Wrap-around is the M1's semantics, not an error -- but a wrapped result
is outside this bound's contract, exactly as the emulator's is.
"""
from __future__ import annotations

import numpy as np

from repro.errors import QRangeError
from repro.quantize.qformat import QFormat, as_qformat

#: plan kinds the fixed-point lane executes.  Projective plans are
#: EXCLUDED by design: the in-kernel perspective divide has no
#: single-shift Qm.n form (w varies per point), so projective chains
#: stay on the float lane and ``TransformChain`` rejects them loudly.
QUANTIZABLE_KINDS = ("diag", "matrix")


def reject_projective(is_projective: bool) -> None:
    """The ONE spelling of the lane's affine-only intake rule, raised by
    every entry that accepts a chain + fixed-point format
    (``TransformChain.apply``/``project`` via ``_apply_q``,
    ``GeometryServer.submit``): projective plans keep the in-kernel
    perspective divide in float32 (no single-shift Qm.n form exists --
    w varies per point)."""
    if is_projective:
        raise ValueError(
            "projective chains have no fixed-point lane: the in-kernel "
            "perspective divide stays float32 (drop the fixed-point "
            "format, or split the affine prefix into its own chain)")


def points_need_quantize(dtype) -> bool:
    """The ONE point-dtype intake rule of the lane: True for float
    dtypes (quantise at the boundary, dequantise on the way out), False
    for int16 (already Qm.n words, returned as words); anything else
    raises."""
    dt = np.dtype(dtype)
    if np.issubdtype(dt, np.floating):
        return True
    if dt == np.int16:
        return False
    raise TypeError(f"fixed-point points must be float (to be quantised) "
                    f"or int16 Qm.n words, got {dt}")


def quantize_fold(folded: tuple, kind: str, fmt) -> tuple[np.ndarray, ...]:
    """Quantise one host-folded parameter set to int16 Qm.n words:
    ``(s_q, t_q)`` for a diag plan, ``(A_q, t_q)`` for a matrix plan --
    the exact arrays the ``chain_*_q`` kernels stage.  One code path for
    ``TransformChain.apply`` and the serving engine's bucket packing, so
    a request quantises to bit-identical words however it is dispatched.
    """
    fmt = as_qformat(fmt)
    if kind not in QUANTIZABLE_KINDS:
        raise ValueError(
            f"the fixed-point lane is affine-only: cannot quantise a "
            f"{kind!r} plan (projective chains keep the in-kernel divide "
            "in float32)")
    return tuple(fmt.quantize(part) for part in folded)


def _abs_dequant(fmt: QFormat, q: np.ndarray) -> np.ndarray:
    return np.abs(fmt.dequantize(q)).astype(np.float64)


def error_bound(folded: tuple, kind: str, fmt, x_max: float) -> np.ndarray:
    """Per-output-coordinate bound on |lane result - exact float chain|
    for inputs with |x_m| <= x_max, as a (d,) float64 array.  Contract:
    holds whenever ``fits(...)`` is True (no wrap anywhere); asserted
    property-style over random chains by ``tests/test_fixedpoint.py``.
    """
    fmt = as_qformat(fmt)
    half_ulp = fmt.eps / 2.0
    quant = quantize_fold(folded, kind, fmt)
    if kind == "diag":
        s_hat = _abs_dequant(fmt, quant[0])
        return half_ulp * (s_hat + x_max + 2.0)
    a_hat = _abs_dequant(fmt, quant[0])
    d = a_hat.shape[0]
    return half_ulp * (a_hat.sum(axis=0) + d * x_max + 2.0)


def fits(folded: tuple, kind: str, fmt, x_max: float) -> bool:
    """True when the lane cannot wrap for inputs with |x_m| <= x_max:
    parameters and inputs are representable, every output coordinate
    (inflated by its error bound) stays inside the format, and the int32
    accumulator has headroom.  The bound contract of ``error_bound``
    only applies under this predicate -- the M1 datapath wraps silently
    beyond it."""
    fmt = as_qformat(fmt)
    if kind not in QUANTIZABLE_KINDS:
        return False
    if x_max > fmt.hi:
        return False
    parts = [np.asarray(p, np.float64) for p in folded]
    if any(np.abs(p).max(initial=0.0) > fmt.hi for p in parts):
        return False
    if kind == "diag":
        s, t = parts
        out_max = np.abs(s) * x_max + np.abs(t)
        acc_terms = out_max
    else:
        a, t = parts
        out_max = np.abs(a).sum(axis=0) * x_max + np.abs(t)
        acc_terms = out_max
    bound = error_bound(folded, kind, fmt, x_max)
    if np.any(out_max + bound > fmt.hi):
        return False
    # int32 accumulator: values carry scale 2**2n pre-shift
    return bool(np.all((acc_terms + bound) * fmt.scale * fmt.scale
                       < 2.0 ** 31))


def ensure_fits(folded: tuple, kind: str, fmt, x_max: float, *,
                ticket: int | None = None) -> None:
    """Raise a typed ``repro.errors.QRangeError`` when ``fits`` is False
    -- the reject arm of the serving engine's configurable
    reject-or-fallback wrap policy (``FaultConfig.on_q_overflow``).  The
    M1 datapath would wrap silently past this point; the serving
    boundary refuses to return wrapped words as if they were results."""
    fmt = as_qformat(fmt)
    if not fits(folded, kind, fmt, x_max):
        raise QRangeError(
            f"fixed-point format {fmt.name} would wrap for this chain at "
            f"|x| <= {float(x_max):.6g} (range bound exceeds "
            f"{fmt.hi:.6g} or the int32 accumulator): submit on the "
            "float32 lane, pick a wider-integer format, or enable the "
            "on_q_overflow='fallback' policy", ticket=ticket)
