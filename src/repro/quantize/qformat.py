"""Qm.n fixed-point format descriptors + float<->fixed converters.

The MorphoSys M1 prototype's RC-array ALUs are 16-bit signed integer
units (paper section 3), and the graphics companion paper runs its
viewing pipelines in fixed point.  ``QFormat`` is that numeric contract
as data: a signed 16-bit word interpreted as ``Qm.n`` -- 1 sign bit,
``m`` integer bits, ``n`` fraction bits (m + n = 15), representing
``word / 2**n``.

Conversion discipline (shared by every consumer -- the host quantizers
here, the numpy Q oracle, and the fixed-point kernels -- so the lane has
ONE rounding story):

  * float -> fixed: round-half-to-even (``np.rint`` / ``jnp.round``, the
    IEEE default -- host and traced quantisation agree bit-for-bit),
    then SATURATE to the int16 range.  Saturation happens only at the
    boundary into the lane; it is the converter's job, not the ALU's.
  * fixed arithmetic: int32-accumulate multiply-adds, one requantising
    shift ``(acc + 2**(n-1)) >> n`` (round half toward +inf -- the
    cheap add-then-arithmetic-shift hardware idiom), then WRAP to int16
    -- the M1 ALU's wrap-around semantics (``core.morphosys.rc_array``
    wraps, it never saturates).  At n = 0 the shift vanishes and the
    lane is bit-for-bit the emulator's integer datapath.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

WORD_BITS = 16          #: the M1 RC-array ALU width
_NAME_RE = re.compile(r"^q(\d+)\.(\d+)$")


@dataclasses.dataclass(frozen=True)
class QFormat:
    """A signed 16-bit Qm.n fixed-point format (1 sign + m int + n frac).

    ``name`` ("q8.7") is the canonical spelling used everywhere a format
    travels as a string: ``TransformChain.apply(dtype=...)``, serving
    bucket keys, and autotune cache keys.
    """
    m: int                         # integer bits
    n: int                         # fraction bits

    def __post_init__(self):
        if self.m < 0 or self.n < 0 or self.m + self.n != WORD_BITS - 1:
            raise ValueError(
                f"Qm.n must satisfy m + n = {WORD_BITS - 1} with m, n >= 0 "
                f"(16-bit signed word); got q{self.m}.{self.n}")

    @property
    def name(self) -> str:
        return f"q{self.m}.{self.n}"

    @property
    def scale(self) -> int:
        """Values represent ``word / scale``."""
        return 1 << self.n

    @property
    def lo(self) -> float:
        """Smallest representable value (-2**m)."""
        return float(-(1 << self.m))

    @property
    def hi(self) -> float:
        """Largest representable value (2**m - 2**-n)."""
        return float((1 << self.m)) - self.eps

    @property
    def eps(self) -> float:
        """One unit in the last place: 2**-n."""
        return 1.0 / self.scale

    # -- converters ----------------------------------------------------------

    def quantize(self, x) -> np.ndarray:
        """float -> int16 words: round-half-to-even, saturating.  The
        scaling multiply runs in float32 so this host quantiser and the
        traced ``quantize_jnp`` twin agree BIT-FOR-BIT (a float64
        intermediate could resolve a tie the float32 path rounds away)."""
        w = np.rint(np.asarray(x, np.float32) * np.float32(self.scale))
        return np.clip(w, -(1 << 15), (1 << 15) - 1).astype(np.int16)

    def dequantize(self, w) -> np.ndarray:
        """int16 words -> float32 values (exact: 21-bit significands)."""
        return (np.asarray(w).astype(np.float32) / np.float32(self.scale)
                ).astype(np.float32)

    def quantize_jnp(self, x):
        """The traced twin of ``quantize`` (same float32 multiply, same
        half-to-even rounding -- bit-identical), for device-resident or
        traced points; this is what ``TransformChain``'s q lane runs."""
        import jax.numpy as jnp
        w = jnp.round(jnp.asarray(x, jnp.float32) * jnp.float32(self.scale))
        return jnp.clip(w, -(1 << 15), (1 << 15) - 1).astype(jnp.int16)

    def dequantize_jnp(self, w):
        import jax.numpy as jnp
        return jnp.asarray(w, jnp.float32) / jnp.float32(self.scale)

def as_qformat(fmt) -> QFormat:
    """Coerce a format spec -- a ``QFormat`` or a name like "q8.7" -- to a
    ``QFormat``; raises ValueError for anything else (including float
    dtype names, which belong on the default float lane)."""
    if isinstance(fmt, QFormat):
        return fmt
    if isinstance(fmt, str):
        match = _NAME_RE.match(fmt)
        if match:
            return QFormat(int(match.group(1)), int(match.group(2)))
    raise ValueError(
        f"not a fixed-point format: {fmt!r} (expected 'qM.N' with "
        f"M + N = {WORD_BITS - 1}, e.g. 'q8.7', or a QFormat)")


def is_qformat(fmt) -> bool:
    """True if ``fmt`` names a Qm.n format this lane can execute."""
    try:
        as_qformat(fmt)
        return True
    except ValueError:
        return False


#: the lane's house format: q8.7 covers the workload range (|x| < 256)
#: at 2**-7 ~ 0.008 resolution, and its Q7 coefficients are the paper's
#: Q7 rotation immediates (the 8-bit context-word field, |coef| <= 127).
Q8_7 = QFormat(8, 7)
#: the integer instantiation: no shift, bit-for-bit the M1 emulator.
Q15_0 = QFormat(15, 0)
