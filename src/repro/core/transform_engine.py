"""Transform engine -- the paper's "graphics acceleration library" in JAX.

Section 7 of the paper: "The discussed findings are part of a complete
graphics acceleration library using the M1 reconfigurable system."  This
module is that library re-expressed for TPU: the three primitive classes
(vector-vector, vector-scalar, matrix) as composable JAX transforms, each
dispatched to the corresponding Pallas kernel on TPU (ref oracle on CPU).

Points are row vectors: (..., 2) in 2D, (..., 3) in 3D, and every
transform right-multiplies (q = p @ M), so chaining builder calls in
reading order is exactly the paper's "General Composite Algorithm using
Matrix Algorithm" -- without ever materialising homogeneous coordinates:
the composed matrix exists only as folded (A, t) plan parameters, and the
homogeneous (d+1, d+1) form is built on demand by ``.matrix``.

Composite transforms
--------------------
Composites are compiled, not interpreted: ``Transform2D``/``Transform3D``
are thin builders over :class:`repro.core.transform_chain.TransformChain`,
the paper's one-pass composite as a small chain compiler.  Builder calls
(``then_translate``/``then_scale``/``then_rotate``) only append to a lazy
IR -- no 3x3 matmuls, no allocation.  At ``apply`` the chain folds
algebraically (adjacent translates sum, scales multiply, scale+translate
fuse into one affine; pure-diagonal chains never touch the MXU) and lowers
to a single fused lane-dense Pallas kernel: one HBM read of the points and
one write for the *whole* chain, versus one read+write per primitive under
sequential dispatch.  Compiled plans are cached by chain structure +
backend, so the serving hot path (same chain shape, fresh parameter values
per request) neither re-folds nor retraces; ``TransformChain.apply_many``
maps one cached plan over a leading batch axis in one launch.  See
``benchmarks/PERF.md`` for the measured byte economy (the ``chain_*``
benchmark rows).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.transform_chain import TransformChain
from repro.kernels import affine as k_affine
from repro.kernels import rotate2d as k_rotate2d
from repro.kernels import scale as k_scale
from repro.kernels import translate as k_translate
from repro.kernels import vecadd as k_vecadd


# -- primitive transforms (paper sections 5.1-5.3) ---------------------------

def translate(points: jnp.ndarray, t, *, backend=None) -> jnp.ndarray:
    """q = p + t (vector-vector; Table 1)."""
    return k_translate(points, jnp.asarray(t, points.dtype), backend=backend)


def scale(points: jnp.ndarray, s, *, backend=None) -> jnp.ndarray:
    """q = S x p, diagonal S (vector-scalar; Table 2)."""
    return k_scale(points, jnp.asarray(s, points.dtype), backend=backend)


def rotate(points: jnp.ndarray, theta, *, backend=None) -> jnp.ndarray:
    """q = p @ R(theta), row-vector form (matrix algorithm; section 5.3)."""
    return k_rotate2d(points, theta, backend=backend)


def affine(points: jnp.ndarray, s, t, *, backend=None) -> jnp.ndarray:
    """q = S x p + t fused (beyond-paper fusion of 5.1 + 5.2)."""
    return k_affine(points, jnp.asarray(s, points.dtype),
                    jnp.asarray(t, points.dtype), backend=backend)


def vecadd(u: jnp.ndarray, v: jnp.ndarray, *, backend=None) -> jnp.ndarray:
    """Elementwise u + v, the raw Table 1 op."""
    return k_vecadd(u, v, backend=backend)


# -- composite transforms (paper's "General Composite Algorithm") ------------

@dataclasses.dataclass(frozen=True)
class Transform2D:
    """Composite 2D transform: ``then_*`` builders append in application
    order (first call applied first -- under the row-vector convention
    that IS the paper's right-multiplied matrix chain).  Builders are lazy
    (IR append only); ``apply`` runs the folded chain as one fused kernel
    pass via the plan cache."""
    chain: TransformChain

    @staticmethod
    def identity() -> "Transform2D":
        return Transform2D(TransformChain.identity(2))

    @staticmethod
    def from_matrix(m: jnp.ndarray) -> "Transform2D":
        """Wrap an explicit (3, 3) homogeneous matrix (row-vector form)."""
        return Transform2D(TransformChain.identity(2).matrix(m))

    def then_translate(self, tx, ty) -> "Transform2D":
        return Transform2D(self.chain.translate(tx, ty))

    def then_scale(self, sx, sy) -> "Transform2D":
        return Transform2D(self.chain.scale(sx, sy))

    def then_rotate(self, theta) -> "Transform2D":
        return Transform2D(self.chain.rotate(theta))

    @property
    def matrix(self) -> jnp.ndarray:
        """The composed (3, 3) homogeneous matrix (materialised on demand;
        building it is no longer part of the apply path)."""
        return self.chain.as_homogeneous()

    def apply(self, points: jnp.ndarray, *, backend=None) -> jnp.ndarray:
        """points (..., 2) -> (..., 2) in one fused HBM pass."""
        return self.chain.apply(points, backend=backend)


@dataclasses.dataclass(frozen=True)
class Transform3D:
    """3D homogeneous composite on (..., 3) points; same lazy chain IR and
    fused one-pass lowering as :class:`Transform2D` (the companion paper's
    MorphoSys 3D pipeline mapping)."""
    chain: TransformChain

    @staticmethod
    def identity() -> "Transform3D":
        return Transform3D(TransformChain.identity(3))

    @staticmethod
    def from_matrix(m: jnp.ndarray) -> "Transform3D":
        """Wrap an explicit (4, 4) homogeneous matrix (row-vector form)."""
        return Transform3D(TransformChain.identity(3).matrix(m))

    def then_translate(self, tx, ty, tz) -> "Transform3D":
        return Transform3D(self.chain.translate(tx, ty, tz))

    def then_scale(self, sx, sy, sz) -> "Transform3D":
        return Transform3D(self.chain.scale(sx, sy, sz))

    def then_rotate(self, theta, axis) -> "Transform3D":
        return Transform3D(self.chain.rotate(theta, axis=axis))

    @property
    def matrix(self) -> jnp.ndarray:
        return self.chain.as_homogeneous()

    def apply(self, points: jnp.ndarray, *, backend=None) -> jnp.ndarray:
        return self.chain.apply(points, backend=backend)
