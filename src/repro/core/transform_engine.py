"""Transform engine -- the paper's "graphics acceleration library" in JAX.

Section 7 of the paper: "The discussed findings are part of a complete
graphics acceleration library using the M1 reconfigurable system."  This
module is that library re-expressed for TPU: the three primitive classes
(vector-vector, vector-scalar, matrix) as composable JAX transforms, each
dispatched to the corresponding Pallas kernel on TPU (ref oracle on CPU).

Points are row vectors (..., 2) in 2D (or (..., 3) homogeneous), so a
composite transform chain is a single right-multiplied matrix product --
exactly the paper's "General Composite Algorithm using Matrix Algorithm".
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.kernels import affine as k_affine
from repro.kernels import matmul as k_matmul
from repro.kernels import rotate2d as k_rotate2d
from repro.kernels import scale as k_scale
from repro.kernels import translate as k_translate
from repro.kernels import vecadd as k_vecadd


# -- primitive transforms (paper sections 5.1-5.3) ---------------------------

def translate(points: jnp.ndarray, t, *, backend=None) -> jnp.ndarray:
    """q = p + t (vector-vector; Table 1)."""
    return k_translate(points, jnp.asarray(t, points.dtype), backend=backend)


def scale(points: jnp.ndarray, s, *, backend=None) -> jnp.ndarray:
    """q = S x p, diagonal S (vector-scalar; Table 2)."""
    return k_scale(points, jnp.asarray(s, points.dtype), backend=backend)


def rotate(points: jnp.ndarray, theta, *, backend=None) -> jnp.ndarray:
    """q = R(theta) p (matrix algorithm; section 5.3)."""
    return k_rotate2d(points, theta, backend=backend)


def affine(points: jnp.ndarray, s, t, *, backend=None) -> jnp.ndarray:
    """q = S x p + t fused (beyond-paper fusion of 5.1 + 5.2)."""
    return k_affine(points, jnp.asarray(s, points.dtype),
                    jnp.asarray(t, points.dtype), backend=backend)


def vecadd(u: jnp.ndarray, v: jnp.ndarray, *, backend=None) -> jnp.ndarray:
    """Elementwise u + v, the raw Table 1 op."""
    return k_vecadd(u, v, backend=backend)


# -- composite transforms (paper's "General Composite Algorithm") ------------

@dataclasses.dataclass(frozen=True)
class Transform2D:
    """Homogeneous 3x3 transform composed right-to-left like the paper's
    matrix algorithm; applying it is one matmul on the array."""
    matrix: jnp.ndarray  # (3, 3)

    @staticmethod
    def identity() -> "Transform2D":
        return Transform2D(jnp.eye(3, dtype=jnp.float32))

    def then_translate(self, tx, ty) -> "Transform2D":
        m = jnp.array([[1, 0, 0], [0, 1, 0], [tx, ty, 1]], jnp.float32)
        return Transform2D(k_matmul(self.matrix, m, backend="ref"))

    def then_scale(self, sx, sy) -> "Transform2D":
        m = jnp.array([[sx, 0, 0], [0, sy, 0], [0, 0, 1]], jnp.float32)
        return Transform2D(k_matmul(self.matrix, m, backend="ref"))

    def then_rotate(self, theta) -> "Transform2D":
        c, s = jnp.cos(theta), jnp.sin(theta)
        m = jnp.array([[c, s, 0], [-s, c, 0], [0, 0, 1]], jnp.float32)
        return Transform2D(k_matmul(self.matrix, m, backend="ref"))

    def apply(self, points: jnp.ndarray, *, backend=None) -> jnp.ndarray:
        """points (..., 2) -> (..., 2) via one homogeneous matmul."""
        flat = points.reshape(-1, 2)
        ones = jnp.ones((flat.shape[0], 1), points.dtype)
        homo = jnp.concatenate([flat, ones], axis=-1)
        out = k_matmul(homo, self.matrix.astype(points.dtype), backend=backend)
        return out[:, :2].reshape(points.shape)
